pub fn handshake() -> Result<u64, String> {
    Err("stringly typed".to_string())
}

pub fn fine() -> Result<String, std::io::Error> {
    Ok(String::new())
}
