//! The one sanctioned wall-clock read.
use std::time::Instant;

pub fn now() -> Instant {
    Instant::now()
}
