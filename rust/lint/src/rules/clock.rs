//! **clock-discipline** — wall time must be injectable.
//!
//! Deterministic replay (docs/DESIGN.md §Scheduling, §Determinism) hangs
//! on one discipline: every timestamp the stack takes goes through
//! `util/clock.rs::Clock`, so a manual clock can substitute virtual time
//! everywhere at once.  A single direct `Instant::now()` in a replayed
//! path silently re-couples the run to the host scheduler — the exact
//! decay this rule exists to stop.
//!
//! Scope: non-test code under `rust/src/`.  Exempt: `util/clock.rs` (the
//! one place allowed to touch the real clock), `#[cfg(test)]` modules,
//! and anything outside `rust/src` (integration tests and the
//! plain-binary benches under `rust/benches/` measure real wall time by
//! design).  Wall-time *profiling* of real hardware execution is
//! legitimate but must carry a justified
//! `// roadlint: allow(clock-discipline)` escape so each site is an
//! audited decision, not an accident.

use super::{code_matches, Finding, RepoContext};

pub const NAME: &str = "clock-discipline";

const PATTERNS: [&str; 2] = ["Instant::now()", "SystemTime::now()"];

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &ctx.files {
        if !file.rel.starts_with("rust/src/") || file.rel == "rust/src/util/clock.rs" {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for pat in PATTERNS {
                if !code_matches(&line.code, pat).is_empty() {
                    out.push(Finding {
                        rule: NAME,
                        path: file.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "direct {pat} — take time from util/clock.rs::Clock so this \
                             path stays replayable on a manual clock"
                        ),
                    });
                }
            }
        }
    }
    out
}
