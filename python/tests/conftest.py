import os

import jax
import pytest

# Deterministic, CPU-only test environment.
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")
