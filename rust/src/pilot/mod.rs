//! The paper's pilot studies (§3.1, Figure 2 and Figure B.1).
//!
//! * **Study 1** — magnitude vs angular displacement: finetune the backbone
//!   (full vs LoRA), extract per-layer last-token representations of the
//!   same inputs from the pretrained and finetuned model through the
//!   `reps_<mode>_<cfg>` graphs, and report ΔM = |‖x‖−‖x⁰‖|/‖x⁰‖ and
//!   ΔD = cos(x, x⁰) per layer.
//! * **Study 2** — disentanglement: freeze the backbone, train the paper's
//!   two-layer head over frozen representations in three first-layer modes
//!   (normal / magnitude-only / angle-only) on four classification tasks,
//!   plus a random-backbone weak baseline.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::adapters::Adapter;
use crate::model::ParamStore;
use crate::runtime::{Arg, Runtime};
use crate::tasks::{Example, Task, TaskSampler};
use crate::tensor::HostTensor;
use crate::trainer::{self, Recipe, Trainer};
use crate::util::rng::Rng;

/// Per-layer (ΔM, ΔD) statistics, averaged over a probe set.
#[derive(Clone, Debug)]
pub struct LayerDelta {
    pub layer: usize,
    /// Mean relative magnitude change |‖x‖−‖x⁰‖| / ‖x⁰‖.
    pub delta_m: f64,
    /// Mean cosine similarity cos(x, x⁰) ∈ [-1, 1] (smaller = more rotation).
    pub delta_d: f64,
}

/// Extract [B, n_layers+1, D] hidden states through a reps graph with the
/// given parameter store (and identity adapters).
pub fn hidden_states(
    rt: &Rc<Runtime>,
    config: &str,
    mode: &str,
    params: &ParamStore,
    adapter: Option<&Adapter>,
    tokens: &[i32],
    lengths: &[i32],
) -> Result<HostTensor> {
    let name = format!("reps_{mode}_{config}");
    let exe = rt.load(&name)?;
    let info = &exe.info;
    let (b, l) = (info.batch.unwrap(), info.seq_len.unwrap());
    if tokens.len() != b * l || lengths.len() != b {
        bail!("reps input shape mismatch (want {b}x{l})");
    }

    // Adapter banks: n=1 slots; install the trained adapter into slot 0
    // (all requests use id 0 here).
    let mut bank = crate::adapters::AdapterBank::new(&exe.info_config(rt)?, mode, 1)?;
    if let Some(a) = adapter {
        bank.set_slot(0, a)?;
    }

    let tok = HostTensor::i32(vec![b, l], tokens.to_vec());
    let len = HostTensor::i32(vec![b], lengths.to_vec());
    let ids = HostTensor::i32(vec![b], vec![0; b]);
    let mut data: BTreeMap<&str, &HostTensor> = BTreeMap::new();
    data.insert("tokens", &tok);
    data.insert("lengths", &len);
    data.insert("ids", &ids);

    let mut owned: Vec<(String, HostTensor)> = Vec::new();
    for spec in &info.inputs {
        if spec.group == "adapters" {
            let t = bank
                .tensors
                .get(&spec.name)
                .ok_or_else(|| anyhow!("bank missing {}", spec.name))?;
            owned.push((spec.name.clone(), t.clone()));
        }
    }

    let mut args: Vec<Arg> = Vec::with_capacity(info.inputs.len());
    let mut oi = 0usize;
    for spec in &info.inputs {
        match spec.group.as_str() {
            "params" => args.push(Arg::Host(params.get(&spec.name)?)),
            "adapters" => {
                args.push(Arg::Host(&owned[oi].1));
                oi += 1;
            }
            "data" => args.push(Arg::Host(
                data.get(spec.name.as_str())
                    .copied()
                    .ok_or_else(|| anyhow!("missing reps data {}", spec.name))?,
            )),
            g => bail!("unexpected reps input group {g}"),
        }
    }
    let mut outs = exe.run(&args)?;
    Ok(outs.remove(0))
}

trait InfoConfig {
    fn info_config(&self, rt: &Rc<Runtime>) -> Result<crate::manifest::ModelConfigInfo>;
}

impl InfoConfig for crate::runtime::Executable {
    fn info_config(&self, rt: &Rc<Runtime>) -> Result<crate::manifest::ModelConfigInfo> {
        Ok(rt.manifest.config(&self.info.config)?.clone())
    }
}

/// Compare per-layer representations of `base` vs `tuned` on a shared
/// probe batch; returns one [`LayerDelta`] per layer (embedding = layer 0).
pub fn rep_deltas(
    rt: &Rc<Runtime>,
    config: &str,
    base: &ParamStore,
    base_mode: &str,
    base_adapter: Option<&Adapter>,
    tuned: &ParamStore,
    tuned_mode: &str,
    tuned_adapter: Option<&Adapter>,
    probe_task: &dyn Task,
    seed: u64,
) -> Result<Vec<LayerDelta>> {
    let name = format!("reps_base_{config}");
    let exe = rt.load(&name)?;
    let (b, l) = (exe.info.batch.unwrap(), exe.info.seq_len.unwrap());
    let d = rt.manifest.config(config)?.d_model;

    // Shared probe inputs.
    let mut rng = Rng::seed_from(seed);
    let mut tokens = vec![0i32; b * l];
    let mut lengths = vec![1i32; b];
    for row in 0..b {
        let ex: Example = probe_task.sample(&mut rng);
        let p = &ex.prompt[..ex.prompt.len().min(l)];
        tokens[row * l..row * l + p.len()].copy_from_slice(p);
        lengths[row] = p.len() as i32;
    }

    let h0 = hidden_states(rt, config, base_mode, base, base_adapter, &tokens, &lengths)?;
    let h1 = hidden_states(rt, config, tuned_mode, tuned, tuned_adapter, &tokens, &lengths)?;
    let n_layers = h0.shape[1];

    let mut out = Vec::with_capacity(n_layers);
    for layer in 0..n_layers {
        let mut dm = 0f64;
        let mut dd = 0f64;
        for row in 0..b {
            let off = (row * n_layers + layer) * d;
            let x0 = h0.read_f32_range(off, d);
            let x1 = h1.read_f32_range(off, d);
            let n0 = norm(&x0);
            let n1 = norm(&x1);
            dm += ((n1 - n0).abs() / n0.max(1e-9)) as f64;
            dd += (dot(&x0, &x1) / (n0 * n1).max(1e-9)) as f64;
        }
        out.push(LayerDelta {
            layer,
            delta_m: dm / b as f64,
            delta_d: dd / b as f64,
        });
    }
    Ok(out)
}

fn norm(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Study-1 driver: finetune with `method` (full or lora) on a probe task,
/// then report per-layer deltas vs the pretrained backbone (Fig 2 L/M and
/// Fig B.1 series).
pub fn study_magnitude_angle(
    rt: &Rc<Runtime>,
    config: &str,
    method: &str,
    steps: usize,
    seed: u64,
) -> Result<Vec<LayerDelta>> {
    let base = ParamStore::load_pretrained(&rt.manifest, config)?;
    let mut tr = Trainer::new(rt.clone(), config, method)?;
    let suite = crate::tasks::nlu_suite();
    let task = &suite[4]; // sst2-x, mirroring the paper's SST-2 pilot
    let recipe = Recipe::default()
        .with_lr(Recipe::default_lr(method))
        .with_steps(steps)
        .with_seed(seed);
    let mut src = TaskSampler { task: task.as_ref(), batch: tr.batch, seq_len: tr.seq_len };
    trainer::train(&mut tr, &recipe, &mut src, None)?;

    match method {
        "full" => {
            let tuned = tr.merged_params()?;
            rep_deltas(rt, config, &base, "base", None, &tuned, "base", None, task.as_ref(), seed)
        }
        "lora" => {
            let adapter = tr.export_adapter()?;
            rep_deltas(
                rt,
                config,
                &base,
                "base",
                None,
                &base,
                "lora",
                Some(&adapter),
                task.as_ref(),
                seed,
            )
        }
        m => bail!("study 1 supports full|lora, got {m}"),
    }
}

// ---------------------------------------------------------------------------
// Study 2: disentanglement head (Fig 2 Right)
// ---------------------------------------------------------------------------

/// Train the two-layer head in `head_mode` (normal / mag / angle) over
/// frozen-backbone representations of `task`; returns eval accuracy.
pub struct HeadResult {
    pub task: String,
    pub head_mode: String,
    pub random_backbone: bool,
    pub score: f64,
}

pub fn study_disentangle(
    rt: &Rc<Runtime>,
    config: &str,
    head_mode: &str,
    task: &dyn Task,
    random_backbone: bool,
    steps: usize,
    seed: u64,
) -> Result<HeadResult> {
    let params = if random_backbone {
        // Weak baseline: re-randomized backbone (different seed stream).
        randomize_params(&ParamStore::load_pretrained(&rt.manifest, config)?, seed ^ 0xbad)
    } else {
        ParamStore::load_pretrained(&rt.manifest, config)?
    };

    let reps_exe = rt.load(&format!("reps_base_{config}"))?;
    let (rb, rl) = (reps_exe.info.batch.unwrap(), reps_exe.info.seq_len.unwrap());
    let d = rt.manifest.config(config)?.d_model;
    let n_layers = rt.manifest.config(config)?.n_layers;

    let head_train = rt.load(&format!("head_train_{head_mode}_{config}"))?;
    let head_logits = rt.load(&format!("head_logits_{head_mode}_{config}"))?;
    let hb = head_train.info.batch.unwrap();
    let n_classes: usize = head_logits.info.outputs[0].shape[1];
    let labels = task.label_tokens();
    if labels.len() > n_classes {
        bail!("task {} has {} classes; head supports {n_classes}", task.name(), labels.len());
    }

    // Head state (init mirrors train.head_init: normal(0, d^-1/2)).
    let mut rng = Rng::seed_from(seed);
    let mut head: Vec<(String, HostTensor)> = vec![
        ("b1".into(), HostTensor::zeros(vec![d], crate::tensor::DType::F32)),
        ("b2".into(), HostTensor::zeros(vec![n_classes], crate::tensor::DType::F32)),
        (
            "w1".into(),
            HostTensor::f32(vec![d, d], rng.normal_vec(d * d, (d as f32).powf(-0.5))),
        ),
        (
            "w2".into(),
            HostTensor::f32(
                vec![d, n_classes],
                rng.normal_vec(d * n_classes, (d as f32).powf(-0.5)),
            ),
        ),
    ];
    let mut opt_m: Vec<HostTensor> =
        head.iter().map(|(_, t)| HostTensor::zeros(t.shape.clone(), crate::tensor::DType::F32)).collect();
    let mut opt_v = opt_m.clone();

    // Representation extraction helper: second-last block output, per the
    // paper's protocol ([CLS] of the penultimate Transformer block).
    let probe_layer = n_layers.saturating_sub(1); // index into [0..=n_layers]
    let get_reps = |rng: &mut Rng, n: usize| -> Result<(Vec<f32>, Vec<i32>)> {
        let mut feats = Vec::with_capacity(n * d);
        let mut labels_out = Vec::with_capacity(n);
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(rb);
            let mut tokens = vec![0i32; rb * rl];
            let mut lengths = vec![1i32; rb];
            let mut lab = vec![0i32; rb];
            for row in 0..take {
                let ex = task.sample(rng);
                let p = &ex.prompt[..ex.prompt.len().min(rl)];
                tokens[row * rl..row * rl + p.len()].copy_from_slice(p);
                lengths[row] = p.len() as i32;
                lab[row] = ex.answer as i32;
            }
            let h = hidden_states(rt, config, "base", &params, None, &tokens, &lengths)?;
            let per = h.shape[1];
            for row in 0..take {
                let off = (row * per + probe_layer) * d;
                feats.extend(h.read_f32_range(off, d));
                labels_out.push(lab[row]);
            }
            done += take;
        }
        Ok((feats, labels_out))
    };

    // Precompute a fixed representation pool once (the backbone is frozen,
    // so reps never change — this is the expensive part), then train the
    // head on minibatches drawn from it.
    let pool_n = 8 * hb;
    let (pool_feats, pool_labs) = get_reps(&mut rng, pool_n)?;

    // Train the head.
    let lr = 1e-3f32;
    for step in 0..steps {
        let mut feats = Vec::with_capacity(hb * d);
        let mut labs = Vec::with_capacity(hb);
        for _ in 0..hb {
            let i = rng.below(pool_n);
            feats.extend_from_slice(&pool_feats[i * d..(i + 1) * d]);
            labs.push(pool_labs[i]);
        }
        let reps_t = HostTensor::f32(vec![hb, d], feats);
        let labs_t = HostTensor::i32(vec![hb], labs);
        let step_t = HostTensor::scalar_f32((step + 1) as f32);
        let lr_t = HostTensor::scalar_f32(lr);
        let mut args: Vec<Arg> = Vec::new();
        for (_, t) in &head {
            args.push(Arg::Host(t));
        }
        for t in &opt_m {
            args.push(Arg::Host(t));
        }
        for t in &opt_v {
            args.push(Arg::Host(t));
        }
        args.push(Arg::Host(&step_t));
        args.push(Arg::Host(&lr_t));
        args.push(Arg::Host(&reps_t));
        args.push(Arg::Host(&labs_t));
        let outs = head_train.run(&args)?;
        let mut it = outs.into_iter();
        for (_, t) in head.iter_mut() {
            *t = it.next().unwrap();
        }
        for t in opt_m.iter_mut() {
            *t = it.next().unwrap();
        }
        for t in opt_v.iter_mut() {
            *t = it.next().unwrap();
        }
    }

    // Evaluate.
    let mut eval_rng = Rng::seed_from(seed ^ 0xe7a1);
    let n_eval = 4 * hb;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut done = 0usize;
    while done < n_eval {
        let (feats, labs) = get_reps(&mut eval_rng, hb)?;
        let reps_t = HostTensor::f32(vec![hb, d], feats);
        let mut args: Vec<Arg> = Vec::new();
        for (_, t) in &head {
            args.push(Arg::Host(t));
        }
        args.push(Arg::Host(&reps_t));
        let outs = head_logits.run(&args)?;
        let logits = &outs[0];
        for row in 0..hb {
            let lrow = logits.read_f32_range(row * n_classes, n_classes);
            let pred = lrow
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == labs[row] as usize {
                correct += 1;
            }
            total += 1;
        }
        done += hb;
    }

    Ok(HeadResult {
        task: task.name().to_string(),
        head_mode: head_mode.to_string(),
        random_backbone,
        score: correct as f64 / total as f64,
    })
}

/// Re-randomize a parameter store (matching magnitudes, fresh directions)
/// — the paper's "randomly initialized RoBERTa" weak baseline.
pub fn randomize_params(store: &ParamStore, seed: u64) -> ParamStore {
    let mut rng = Rng::seed_from(seed);
    let named: Vec<(String, HostTensor)> = store
        .names
        .iter()
        .zip(&store.tensors)
        .map(|(n, t)| {
            let vals = t.as_f32();
            let scale = (vals.iter().map(|v| v * v).sum::<f32>() / vals.len() as f32)
                .sqrt()
                .max(1e-6);
            // Norm-like params stay at 1 (they gate variance, not direction).
            if n.ends_with("norm") {
                (n.clone(), t.clone())
            } else {
                (n.clone(), HostTensor::f32(t.shape.clone(), rng.normal_vec(vals.len(), scale)))
            }
        })
        .collect();
    ParamStore::from_tensors(store.config.clone(), named)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_dot_basics() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn randomize_preserves_shapes_and_norm_params() {
        let cfg = crate::manifest::ModelConfigInfo {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            d_ff: 8,
            max_seq: 8,
            head_dim: 2,
            n_adapters: 2,
            lora_rank: 2,
        };
        let named = vec![
            ("w".to_string(), HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0])),
            ("final_norm".to_string(), HostTensor::f32(vec![2], vec![1.0, 1.0])),
        ];
        let store = ParamStore::from_tensors(cfg, named);
        let r = randomize_params(&store, 1);
        assert_eq!(r.get("w").unwrap().shape, vec![2, 2]);
        assert_ne!(r.get("w").unwrap().as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get("final_norm").unwrap().as_f32(), vec![1.0, 1.0]);
    }
}
