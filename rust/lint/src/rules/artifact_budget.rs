//! **artifact-gate-budget** — end-to-end coverage must not drain back
//! behind the artifact gate.
//!
//! PR 5's reference backend un-gated the integration suites; the tests
//! still carrying `require_artifacts!()` are exactly the ones where PJRT
//! numerics are the point (golden records, the trainer, the
//! cross-backend oracle).  The gate is counted *statically* — libtest
//! captures the skip notices of passing tests, so grepping test output
//! would always see zero — and held to a hard budget: a new gated test
//! fails the lint until the budget here is consciously raised.
//!
//! This rule replaces the shell `grep | wc -l` step that used to live in
//! `.github/workflows/ci.yml` ("check the discipline, not the author" —
//! and not the shell quoting either).

use super::{code_matches, Finding, RepoContext};

pub const NAME: &str = "artifact-gate-budget";

/// The allowed number of `require_artifacts!()` call sites under
/// `rust/tests`.  Raising this number is a reviewed decision: it means a
/// test that could run on the reference backend was parked behind the
/// artifact gate instead.
pub const BUDGET: usize = 17;

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut sites: Vec<(String, usize)> = Vec::new();
    for file in &ctx.files {
        if !file.rel.starts_with("rust/tests/") {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            for _ in code_matches(&line.code, "require_artifacts!") {
                sites.push((file.rel.clone(), i + 1));
            }
        }
    }
    if sites.len() <= BUDGET {
        return Vec::new();
    }
    // One finding per over-budget site (the budget covers the first
    // BUDGET in file order; the overflow is what gets pointed at).
    sites
        .iter()
        .skip(BUDGET)
        .map(|(path, line)| Finding {
            rule: NAME,
            path: path.clone(),
            line: *line,
            message: format!(
                "{} require_artifacts!() call sites exceed the budget of {BUDGET} — \
                 port the test to the reference backend, or raise BUDGET in \
                 rust/lint/src/rules/artifact_budget.rs with a rationale",
                sites.len()
            ),
        })
        .collect()
}
