//! Pluggable admission scheduling: which waiting request gets the next
//! free decode slot (and the chance to page its adapter into the device
//! bank).
//!
//! The engine's admission loop ranks the [`super::queue::AdmissionQueue`]
//! through a [`SchedPolicy`] every scheduler iteration and pops in that
//! order ([`super::queue::AdmissionQueue::pop_scheduled`]).  Four
//! policies ship ([`PolicyKind`]):
//!
//! * **fcfs** — identity ranking; byte-identical to the pre-policy FIFO
//!   admission, and the default.
//! * **edf** — earliest absolute deadline first
//!   ([`super::request::Request::deadline_at`]); deadline-free requests
//!   admit after all deadline-bearing ones, FIFO within ties.
//! * **priority** — higher [`super::request::Request::priority`] tier
//!   first, FIFO within a tier.
//! * **fair** — fair-share across adapters: fewest decode lanes currently
//!   held, then fewest lifetime admissions, so one hot adapter cannot
//!   starve the rest of the slots and bank pages.  Cold adapters always
//!   outrank the flood, which bounds every adapter's queue wait.
//!
//! Rankings must be deterministic pure functions of the queue and
//! [`SchedContext`] — determinism is what makes the virtual-clock suites
//! and `road bench-serving --study sched --sim-clock` byte-reproducible.
//!
//! [`SchedSim`] is the deterministic engine harness: the same queue +
//! policy + deadline machinery the engine runs, with decode compute
//! replaced by a fixed per-step virtual cost on a
//! [`crate::util::clock::Clock::manual`] clock.  It needs no AOT
//! artifacts, so the per-policy invariant suites
//! (`rust/tests/integration_sched.rs`, the scheduler proptests) and the
//! sched study run everywhere, fast, with zero sleeps.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::clock::Clock;

use super::queue::{AdmissionQueue, EngineError};
use super::request::Request;

/// Which admission scheduler an engine runs; selected via
/// `EngineConfig::policy` / `road serve --policy <name>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Fcfs,
    Edf,
    Priority,
    FairShare,
}

impl PolicyKind {
    /// Every shipped policy, in the order studies and tests sweep them.
    pub const ALL: [PolicyKind; 4] =
        [PolicyKind::Fcfs, PolicyKind::Edf, PolicyKind::Priority, PolicyKind::FairShare];

    /// Stable CLI / report name.
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fcfs => "fcfs",
            PolicyKind::Edf => "edf",
            PolicyKind::Priority => "priority",
            PolicyKind::FairShare => "fair",
        }
    }

    /// Parse a `--policy` flag value.
    pub fn from_name(name: &str) -> Result<PolicyKind> {
        Ok(match name {
            "fcfs" => PolicyKind::Fcfs,
            "edf" => PolicyKind::Edf,
            "priority" => PolicyKind::Priority,
            "fair" | "fair-share" => PolicyKind::FairShare,
            other => bail!("unknown scheduling policy {other:?} (fcfs|edf|priority|fair)"),
        })
    }
}

/// How a [`SchedSim`] accounts prompt prefill — the virtual-time analogue
/// of [`super::engine::EngineConfig::prefill_chunk_tokens`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillModel {
    /// Legacy: admission instantly installs a generating lane (prefill is
    /// free) and every step costs exactly the sim's `step_cost`.  The
    /// default; byte-identical to the pre-chunking harness.
    None,
    /// Atomic prefill (the engine's `--prefill-chunk=0` baseline): an
    /// admitted lane's whole prompt is fed in its admission step, which
    /// stretches that step by `token_cost` per prompt token — every other
    /// lane's inter-token gap absorbs the full stretch.
    Atomic { token_cost: Duration },
    /// Chunked prefill (the engine's mixed step): each step spends at most
    /// `budget` tokens — one per occupied lane (decode, or the feeding
    /// lane's decode-fed prompt token), the leftover fed to
    /// admitted-but-unfinished prompts in admission order — so no step
    /// stretches beyond the budget.
    Chunked { budget: usize, token_cost: Duration },
}

/// Live engine state a policy may consult when ranking waiting work.
pub struct SchedContext<'a> {
    /// Scheduler-iteration timestamp from the engine's clock.
    pub now: Instant,
    /// Decode lanes currently held, per adapter name ("" = base model).
    pub in_flight: &'a BTreeMap<String, usize>,
    /// Lifetime admissions per adapter name ("" = base model).
    pub admitted: &'a BTreeMap<String, usize>,
}

/// An admission scheduler: ranks the waiting queue each iteration.
pub trait SchedPolicy {
    fn kind(&self) -> PolicyKind;

    /// Queue indices in admission-priority order (best candidate first).
    /// Must be deterministic in (queue, ctx); the pop keeps FIFO order
    /// among requests the ranking does not take.
    fn order(&mut self, queue: &AdmissionQueue, ctx: &SchedContext<'_>) -> Vec<usize>;
}

/// First-come-first-served: the identity ranking (pre-policy behavior).
pub struct Fcfs;

impl SchedPolicy for Fcfs {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Fcfs
    }

    fn order(&mut self, queue: &AdmissionQueue, _ctx: &SchedContext<'_>) -> Vec<usize> {
        (0..queue.len()).collect()
    }
}

/// Earliest-deadline-first: tightest absolute deadline admits first;
/// deadline-free requests rank after all deadline-bearing ones.
pub struct Edf;

impl SchedPolicy for Edf {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Edf
    }

    fn order(&mut self, queue: &AdmissionQueue, _ctx: &SchedContext<'_>) -> Vec<usize> {
        // (no-deadline-last, absolute deadline); the stable sort keeps
        // FIFO order within ties and among the deadline-free tail.
        let keys: Vec<(bool, Option<Instant>)> =
            queue.iter().map(|r| (r.deadline_at().is_none(), r.deadline_at())).collect();
        let mut idx: Vec<usize> = (0..keys.len()).collect();
        idx.sort_by_key(|&i| keys[i]);
        idx
    }
}

/// Priority tiers: higher [`Request::priority`] first, FIFO within a tier.
pub struct Priority;

impl SchedPolicy for Priority {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Priority
    }

    fn order(&mut self, queue: &AdmissionQueue, _ctx: &SchedContext<'_>) -> Vec<usize> {
        let prios: Vec<u8> = queue.iter().map(|r| r.priority).collect();
        let mut idx: Vec<usize> = (0..prios.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(prios[i]));
        idx
    }
}

/// Fair-share across adapters: requests whose adapter holds the fewest
/// decode lanes right now rank first, then fewest lifetime admissions,
/// then FIFO — round-robin service under skew, so a hot adapter's flood
/// cannot starve cold adapters out of slots or bank pages.
pub struct FairShare;

impl SchedPolicy for FairShare {
    fn kind(&self) -> PolicyKind {
        PolicyKind::FairShare
    }

    fn order(&mut self, queue: &AdmissionQueue, ctx: &SchedContext<'_>) -> Vec<usize> {
        let keys: Vec<(usize, usize)> = queue
            .iter()
            .map(|r| {
                let name = r.adapter.as_deref().unwrap_or("");
                (
                    ctx.in_flight.get(name).copied().unwrap_or(0),
                    ctx.admitted.get(name).copied().unwrap_or(0),
                )
            })
            .collect();
        let mut idx: Vec<usize> = (0..keys.len()).collect();
        idx.sort_by_key(|&i| keys[i]);
        idx
    }
}

/// Instantiate the policy an `EngineConfig` names.
pub fn make_policy(kind: PolicyKind) -> Box<dyn SchedPolicy> {
    match kind {
        PolicyKind::Fcfs => Box::new(Fcfs),
        PolicyKind::Edf => Box::new(Edf),
        PolicyKind::Priority => Box::new(Priority),
        PolicyKind::FairShare => Box::new(FairShare),
    }
}

// ---------------------------------------------------------------------------
// SchedSim: the deterministic engine harness
// ---------------------------------------------------------------------------

/// Terminal state of one simulated request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimOutcome {
    Finished,
    /// Shed from the queue or reaped from a lane by the deadline enforcer.
    DeadlineShed,
    Cancelled,
}

/// One simulated request's terminal record — everything the scheduler
/// study and the invariant suites aggregate.
#[derive(Clone, Debug)]
pub struct SimRecord {
    pub id: u64,
    pub adapter: Option<String>,
    pub priority: u8,
    pub deadline: Option<Duration>,
    pub submitted_at: Instant,
    /// `None` when the request never reached a decode lane.
    pub admitted_at: Option<Instant>,
    /// Global admission ordinal (0 = first request ever admitted).
    /// Several lanes can share one `admitted_at` virtual instant; this
    /// sequence is the unambiguous admission order.  `None` when never
    /// admitted.
    pub admitted_seq: Option<usize>,
    pub finished_at: Instant,
    pub outcome: SimOutcome,
}

impl SimRecord {
    /// Submit → admission on the virtual clock; `None` if never admitted.
    pub fn queue_wait(&self) -> Option<Duration> {
        self.admitted_at.map(|a| a - self.submitted_at)
    }

    /// Submit → terminal event on the virtual clock.
    pub fn e2e(&self) -> Duration {
        self.finished_at - self.submitted_at
    }
}

struct SimLane {
    req: Request,
    admitted_at: Instant,
    admitted_seq: usize,
    generated: usize,
    /// Prompt tokens already prefilled into this lane's virtual cache
    /// (== `prompt.len()` once the lane is generating).
    fed: usize,
    /// Virtual stamp of the lane's latest token — the ITL baseline.
    last_token_at: Option<Instant>,
}

/// Paging counters of a [`SchedSim`]'s optional adapter-bank model
/// ([`SchedSim::with_bank`]) — the per-replica numbers the router study
/// compares across placement policies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimBankStats {
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    pub upload_bytes: usize,
}

/// Hit counters of a [`SchedSim`]'s optional shared-prefix cache model
/// ([`SchedSim::with_prefix_cache`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimPrefixStats {
    pub hits: usize,
    pub misses: usize,
}

/// LRU adapter-bank model: the accounting skeleton of
/// [`crate::adapters::AdapterBank`] (slot capacity, LRU eviction, pinning
/// of in-flight adapters, per-page-in upload bytes) with the device
/// transfers replaced by counters.  Admission fails — the request stays
/// queued, like the engine — when the adapter is cold and every resident
/// slot is pinned.
struct SimBank {
    slots: usize,
    row_bytes: usize,
    /// Resident adapter names, LRU order (front = coldest).
    resident: Vec<String>,
    stats: SimBankStats,
}

impl SimBank {
    /// Touch `adapter` for an admission.  `pinned` holds the adapters of
    /// currently active lanes (plus same-step admissions) — never LRU
    /// victims.  Returns whether the adapter is (now) resident.
    fn admit(&mut self, adapter: &str, pinned: &BTreeMap<String, usize>) -> bool {
        if let Some(pos) = self.resident.iter().position(|a| a == adapter) {
            let name = self.resident.remove(pos);
            self.resident.push(name);
            self.stats.hits += 1;
            return true;
        }
        if self.resident.len() >= self.slots {
            let victim = self
                .resident
                .iter()
                .position(|a| pinned.get(a).copied().unwrap_or(0) == 0);
            match victim {
                Some(pos) => {
                    self.resident.remove(pos);
                    self.stats.evictions += 1;
                }
                // Every resident adapter is pinned by an active lane: the
                // request stays queued (the engine's kv_admission_stall
                // analogue for the bank).
                None => return false,
            }
        }
        self.resident.push(adapter.to_string());
        self.stats.misses += 1;
        self.stats.upload_bytes += self.row_bytes;
        true
    }
}

/// LRU shared-prefix cache model: the hit/miss skeleton of
/// [`super::kv::PagedKv`]'s prefix reuse, keyed by (adapter, leading
/// prompt tokens) exactly like the engine's adapter-salted block hash.
struct SimPrefixCache {
    capacity: usize,
    prefix_len: usize,
    /// (adapter, prefix) keys, LRU order (front = coldest).
    entries: Vec<(String, Vec<i32>)>,
    stats: SimPrefixStats,
}

impl SimPrefixCache {
    fn on_admit(&mut self, adapter: &str, prompt: &[i32]) {
        let cut = self.prefix_len.min(prompt.len());
        let key = (adapter.to_string(), prompt[..cut].to_vec());
        if let Some(pos) = self.entries.iter().position(|e| *e == key) {
            let e = self.entries.remove(pos);
            self.entries.push(e);
            self.stats.hits += 1;
            return;
        }
        self.stats.misses += 1;
        self.entries.push(key);
        if self.entries.len() > self.capacity {
            self.entries.remove(0);
        }
    }
}

/// The engine's admission/decode loop with compute replaced by a fixed
/// per-step virtual cost, driven on a manual [`Clock`].
///
/// One [`SchedSim::step`] mirrors one `Engine::step`: shed expired queued
/// work, reap expired lanes, admit by policy ranking into free lanes,
/// advance every active lane by one token, then move the clock by the
/// step cost.  The queue, policies, and deadline machinery are the real
/// coordinator types, so invariants proved here are invariants of the
/// engine's scheduling layer — without needing AOT artifacts or sleeps.
pub struct SchedSim {
    pub clock: Clock,
    pub queue: AdmissionQueue,
    /// Longest admissible prompt (stands in for the engine's largest
    /// prefill bucket).
    pub max_prompt_len: usize,
    policy: Box<dyn SchedPolicy>,
    slots: Vec<Option<SimLane>>,
    admitted: BTreeMap<String, usize>,
    /// Total admissions so far — the source of `SimRecord::admitted_seq`.
    admissions: usize,
    step_cost: Duration,
    next_id: u64,
    records: Vec<SimRecord>,
    /// Optional adapter-bank model ([`SchedSim::with_bank`]); admission
    /// gates on residency exactly like the engine's paging hook.
    bank: Option<SimBank>,
    /// Optional shared-prefix cache model ([`SchedSim::with_prefix_cache`]).
    prefix: Option<SimPrefixCache>,
    /// Prefill accounting model ([`SchedSim::with_prefill`]).
    prefill: PrefillModel,
    /// Inter-token gap samples across all lanes (virtual durations).
    itl: Vec<Duration>,
    /// Per-gap stall: the gap in excess of the nominal decode cadence
    /// (`step_cost`) — what a prefill stretching the step costs everyone.
    itl_stall: Vec<Duration>,
    /// Submit → first-token samples (virtual durations).
    ttft: Vec<Duration>,
}

impl SchedSim {
    pub fn new(
        kind: PolicyKind,
        decode_slots: usize,
        queue_capacity: usize,
        step_cost: Duration,
    ) -> SchedSim {
        SchedSim {
            clock: Clock::manual(),
            queue: AdmissionQueue::new(queue_capacity),
            max_prompt_len: 64,
            policy: make_policy(kind),
            slots: (0..decode_slots).map(|_| None).collect(),
            admitted: BTreeMap::new(),
            admissions: 0,
            step_cost,
            next_id: 1,
            records: Vec::new(),
            bank: None,
            prefix: None,
            prefill: PrefillModel::None,
            itl: Vec::new(),
            itl_stall: Vec::new(),
            ttft: Vec::new(),
        }
    }

    /// Attach a prefill accounting model (default [`PrefillModel::None`],
    /// the legacy free-prefill harness).
    pub fn with_prefill(mut self, model: PrefillModel) -> SchedSim {
        self.prefill = model;
        self
    }

    /// Attach the LRU adapter-bank model: `slots` resident adapters,
    /// `row_bytes` uploaded per page-in.  Admissions whose adapter is cold
    /// when every resident slot is pinned stay queued, like the engine.
    pub fn with_bank(mut self, slots: usize, row_bytes: usize) -> SchedSim {
        self.bank = Some(SimBank { slots, row_bytes, resident: Vec::new(), stats: Default::default() });
        self
    }

    /// Attach the shared-prefix cache model: `capacity` cached
    /// (adapter, leading `prefix_len` prompt tokens) entries, LRU.
    pub fn with_prefix_cache(mut self, capacity: usize, prefix_len: usize) -> SchedSim {
        self.prefix = Some(SimPrefixCache {
            capacity,
            prefix_len,
            entries: Vec::new(),
            stats: Default::default(),
        });
        self
    }

    /// Paging counters of the bank model (zeros when no bank is attached).
    pub fn bank_stats(&self) -> SimBankStats {
        self.bank.as_ref().map(|b| b.stats).unwrap_or_default()
    }

    /// Hit counters of the prefix-cache model (zeros when none attached).
    pub fn prefix_stats(&self) -> SimPrefixStats {
        self.prefix.as_ref().map(|p| p.stats).unwrap_or_default()
    }

    pub fn policy_kind(&self) -> PolicyKind {
        self.policy.kind()
    }

    /// All inter-token gap samples recorded so far (virtual durations,
    /// across every lane, in emission order).
    pub fn itl_samples(&self) -> &[Duration] {
        &self.itl
    }

    /// Per-gap stall samples: each gap's excess over the nominal decode
    /// cadence (`step_cost`).  Zero everywhere under
    /// [`PrefillModel::None`]; the sched study's headline contrast.
    pub fn itl_stall_samples(&self) -> &[Duration] {
        &self.itl_stall
    }

    /// Submit → first-token samples (virtual durations).
    pub fn ttft_samples(&self) -> &[Duration] {
        &self.ttft
    }

    /// Enqueue a request (id engine-issued, submit time stamped from the
    /// virtual clock) — the same typed backpressure as `Engine::submit`.
    pub fn submit(&mut self, mut req: Request) -> std::result::Result<u64, EngineError> {
        req.id = self.next_id;
        self.next_id += 1;
        if req.submitted_at.is_none() {
            req.submitted_at = Some(self.clock.now());
        }
        let id = req.id;
        self.queue.push(req)?;
        Ok(id)
    }

    /// Cancel wherever the request lives; `false` when the id is unknown
    /// or already terminal (races resolve as no-ops, like the engine).
    pub fn cancel(&mut self, id: u64) -> bool {
        let now = self.clock.now();
        if let Some(req) = self.queue.cancel(id) {
            self.push_record(&req, None, now, SimOutcome::Cancelled);
            return true;
        }
        let Some(s) = self
            .slots
            .iter()
            .position(|l| l.as_ref().is_some_and(|l| l.req.id == id))
        else {
            return false;
        };
        let Some(lane) = self.slots[s].take() else { return false };
        self.push_record(
            &lane.req,
            Some((lane.admitted_at, lane.admitted_seq)),
            now,
            SimOutcome::Cancelled,
        );
        true
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.n_active() > 0 || !self.queue.is_empty()
    }

    /// Terminal records, in completion order.  Every submitted request
    /// lands here exactly once (finished, shed, or cancelled) — the
    /// conservation law the proptests pin down.
    pub fn records(&self) -> &[SimRecord] {
        &self.records
    }

    /// `admitted` is the lane's `(admitted_at, admitted_seq)` pair, or
    /// `None` for requests that never left the queue.
    fn push_record(
        &mut self,
        req: &Request,
        admitted: Option<(Instant, usize)>,
        finished_at: Instant,
        outcome: SimOutcome,
    ) {
        self.records.push(SimRecord {
            id: req.id,
            adapter: req.adapter.clone(),
            priority: req.priority,
            deadline: req.deadline,
            submitted_at: req.submitted_at.unwrap_or(finished_at),
            admitted_at: admitted.map(|(at, _)| at),
            admitted_seq: admitted.map(|(_, seq)| seq),
            finished_at,
            outcome,
        });
    }

    /// One scheduler iteration on the virtual clock (see the type docs).
    pub fn step(&mut self) {
        let now = self.clock.now();

        // Deadline enforcement first, exactly like `Engine::step`: shed
        // expired queued work, then reap expired lanes.
        let shed = self.queue.shed_expired(now);
        for req in shed {
            self.push_record(&req, None, now, SimOutcome::DeadlineShed);
        }
        for s in 0..self.slots.len() {
            if self.slots[s].as_ref().is_some_and(|l| l.req.expired(now)) {
                let Some(lane) = self.slots[s].take() else { continue };
                self.push_record(
                    &lane.req,
                    Some((lane.admitted_at, lane.admitted_seq)),
                    now,
                    SimOutcome::DeadlineShed,
                );
            }
        }

        // Admission: policy ranking over the queue, free lanes only.
        let n_free = self.slots.iter().filter(|s| s.is_none()).count();
        if n_free > 0 && !self.queue.is_empty() {
            let mut in_flight: BTreeMap<String, usize> = BTreeMap::new();
            for lane in self.slots.iter().flatten() {
                *in_flight.entry(lane.req.adapter.clone().unwrap_or_default()).or_insert(0) += 1;
            }
            let order = {
                let ctx = SchedContext { now, in_flight: &in_flight, admitted: &self.admitted };
                self.policy.order(&self.queue, &ctx)
            };
            // The admit predicate is the engine's paging hook: a request
            // whose adapter cannot be paged into the bank model stays
            // queued.  `pins` starts as the active-lane pin set and grows
            // with same-step admissions so one pop cannot evict an adapter
            // it just paged in.
            let bank = &mut self.bank;
            let max_prompt_len = self.max_prompt_len;
            let mut pins = in_flight.clone();
            let take = self.queue.pop_scheduled(&order, n_free, max_prompt_len, |r| {
                let resident = match (bank.as_mut(), r.adapter.as_deref()) {
                    (Some(b), Some(a)) => b.admit(a, &pins),
                    _ => true,
                };
                if resident {
                    if let Some(a) = &r.adapter {
                        *pins.entry(a.clone()).or_insert(0) += 1;
                    }
                }
                resident
            });
            if let Some(p) = &mut self.prefix {
                for req in &take {
                    p.on_admit(req.adapter.as_deref().unwrap_or(""), &req.prompt);
                }
            }
            // `pop_scheduled` hands back at most `n_free` requests, so
            // zipping against the free lanes can never drop one.
            let free: Vec<usize> =
                (0..self.slots.len()).filter(|&s| self.slots[s].is_none()).collect();
            debug_assert!(take.len() <= free.len(), "admitted more than the free lanes");
            for (req, &s) in take.into_iter().zip(free.iter()) {
                *self
                    .admitted
                    .entry(req.adapter.clone().unwrap_or_default())
                    .or_insert(0) += 1;
                let admitted_seq = self.admissions;
                self.admissions += 1;
                // Under the legacy free-prefill model a lane admits fully
                // fed; the costed models start at 0 and feed per-step.
                let fed = match self.prefill {
                    PrefillModel::None => req.prompt.len(),
                    _ => 0,
                };
                self.slots[s] = Some(SimLane {
                    req,
                    admitted_at: now,
                    admitted_seq,
                    generated: 0,
                    fed,
                    last_token_at: None,
                });
            }
        }

        // Decode + prefill feeding: every occupied lane advances one token
        // — generating lanes decode, feeding lanes push one prompt token
        // through the decode batch (the engine's decode-fed token, which
        // guarantees progress even with a zero chunk budget).  Atomic
        // prefill instead feeds a lane's whole remaining prompt in one go,
        // stretching this step for everyone.
        let n_active = self.slots.iter().filter(|s| s.is_some()).count();
        let mut prefill_tokens = 0usize;
        // Slots that emitted a token this step; stamped once the step's
        // virtual length (which depends on the prefill work) is known.
        let mut emitted: Vec<usize> = Vec::new();
        for s in 0..self.slots.len() {
            let Some(lane) = self.slots[s].as_mut() else { continue };
            let plen = lane.req.prompt.len();
            if lane.fed >= plen {
                lane.generated += 1;
                emitted.push(s);
                continue;
            }
            match self.prefill {
                // `None` admits lanes fully fed, so only `Atomic` reaches
                // this arm in practice; feeding the whole prompt keeps the
                // arm total either way.
                PrefillModel::None | PrefillModel::Atomic { .. } => {
                    prefill_tokens += plen - lane.fed;
                    lane.fed = plen;
                    lane.generated += 1; // prefill samples the first token
                    emitted.push(s);
                }
                PrefillModel::Chunked { .. } => {
                    lane.fed += 1;
                    if lane.fed >= plen {
                        lane.generated += 1; // last decode-fed token samples
                        emitted.push(s);
                    }
                }
            }
        }
        // Chunked: spend the leftover budget on feeding lanes, earliest
        // admission first — admission order is the policy's own ranking,
        // so the chunk budget follows the policy too.
        if let PrefillModel::Chunked { budget, .. } = self.prefill {
            let mut left = budget.saturating_sub(n_active);
            let mut feeding: Vec<usize> = (0..self.slots.len())
                .filter(|&s| {
                    self.slots[s].as_ref().is_some_and(|l| l.fed < l.req.prompt.len())
                })
                .collect();
            feeding.sort_by_key(|&s| self.slots[s].as_ref().map(|l| l.admitted_seq));
            for s in feeding {
                if left == 0 {
                    break;
                }
                let Some(lane) = self.slots[s].as_mut() else { continue };
                let n = (lane.req.prompt.len() - lane.fed).min(left);
                lane.fed += n;
                left -= n;
                prefill_tokens += n;
                if lane.fed >= lane.req.prompt.len() {
                    lane.generated += 1; // the completing chunk samples
                    emitted.push(s);
                }
            }
        }
        // Tokens land at the end of the step; the step stretches by the
        // prefill work it carried (zero under `None`, so the virtual
        // timeline of the legacy harness is bit-preserved).
        let token_cost = match self.prefill {
            PrefillModel::None => Duration::ZERO,
            PrefillModel::Atomic { token_cost } => token_cost,
            PrefillModel::Chunked { token_cost, .. } => token_cost,
        };
        let step_len = self.step_cost + token_cost * prefill_tokens as u32;
        let step_end = now + step_len;
        for s in emitted {
            let Some(lane) = self.slots[s].as_mut() else { continue };
            match lane.last_token_at {
                Some(prev) => {
                    let gap = step_end.saturating_duration_since(prev);
                    self.itl.push(gap);
                    self.itl_stall.push(gap.saturating_sub(self.step_cost));
                }
                None => {
                    let sub = lane.req.submitted_at.unwrap_or(lane.admitted_at);
                    self.ttft.push(step_end.saturating_duration_since(sub));
                }
            }
            lane.last_token_at = Some(step_end);
        }
        // Reap finished lanes (recorded at the step-start instant, as the
        // pre-chunking harness always has).
        for s in 0..self.slots.len() {
            let done = self.slots[s]
                .as_ref()
                .is_some_and(|l| l.generated >= l.req.max_new_tokens);
            if done {
                let Some(lane) = self.slots[s].take() else { continue };
                self.push_record(
                    &lane.req,
                    Some((lane.admitted_at, lane.admitted_seq)),
                    now,
                    SimOutcome::Finished,
                );
            }
        }

        self.clock.advance(step_len);
    }

    /// Step until idle; returns the number of steps taken (capped at
    /// `max_steps`, the runaway guard for tests).
    pub fn run_until_idle(&mut self, max_steps: usize) -> usize {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step();
            steps += 1;
        }
        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_of<'a>(
        now: Instant,
        in_flight: &'a BTreeMap<String, usize>,
        admitted: &'a BTreeMap<String, usize>,
    ) -> SchedContext<'a> {
        SchedContext { now, in_flight, admitted }
    }

    fn queue_of(reqs: Vec<Request>) -> AdmissionQueue {
        let mut q = AdmissionQueue::new(64);
        for (i, mut r) in reqs.into_iter().enumerate() {
            r.id = i as u64 + 1;
            if r.submitted_at.is_none() {
                r.submitted_at = Some(Instant::now());
            }
            q.push(r).unwrap();
        }
        q
    }

    #[test]
    fn policy_names_round_trip() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::from_name(kind.name()).unwrap(), kind);
            assert_eq!(make_policy(kind).kind(), kind);
        }
        assert_eq!(PolicyKind::from_name("fair-share").unwrap(), PolicyKind::FairShare);
        assert!(PolicyKind::from_name("lifo").is_err());
    }

    #[test]
    fn fcfs_is_the_identity_ranking() {
        let q = queue_of(vec![
            Request::new(vec![1; 4], 2),
            Request::new(vec![1; 8], 2).with_priority(9),
            Request::new(vec![1; 2], 2).with_deadline(Duration::from_millis(1)),
        ]);
        let (inf, adm) = (BTreeMap::new(), BTreeMap::new());
        let order = make_policy(PolicyKind::Fcfs).order(&q, &ctx_of(Instant::now(), &inf, &adm));
        assert_eq!(order, vec![0, 1, 2], "fcfs ignores priority and deadlines");
    }

    #[test]
    fn edf_ranks_by_absolute_deadline_with_deadline_free_last() {
        let t0 = Instant::now();
        let stamp = |deadline_ms: Option<u64>, submitted_off_ms: u64| {
            let mut r = Request::new(vec![1; 4], 2);
            r.submitted_at = Some(t0 + Duration::from_millis(submitted_off_ms));
            r.deadline = deadline_ms.map(Duration::from_millis);
            r
        };
        // Absolute deadlines: a=t0+50, b=none, c=t0+30 (tighter despite the
        // later submit), d=t0+50 (ties with a; FIFO breaks the tie).
        let q = queue_of(vec![
            stamp(Some(50), 0),
            stamp(None, 0),
            stamp(Some(20), 10),
            stamp(Some(50), 0),
        ]);
        let (inf, adm) = (BTreeMap::new(), BTreeMap::new());
        let order = make_policy(PolicyKind::Edf).order(&q, &ctx_of(t0, &inf, &adm));
        assert_eq!(order, vec![2, 0, 3, 1]);
    }

    #[test]
    fn priority_ranks_tiers_then_fifo() {
        let q = queue_of(vec![
            Request::new(vec![1], 2),
            Request::new(vec![1], 2).with_priority(5),
            Request::new(vec![1], 2).with_priority(5),
            Request::new(vec![1], 2).with_priority(1),
        ]);
        let (inf, adm) = (BTreeMap::new(), BTreeMap::new());
        let order =
            make_policy(PolicyKind::Priority).order(&q, &ctx_of(Instant::now(), &inf, &adm));
        assert_eq!(order, vec![1, 2, 3, 0], "tiers descend, FIFO within a tier");
    }

    #[test]
    fn fair_share_prefers_least_served_adapter() {
        let q = queue_of(vec![
            Request::new(vec![1], 2).with_adapter("hot"),
            Request::new(vec![1], 2).with_adapter("hot"),
            Request::new(vec![1], 2).with_adapter("cold"),
        ]);
        let mut inf = BTreeMap::new();
        inf.insert("hot".to_string(), 2usize);
        let mut adm = BTreeMap::new();
        adm.insert("hot".to_string(), 10usize);
        adm.insert("cold".to_string(), 1usize);
        let order =
            make_policy(PolicyKind::FairShare).order(&q, &ctx_of(Instant::now(), &inf, &adm));
        assert_eq!(order, vec![2, 0, 1], "cold adapter outranks the flood");
    }

    #[test]
    fn sim_conserves_and_finishes_simple_workload() {
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 2, 16, Duration::from_millis(5));
        let mut ids = Vec::new();
        for _ in 0..5 {
            ids.push(sim.submit(Request::new(vec![1; 4], 3)).unwrap());
        }
        assert_eq!(ids, vec![1, 2, 3, 4, 5], "ids are issue-ordered");
        let steps = sim.run_until_idle(64);
        assert!(steps > 0 && !sim.has_work());
        assert_eq!(sim.records().len(), 5);
        assert!(sim.records().iter().all(|r| r.outcome == SimOutcome::Finished));
        // 2 lanes x 3 tokens per request: the first pair waits 0, the rest
        // wait for a lane; queue waits are exact virtual durations.
        let w0 = sim.records()[0].queue_wait().unwrap();
        assert_eq!(w0, Duration::ZERO);
        assert_eq!(sim.records()[0].admitted_seq, Some(0), "first admission has ordinal 0");
        assert!(sim.records().iter().any(|r| r.queue_wait().unwrap() > Duration::ZERO));
    }

    #[test]
    fn bank_model_counts_hits_misses_and_evictions_lru() {
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 1, 16, Duration::from_millis(5))
            .with_bank(2, 100);
        // One lane serializes admissions: a, b, a (hit), c (evicts LRU=b),
        // b (miss again).
        for name in ["a", "b", "a", "c", "b"] {
            sim.submit(Request::new(vec![1; 4], 1).with_adapter(name)).unwrap();
        }
        sim.run_until_idle(64);
        assert_eq!(sim.records().len(), 5);
        let b = sim.bank_stats();
        assert_eq!(b.hits, 1, "{b:?}");
        assert_eq!(b.misses, 4, "{b:?}");
        assert_eq!(b.evictions, 2, "c evicts b, then b evicts a: {b:?}");
        assert_eq!(b.upload_bytes, 400, "one row per miss: {b:?}");
    }

    #[test]
    fn bank_model_pins_active_adapters_and_defers_when_full() {
        // 2 lanes, 1 bank slot: while adapter "a" holds a lane, "b" cannot
        // page in (the only slot is pinned) and must wait for a to finish.
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 2, 16, Duration::from_millis(5))
            .with_bank(1, 64);
        sim.submit(Request::new(vec![1; 4], 4).with_adapter("a")).unwrap();
        sim.submit(Request::new(vec![1; 4], 1).with_adapter("b")).unwrap();
        sim.step();
        assert_eq!(sim.n_active(), 1, "b is deferred while a pins the slot");
        sim.run_until_idle(64);
        assert_eq!(sim.records().len(), 2);
        assert!(sim.records().iter().all(|r| r.outcome == SimOutcome::Finished));
        let (a_rec, b_rec) = (&sim.records()[0], &sim.records()[1]);
        assert_eq!(a_rec.adapter.as_deref(), Some("a"));
        assert_eq!(b_rec.adapter.as_deref(), Some("b"));
        assert!(b_rec.queue_wait().unwrap() > Duration::ZERO, "b waited for the pinned slot");
    }

    #[test]
    fn prefill_none_records_zero_stall_and_exact_cadence() {
        let step = Duration::from_millis(5);
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 2, 16, step);
        for _ in 0..3 {
            sim.submit(Request::new(vec![1; 8], 4)).unwrap();
        }
        sim.run_until_idle(64);
        assert_eq!(sim.records().len(), 3);
        assert!(!sim.itl_samples().is_empty());
        assert!(sim.itl_samples().iter().all(|&g| g == step), "free prefill: pure cadence");
        assert!(sim.itl_stall_samples().iter().all(|&g| g == Duration::ZERO));
    }

    #[test]
    fn chunked_prefill_bounds_the_stall_atomic_does_not() {
        let step = Duration::from_millis(5);
        let tok = Duration::from_micros(625);
        let budget = 16usize;
        let run = |model: PrefillModel| {
            let mut sim =
                SchedSim::new(PolicyKind::Fcfs, 2, 16, step).with_prefill(model);
            // A short request holds a decode lane...
            sim.submit(Request::new(vec![1; 4], 24)).unwrap();
            sim.step();
            sim.step();
            // ...then a maximum-length prompt lands in the second lane.
            sim.submit(Request::new(vec![2; 64], 4)).unwrap();
            sim.run_until_idle(256);
            assert_eq!(sim.records().len(), 2);
            assert!(sim.records().iter().all(|r| r.outcome == SimOutcome::Finished));
            sim.itl_stall_samples().iter().copied().max().unwrap_or(Duration::ZERO)
        };
        let atomic = run(PrefillModel::Atomic { token_cost: tok });
        let chunked = run(PrefillModel::Chunked { budget, token_cost: tok });
        // Atomic: the admission step stretches by the whole 64-token
        // prompt; chunked steps never carry more than the budget.
        assert_eq!(atomic, tok * 64, "atomic stall is the full prompt");
        assert!(chunked <= tok * budget as u32, "chunked stall bounded by the budget: {chunked:?}");
        assert!(chunked < atomic);
    }

    #[test]
    fn chunked_prefill_progresses_on_decode_fed_tokens_even_with_zero_budget() {
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 1, 16, Duration::from_millis(5))
            .with_prefill(PrefillModel::Chunked {
                budget: 0,
                token_cost: Duration::from_micros(625),
            });
        sim.submit(Request::new(vec![1; 8], 2)).unwrap();
        let steps = sim.run_until_idle(64);
        assert_eq!(sim.records().len(), 1, "decode-fed token defeats the zero-budget livelock");
        assert_eq!(sim.records()[0].outcome, SimOutcome::Finished);
        // 7 decode-fed prompt steps + the completing feed (first token) +
        // 1 decode step for the second token.
        assert_eq!(steps, 9);
    }

    #[test]
    fn prefix_cache_model_hits_on_repeated_adapter_prefix() {
        let mut sim = SchedSim::new(PolicyKind::Fcfs, 1, 16, Duration::from_millis(5))
            .with_prefix_cache(4, 3);
        let prompt = vec![7, 8, 9, 1];
        for _ in 0..3 {
            sim.submit(Request::new(prompt.clone(), 1).with_adapter("a")).unwrap();
        }
        // Same leading tokens, different adapter: its own cache key.
        sim.submit(Request::new(prompt.clone(), 1).with_adapter("b")).unwrap();
        sim.run_until_idle(64);
        let p = sim.prefix_stats();
        assert_eq!(p.hits, 2, "{p:?}");
        assert_eq!(p.misses, 2, "adapter-salted keys: {p:?}");
    }
}
