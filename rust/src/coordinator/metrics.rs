//! Serving metrics: throughput, TTFT, per-token and end-to-end latency,
//! queueing delay/depth, step-time accounting split by phase, KV-cache
//! transfer counters, and adapter-bank paging counters
//! (hits/misses/evictions and host-to-device upload bytes).
//!
//! Latency clocks start at `Engine::submit` (the request's
//! `submitted_at` stamp), so TTFT and e2e include time spent waiting in
//! the admission queue — what a client actually observes — not just
//! compute after admission.

use std::time::{Duration, Instant};

use crate::util::stats::{LatencyRecorder, Summary};
use crate::util::table::kv_table;

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    pub prefill_batches: usize,
    pub decode_steps: usize,
    /// Submit → first generated token (queue wait included).
    pub ttft: LatencyRecorder,
    /// Submit → request finished (queue wait included).
    pub e2e: LatencyRecorder,
    /// Submit → admission into a prefill batch (the queueing component of
    /// ttft/e2e, recorded separately so saturation is visible).
    pub queue_wait: LatencyRecorder,
    /// Admission-queue depth sampled at each scheduler step (a depth
    /// histogram, not a latency — samples are request counts).
    pub queue_depth: LatencyRecorder,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// Full K/V cache device→host transfers.  Device-resident decode:
    /// admission-time materializations only (tracks prefill batches, not
    /// decode steps).  `kv_host_roundtrip` baseline: one per decode step.
    pub kv_host_syncs: usize,
    /// Full K/V cache host→device transfers (mirror of `kv_host_syncs`:
    /// re-uploads after materialization, or per-step in baseline mode).
    pub kv_uploads: usize,
    /// Admissions whose adapter was already device-resident.
    pub bank_hits: usize,
    /// Admissions that had to page their adapter into a bank slot.
    pub bank_misses: usize,
    /// Page-ins that displaced another resident adapter (LRU victim).
    pub bank_evictions: usize,
    /// Host→device bytes attributed to adapter-bank content (per-slot rows
    /// on the paged path, full tensors on the whole-bank baseline).
    pub bank_upload_bytes: usize,
    /// Whole-bank uploads (first upload, or every change in baseline mode).
    pub bank_full_uploads: usize,
    /// Per-slot row tensors staged on the paged upload path.
    pub bank_staged_rows: usize,
    /// Submit → admission for requests that suffered a bank miss (the
    /// queue-wait cost of paging, recorded separately from `queue_wait`).
    pub paged_wait: LatencyRecorder,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => (f - s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of wall time — Figure 4's y-axis.
    pub fn throughput(&self) -> f64 {
        let w = self.wall();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttft.summary()
    }

    pub fn e2e_summary(&self) -> Summary {
        self.e2e.summary()
    }

    pub fn queue_wait_summary(&self) -> Summary {
        self.queue_wait.summary()
    }

    pub fn queue_depth_summary(&self) -> Summary {
        self.queue_depth.summary()
    }

    pub fn paged_wait_summary(&self) -> Summary {
        self.paged_wait.summary()
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        let qw = self.queue_wait_summary();
        let qd = self.queue_depth_summary();
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s \
             prefill_batches={} decode_steps={} \
             ttft(p50/p90)={:.1}/{:.1}ms e2e(p50/p90)={:.1}/{:.1}ms \
             queue_wait(p50/p90)={:.1}/{:.1}ms queue_depth(p50/max)={:.0}/{:.0} \
             prefill={:.2}s decode={:.2}s kv_dl/ul={}/{} \
             bank(h/m/e)={}/{}/{} bank_upload={}B",
            self.requests_completed,
            self.tokens_generated,
            self.wall(),
            self.throughput(),
            self.prefill_batches,
            self.decode_steps,
            t.p50 / 1e3,
            t.p90 / 1e3,
            e.p50 / 1e3,
            e.p90 / 1e3,
            qw.p50 / 1e3,
            qw.p90 / 1e3,
            qd.p50,
            qd.max,
            self.prefill_time.as_secs_f64(),
            self.decode_time.as_secs_f64(),
            self.kv_host_syncs,
            self.kv_uploads,
            self.bank_hits,
            self.bank_misses,
            self.bank_evictions,
            self.bank_upload_bytes,
        )
    }

    /// Full serving report as a two-column markdown table (`road serve
    /// --stats`), including the bank paging counters the one-line
    /// [`Metrics::report`] summarizes.
    pub fn report_table(&self) -> String {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        let qw = self.queue_wait_summary();
        let pw = self.paged_wait_summary();
        let qd = self.queue_depth_summary();
        kv_table(&[
            ("requests completed", self.requests_completed.to_string()),
            ("tokens generated", self.tokens_generated.to_string()),
            ("throughput (tok/s)", format!("{:.1}", self.throughput())),
            ("prefill batches", self.prefill_batches.to_string()),
            ("decode steps", self.decode_steps.to_string()),
            ("ttft p50/p90 (ms)", format!("{:.1} / {:.1}", t.p50 / 1e3, t.p90 / 1e3)),
            ("e2e p50/p90 (ms)", format!("{:.1} / {:.1}", e.p50 / 1e3, e.p90 / 1e3)),
            ("queue wait p50/p90 (ms)", format!("{:.1} / {:.1}", qw.p50 / 1e3, qw.p90 / 1e3)),
            (
                "paged-adapter wait p50/p90 (ms)",
                format!("{:.1} / {:.1}", pw.p50 / 1e3, pw.p90 / 1e3),
            ),
            ("queue depth p50/max", format!("{:.0} / {:.0}", qd.p50, qd.max)),
            ("kv downloads/uploads", format!("{} / {}", self.kv_host_syncs, self.kv_uploads)),
            ("bank hits", self.bank_hits.to_string()),
            ("bank misses", self.bank_misses.to_string()),
            ("bank evictions", self.bank_evictions.to_string()),
            ("bank upload bytes", self.bank_upload_bytes.to_string()),
            ("bank full uploads", self.bank_full_uploads.to_string()),
            ("bank staged rows", self.bank_staged_rows.to_string()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_includes_queue_and_kv_fields() {
        let mut m = Metrics::default();
        m.queue_wait.record(Duration::from_millis(4));
        m.queue_depth.record_value(3.0);
        m.queue_depth.record_value(7.0);
        m.kv_host_syncs = 2;
        m.kv_uploads = 2;
        let r = m.report();
        assert!(r.contains("queue_wait"), "{r}");
        assert!(r.contains("queue_depth(p50/max)"), "{r}");
        assert!(r.contains("kv_dl/ul=2/2"), "{r}");
        assert!((m.queue_wait_summary().p50 - 4000.0).abs() < 1e-6);
        assert_eq!(m.queue_depth_summary().max, 7.0);
    }

    #[test]
    fn report_includes_bank_paging_counters() {
        let mut m = Metrics::default();
        m.paged_wait.record(Duration::from_millis(8));
        m.bank_hits = 10;
        m.bank_misses = 3;
        m.bank_evictions = 2;
        m.bank_upload_bytes = 4096;
        let r = m.report();
        assert!(r.contains("bank(h/m/e)=10/3/2"), "{r}");
        assert!(r.contains("bank_upload=4096B"), "{r}");
        let t = m.report_table();
        let needles = [
            "bank hits",
            "bank misses",
            "bank evictions",
            "bank upload bytes",
            "10",
            "4096",
            "paged-adapter wait",
        ];
        for needle in needles {
            assert!(t.contains(needle), "missing {needle:?} in\n{t}");
        }
    }
}
