//! KV-cache state and decode-slot allocation.
//!
//! XLA executables are shape-specialized, so the decode step runs at a
//! fixed slot count B; continuous batching assigns requests to free slot
//! lanes (each lane tracks its own sequence position — the per-slot `pos`
//! vector of the decode entry point).  The cache layout matches the HLO
//! signature: [n_layers, B, n_heads, max_seq, head_dim], f32.
//!
//! # Residency
//!
//! [`KvState`] is a two-residency cache: exactly one of the host tensors or
//! the device buffers is authoritative at any time.
//!
//! * **Device** is the steady state of the decode loop: step `t`'s output
//!   buffers are installed via [`KvState::install_device`] and fed straight
//!   back in at step `t+1` ([`KvState::device_pair`]) with no host copy.
//! * **Host** is the escape hatch: [`KvState::materialize_host`] downloads
//!   the cache for operations PJRT has no artifact for — prefill lane
//!   adoption ([`KvState::adopt_prefill_lane`]), slot clearing, tests, and
//!   golden-record comparison.  Prefill admission therefore costs one full
//!   cache round-trip *per admitted batch*; the per-step decode transfers
//!   stay O(B·vocab) (logits only).
//!
//! # Paging
//!
//! [`PagedKv`] layers block-granular accounting and a shared-prefix content
//! cache (copy-on-write, refcounted, LRU-evicted) over the contiguous
//! layout; see its docs for the admission/publish/release protocol.  The
//! flat contiguous behaviour survives as the measurable `paged_kv = false`
//! baseline, where every lane charges a full `max_seq` worth of blocks.

use anyhow::{bail, Result};

use crate::coordinator::pool::BlockPool;
use crate::manifest::ModelConfigInfo;
use crate::runtime::reference::{gather_cache_block, scatter_cache_block};
use crate::runtime::{buffer_to_host, upload};
use crate::tensor::{DType, HostTensor};

/// Free-list slot allocator with double-free protection.
#[derive(Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl SlotAllocator {
    pub fn new(n: usize) -> SlotAllocator {
        SlotAllocator { free: (0..n).rev().collect(), in_use: vec![false; n] }
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        self.in_use[s] = true;
        Some(s)
    }

    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.in_use.len() {
            bail!("slot {slot} out of range");
        }
        if !self.in_use[slot] {
            bail!("double free of slot {slot}");
        }
        self.in_use[slot] = false;
        self.free.push(slot);
        Ok(())
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_total(&self) -> usize {
        self.in_use.len()
    }

    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use.get(slot).copied().unwrap_or(false)
    }
}

/// Which side of the host/device boundary currently owns the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    Host,
    Device,
}

/// K/V caches for all decode slots (see module docs for the residency
/// model).
pub struct KvState {
    /// Host-side tensors; authoritative only when `residency == Host`.
    hk: HostTensor,
    hv: HostTensor,
    /// Device-side buffers; `Some` exactly when `residency == Device`.
    dev: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    residency: Residency,
    pub n_layers: usize,
    pub n_slots: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvState {
    pub fn new(cfg: &ModelConfigInfo, n_slots: usize) -> KvState {
        let shape = vec![cfg.n_layers, n_slots, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        KvState {
            hk: HostTensor::zeros(shape.clone(), DType::F32),
            hv: HostTensor::zeros(shape, DType::F32),
            dev: None,
            residency: Residency::Host,
            n_layers: cfg.n_layers,
            n_slots,
            n_heads: cfg.n_heads,
            max_seq: cfg.max_seq,
            head_dim: cfg.head_dim,
        }
    }

    pub fn shape(&self) -> Vec<usize> {
        vec![self.n_layers, self.n_slots, self.n_heads, self.max_seq, self.head_dim]
    }

    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// Host-materialization escape hatch: download the cache if it is
    /// device-resident.  Returns `true` when a transfer actually happened.
    ///
    /// Downloads complete before any state is committed, so a failed
    /// transfer leaves the cache device-resident and retryable rather than
    /// wedged between residencies.
    pub fn materialize_host(&mut self) -> Result<bool> {
        let Some((kb, vb)) = self.dev.as_ref() else {
            return Ok(false);
        };
        let k = buffer_to_host(kb, DType::F32)?;
        let v = buffer_to_host(vb, DType::F32)?;
        let want = self.shape();
        if k.shape != want || v.shape != want {
            bail!("device cache shape {:?}/{:?}, expected {:?}", k.shape, v.shape, want);
        }
        self.dev = None;
        self.hk = k;
        self.hv = v;
        self.residency = Residency::Host;
        Ok(true)
    }

    /// Upload the cache if it is host-resident.  Returns `true` when a
    /// transfer actually happened.
    ///
    /// The host tensors are released after the upload — they are stale
    /// while device-resident, and at serve size they are the largest host
    /// allocation; `materialize_host` reallocates them from the download.
    pub fn ensure_device(&mut self, client: &xla::PjRtClient) -> Result<bool> {
        if self.residency == Residency::Device {
            return Ok(false);
        }
        let kb = upload(client, &self.hk)?;
        let vb = upload(client, &self.hv)?;
        self.hk = HostTensor::zeros(vec![0], DType::F32);
        self.hv = HostTensor::zeros(vec![0], DType::F32);
        self.dev = Some((kb, vb));
        self.residency = Residency::Device;
        Ok(true)
    }

    /// The device buffers to pass as the decode step's `k_cache`/`v_cache`
    /// inputs.  Call [`KvState::ensure_device`] first.
    pub fn device_pair(&self) -> Result<(&xla::PjRtBuffer, &xla::PjRtBuffer)> {
        match &self.dev {
            Some((k, v)) => Ok((k, v)),
            None => bail!("KV cache is host-resident; call ensure_device first"),
        }
    }

    /// Install a decode step's output buffers as the new cache (the
    /// zero-copy hand-off that keeps the loop device-resident).
    pub fn install_device(&mut self, k: xla::PjRtBuffer, v: xla::PjRtBuffer) -> Result<()> {
        let want: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        if k.dims() != want || v.dims() != want {
            bail!(
                "decode returned cache dims {:?}/{:?}, expected {:?}",
                k.dims(),
                v.dims(),
                want
            );
        }
        self.dev = Some((k, v));
        self.residency = Residency::Device;
        Ok(())
    }

    /// Host view of the K cache (host residency required).
    pub fn host_k(&self) -> Result<&HostTensor> {
        match self.residency {
            Residency::Host => Ok(&self.hk),
            Residency::Device => bail!("KV cache is device-resident; materialize_host first"),
        }
    }

    /// Host view of the V cache (host residency required).
    pub fn host_v(&self) -> Result<&HostTensor> {
        match self.residency {
            Residency::Host => Ok(&self.hv),
            Residency::Device => bail!("KV cache is device-resident; materialize_host first"),
        }
    }

    /// Flat element offset of [layer, slot, head, 0, 0].
    fn lane_offset(&self, layer: usize, slot: usize, head: usize) -> usize {
        ((layer * self.n_slots + slot) * self.n_heads + head) * self.max_seq * self.head_dim
    }

    /// Copy one request's cache lane out of a prefill output
    /// ([n_layers, b_prefill, n_heads, max_seq, head_dim]) into `slot`.
    /// Materializes the cache to host if needed (the admission-time escape
    /// hatch; see module docs).
    pub fn adopt_prefill_lane(
        &mut self,
        pk: &HostTensor,
        pv: &HostTensor,
        prefill_lane: usize,
        slot: usize,
        prompt_len: usize,
    ) -> Result<()> {
        self.materialize_host()?;
        let b_pre = pk.shape[1];
        if prefill_lane >= b_pre || slot >= self.n_slots {
            bail!("lane {prefill_lane}/{b_pre} or slot {slot}/{} out of range", self.n_slots);
        }
        // Only the first prompt_len positions carry data; copying the head
        // of each [max_seq, head_dim] row bounds the memcpy to what matters.
        let row = prompt_len.min(self.max_seq) * self.head_dim;
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let src =
                    ((l * b_pre + prefill_lane) * self.n_heads + h) * self.max_seq * self.head_dim;
                let dst = self.lane_offset(l, slot, h);
                let kd = pk.read_f32_range(src, row);
                self.hk.write_f32_range(dst, &kd);
                let vd = pv.read_f32_range(src, row);
                self.hv.write_f32_range(dst, &vd);
            }
        }
        Ok(())
    }

    /// Replace both caches with host tensors (the host-round-trip baseline
    /// path; the device-resident loop uses [`KvState::install_device`]).
    pub fn replace(&mut self, k: HostTensor, v: HostTensor) -> Result<()> {
        let want = self.shape();
        if k.shape != want || v.shape != want {
            bail!("kv shape changed: {:?} vs {:?}", k.shape, want);
        }
        self.hk = k;
        self.hv = v;
        self.dev = None;
        self.residency = Residency::Host;
        Ok(())
    }

    /// Zero a slot's lanes (hygiene on release; correctness does not depend
    /// on it because prefill overwrites and masks exclude stale positions).
    pub fn clear_slot(&mut self, slot: usize) -> Result<()> {
        self.materialize_host()?;
        let zeros = vec![0f32; self.max_seq * self.head_dim];
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let off = self.lane_offset(l, slot, h);
                self.hk.write_f32_range(off, &zeros);
                self.hv.write_f32_range(off, &zeros);
            }
        }
        Ok(())
    }

    /// Read `n_tokens` contiguous cache positions of `slot` starting at
    /// `start`, as flat `[n_layers, n_heads, n_tokens, head_dim]` K and V
    /// buffers (the payload format of a shared-prefix block).
    /// Materializes the cache to host if needed.
    pub fn read_block(
        &mut self,
        slot: usize,
        start: usize,
        n_tokens: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.materialize_host()?;
        let k = gather_cache_block(&self.hk, slot, start, n_tokens)?;
        let v = gather_cache_block(&self.hv, slot, start, n_tokens)?;
        Ok((k, v))
    }

    /// Scatter block payloads produced by [`KvState::read_block`] into
    /// `slot` at position `start` (the shared-prefix adoption path).
    /// Materializes the cache to host if needed.
    pub fn write_block(
        &mut self,
        slot: usize,
        start: usize,
        n_tokens: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        self.materialize_host()?;
        scatter_cache_block(&mut self.hk, slot, start, n_tokens, k)?;
        scatter_cache_block(&mut self.hv, slot, start, n_tokens, v)?;
        Ok(())
    }
}

/// Chained FNV-1a-64 keys for each *full* `block_size`-token block of a
/// prompt, salted by the adapter name (K/V contents depend on the adapter's
/// rotation epilogue, so the same tokens under different adapters must never
/// share cache blocks).  `keys[j]` commits to the adapter and to
/// `prompt[..(j + 1) * block_size]`, so equal keys mean equal prefixes.
pub fn prefix_block_keys(adapter: Option<&str>, prompt: &[i32], block_size: usize) -> Vec<u64> {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let bs = block_size.max(1);
    let mut h = FNV_OFFSET;
    for b in adapter.unwrap_or("").bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    // Separator so adapter "a" + token bytes can't collide with adapter "".
    h = (h ^ 0xff).wrapping_mul(FNV_PRIME);
    let mut keys = Vec::with_capacity(prompt.len() / bs);
    for (i, &tok) in prompt.iter().enumerate() {
        for byte in (tok as u32).to_le_bytes() {
            h = (h ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
        }
        if (i + 1) % bs == 0 {
            keys.push(h);
        }
    }
    keys
}

/// The blocks backing one decode slot: `shared` holds refcounted
/// cached-prefix blocks (read-only by construction — their content was
/// *copied* into the lane's contiguous region at admission), `private`
/// holds this lane's exclusively-owned blocks.
#[derive(Clone, Debug, Default)]
pub struct LaneBlocks {
    shared: Vec<usize>,
    private: Vec<usize>,
    /// Cache positions covered by the shared prefix (`hit_blocks * block_size`).
    hit_tokens: usize,
    /// Chained prefix keys for this lane's prompt, one per full block.
    keys: Vec<u64>,
}

/// A successful admission-time block reservation, to be either bound to a
/// slot ([`PagedKv::bind_lane`]) or rolled back
/// ([`PagedKv::cancel_reservation`]) if a later admission gate stalls.
#[derive(Debug)]
pub struct KvReservation {
    shared: Vec<usize>,
    private: Vec<usize>,
    keys: Vec<u64>,
    /// Leading full prompt blocks served from the shared-prefix cache.
    pub hit_blocks: usize,
    /// Cached blocks evicted (LRU) to satisfy the private allocations.
    pub evictions: usize,
}

impl KvReservation {
    pub fn n_blocks(&self) -> usize {
        self.shared.len() + self.private.len()
    }
}

/// Bookkeeping results of releasing a lane (metrics fodder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvRelease {
    pub private_freed: usize,
    pub shared_unrefs: usize,
}

/// Block-granular KV accounting and shared-prefix content cache layered
/// over the contiguous [`KvState`] layout.
///
/// XLA executables are shape-specialized, so the *staging* layout stays the
/// contiguous `[n_layers, B, n_heads, max_seq, head_dim]` cache; what pages
/// is the *accounting* (admission is gated on block availability instead of
/// whole `max_seq` lanes) and the *content* of shared prompt prefixes:
///
/// * On admission, [`PagedKv::try_reserve`] keys the prompt's full blocks
///   ([`prefix_block_keys`]), takes refcounts on the longest cached prefix
///   run, and allocates private blocks for the rest of the footprint
///   (`ceil(min(prompt + max_new, max_seq) / block_size)` in paged mode,
///   the full `ceil(max_seq / block_size)` in flat mode) — all-or-nothing,
///   with rollback.
/// * A hit lane *copies* the cached payloads into its contiguous region
///   ([`PagedKv::adopt_shared_prefix`]) — copy-on-write by construction:
///   there is no write path to a cached block, so writers can never alias a
///   shared block.
/// * After a cold prefill, [`PagedKv::publish_prefix`] promotes the lane's
///   leading private blocks to cached entries (refs = 1 while the lane
///   lives) and snapshots their payloads.
/// * [`PagedKv::release_lane`] returns every block exactly once: private
///   blocks to the free list, shared blocks via unref.  Unreferenced cached
///   blocks stay resident and are reclaimed LRU-first under pressure by the
///   next reservation ([`crate::coordinator::pool::BlockPool`] semantics).
pub struct PagedKv {
    pool: BlockPool,
    lanes: Vec<Option<LaneBlocks>>,
    /// Snapshotted payloads of published blocks, `[n_layers, n_heads,
    /// block_size, head_dim]` flat (indexed by block id).
    data_k: Vec<Vec<f32>>,
    data_v: Vec<Vec<f32>>,
    paged: bool,
    max_seq: usize,
}

fn ceil_div(a: usize, b: usize) -> usize {
    let b = b.max(1);
    (a + b - 1) / b
}

impl PagedKv {
    pub fn new(
        n_slots: usize,
        max_seq: usize,
        block_size: usize,
        pool_blocks: usize,
        paged: bool,
    ) -> PagedKv {
        PagedKv {
            pool: BlockPool::new(pool_blocks, block_size),
            lanes: (0..n_slots).map(|_| None).collect(),
            data_k: vec![Vec::new(); pool_blocks],
            data_v: vec![Vec::new(); pool_blocks],
            paged,
            max_seq,
        }
    }

    pub fn paged(&self) -> bool {
        self.paged
    }

    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// Pool-level stats (free/private/cached/refcounts) for metrics gauges.
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn is_bound(&self, slot: usize) -> bool {
        self.lanes.get(slot).map(|l| l.is_some()).unwrap_or(false)
    }

    /// Blocks one admission would occupy: the full generation footprint in
    /// paged mode, a whole `max_seq` lane in flat mode.
    pub fn footprint_blocks(&self, prompt_len: usize, max_new: usize) -> usize {
        let bs = self.pool.block_size();
        if self.paged {
            ceil_div((prompt_len + max_new).min(self.max_seq), bs).max(1)
        } else {
            ceil_div(self.max_seq, bs).max(1)
        }
    }

    /// Try to reserve the blocks for one request: refcount the longest
    /// cached prefix run (paged mode only), then allocate private blocks
    /// for the remainder of the footprint.  Returns `None` — with full
    /// rollback — when the pool cannot cover it; the request stays queued.
    ///
    /// The hit run is capped at `floor((prompt_len - 1) / block_size)` so at
    /// least one prompt token always remains to be fed through the model
    /// (first-token logits are computed, never cached).
    pub fn try_reserve(
        &mut self,
        adapter: Option<&str>,
        prompt: &[i32],
        max_new: usize,
    ) -> Option<KvReservation> {
        let bs = self.pool.block_size();
        let footprint = self.footprint_blocks(prompt.len(), max_new);
        let (keys, hit_blocks) = if self.paged {
            let keys = prefix_block_keys(adapter, prompt, bs);
            let max_hit = prompt.len().saturating_sub(1) / bs;
            let mut hit = 0;
            for &k in keys.iter().take(max_hit) {
                if self.pool.lookup(k).is_some() {
                    hit += 1;
                } else {
                    break;
                }
            }
            (keys, hit)
        } else {
            (Vec::new(), 0)
        };
        let mut shared = Vec::with_capacity(hit_blocks);
        for &k in keys.iter().take(hit_blocks) {
            match self.pool.ref_cached(k) {
                Some(b) => shared.push(b),
                // Unreachable while &mut self is held, but stay total.
                None => break,
            }
        }
        let hit_blocks = shared.len();
        let need = footprint.saturating_sub(hit_blocks);
        let mut private = Vec::with_capacity(need);
        let mut evictions = 0usize;
        for _ in 0..need {
            match self.pool.alloc_private() {
                Some(pa) => {
                    if pa.evicted.is_some() {
                        evictions += 1;
                    }
                    private.push(pa.block);
                }
                None => {
                    for &b in &private {
                        let _ = self.pool.release_private(b);
                    }
                    for &b in &shared {
                        let _ = self.pool.unref_cached(b);
                    }
                    return None;
                }
            }
        }
        Some(KvReservation { shared, private, keys, hit_blocks, evictions })
    }

    /// Roll back a reservation whose admission later stalled (e.g. the
    /// adapter bank had no evictable slot).
    pub fn cancel_reservation(&mut self, res: KvReservation) -> Result<()> {
        for &b in &res.private {
            self.pool.release_private(b)?;
        }
        for &b in &res.shared {
            self.pool.unref_cached(b)?;
        }
        Ok(())
    }

    /// Commit a reservation to a decode slot's block table.
    pub fn bind_lane(&mut self, slot: usize, res: KvReservation) -> Result<()> {
        let bs = self.pool.block_size();
        let n = self.lanes.len();
        let Some(entry) = self.lanes.get_mut(slot) else {
            bail!("KV lane {slot} out of range ({n})");
        };
        if entry.is_some() {
            bail!("KV lane {slot} is already bound");
        }
        *entry = Some(LaneBlocks {
            hit_tokens: res.hit_blocks * bs,
            shared: res.shared,
            private: res.private,
            keys: res.keys,
        });
        Ok(())
    }

    /// Copy the lane's shared-prefix payloads into its contiguous cache
    /// region (positions `0..hit_tokens`).  Returns `hit_tokens` — the
    /// position decode resumes prompt-feeding from.
    pub fn adopt_shared_prefix(&self, kv: &mut KvState, slot: usize) -> Result<usize> {
        let Some(lane) = self.lanes.get(slot).and_then(|l| l.as_ref()) else {
            bail!("KV lane {slot} is not bound");
        };
        let bs = self.pool.block_size();
        for (j, &b) in lane.shared.iter().take(lane.hit_tokens / bs.max(1)).enumerate() {
            let (Some(kd), Some(vd)) = (self.data_k.get(b), self.data_v.get(b)) else {
                bail!("cached block {b} has no stored payload");
            };
            kv.write_block(slot, j * bs, bs, kd, vd)?;
        }
        Ok(lane.hit_tokens)
    }

    /// After a cold prefill lands in `slot`, promote the lane's leading
    /// private blocks (those covering full prompt blocks beyond the hit
    /// run) into the shared-prefix cache, snapshotting their payloads.
    /// Returns the number of blocks published.  A key already published by
    /// a concurrent lane keeps this lane's block private (no dedup copy).
    pub fn publish_prefix(
        &mut self,
        kv: &mut KvState,
        slot: usize,
        prompt_len: usize,
    ) -> Result<usize> {
        if !self.paged {
            return Ok(0);
        }
        let bs = self.pool.block_size();
        let n = self.lanes.len();
        let Some(lane) = self.lanes.get_mut(slot).and_then(|l| l.as_mut()) else {
            bail!("KV lane {slot}/{n} is not bound");
        };
        let full = prompt_len / bs;
        let hit = lane.hit_tokens / bs;
        if full <= hit {
            return Ok(0);
        }
        let publishable = (full - hit).min(lane.private.len());
        let candidates: Vec<usize> = lane.private.drain(..publishable).collect();
        let mut kept = Vec::new();
        let mut published = 0usize;
        for (idx, b) in candidates.into_iter().enumerate() {
            let j = hit + idx;
            let Some(&key) = lane.keys.get(j) else {
                kept.push(b);
                continue;
            };
            if self.pool.publish(b, key)? {
                let (kd, vd) = kv.read_block(slot, j * bs, bs)?;
                if let (Some(dk), Some(dv)) = (self.data_k.get_mut(b), self.data_v.get_mut(b)) {
                    *dk = kd;
                    *dv = vd;
                }
                lane.shared.push(b);
                published += 1;
            } else {
                kept.push(b);
            }
        }
        kept.append(&mut lane.private);
        lane.private = kept;
        Ok(published)
    }

    /// Return every block of a lane exactly once: private blocks to the
    /// free list, shared blocks via unref (the cached originals survive
    /// with their refcount decremented — a cancelled hit lane never frees
    /// the shared prefix out from under other lanes).  Double release of
    /// the same slot fails, as does releasing an unbound slot.
    pub fn release_lane(&mut self, slot: usize) -> Result<KvRelease> {
        let n = self.lanes.len();
        let Some(entry) = self.lanes.get_mut(slot) else {
            bail!("KV lane {slot} out of range ({n})");
        };
        let Some(lane) = entry.take() else {
            bail!("release of unbound KV lane {slot}");
        };
        for &b in &lane.private {
            self.pool.release_private(b)?;
        }
        for &b in &lane.shared {
            self.pool.unref_cached(b)?;
        }
        Ok(KvRelease { private_freed: lane.private.len(), shared_unrefs: lane.shared.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfigInfo {
        ModelConfigInfo {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
            head_dim: 4,
            n_adapters: 4,
            lora_rank: 2,
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut a = SlotAllocator::new(3);
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s1, s2);
        assert_eq!(a.n_free(), 1);
        a.release(s1).unwrap();
        assert!(a.release(s1).is_err(), "double free must fail");
        assert_eq!(a.n_free(), 2);
        let _ = a.alloc().unwrap();
        let _ = a.alloc().unwrap();
        assert!(a.alloc().is_none());
    }

    #[test]
    fn adopt_prefill_lane_copies_right_region() {
        let c = cfg();
        let mut kv = KvState::new(&c, 4);
        // prefill output with b=2; fill lane 1 with a marker pattern
        let shape = vec![c.n_layers, 2, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = shape.iter().product();
        let mut pk = HostTensor::zeros(shape.clone(), DType::F32);
        let pv = HostTensor::zeros(shape, DType::F32);
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = ((l * 2 + 1) * c.n_heads + h) * c.max_seq * c.head_dim;
                pk.write_f32_range(off, &vec![7.5; 3 * c.head_dim]);
            }
        }
        assert!(n > 0);
        kv.adopt_prefill_lane(&pk, &pv, 1, 2, 3).unwrap();
        // slot 2 has the marker in the first 3 positions of every lane
        let hk = kv.host_k().unwrap().clone();
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = kv.lane_offset(l, 2, h);
                assert_eq!(hk.read_f32_range(off, 3 * c.head_dim), vec![7.5; 3 * c.head_dim]);
                assert_eq!(hk.f32_at(off + 3 * c.head_dim), 0.0);
            }
        }
        // other slots untouched
        assert_eq!(hk.f32_at(kv.lane_offset(0, 1, 0)), 0.0);
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = KvState::new(&c, 2);
        let off = kv.lane_offset(0, 1, 0);
        kv.hk.write_f32_range(off, &[9.0; 4]);
        kv.clear_slot(1).unwrap();
        assert_eq!(kv.host_k().unwrap().f32_at(off), 0.0);
    }

    #[test]
    fn device_roundtrip_preserves_cache() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        let marker = kv.lane_offset(1, 1, 1);
        kv.hk.write_f32_range(marker, &[3.25; 4]);
        kv.hv.write_f32_range(marker, &[-1.5; 4]);

        assert_eq!(kv.residency(), Residency::Host);
        assert!(kv.ensure_device(&client).unwrap(), "first upload transfers");
        assert_eq!(kv.residency(), Residency::Device);
        assert!(!kv.ensure_device(&client).unwrap(), "already device-resident");
        assert!(kv.host_k().is_err(), "host view requires materialization");
        kv.device_pair().unwrap();

        assert!(kv.materialize_host().unwrap(), "download transfers");
        assert!(!kv.materialize_host().unwrap(), "already host-resident");
        assert_eq!(kv.host_k().unwrap().read_f32_range(marker, 4), vec![3.25; 4]);
        assert_eq!(kv.host_v().unwrap().read_f32_range(marker, 4), vec![-1.5; 4]);
        assert!(kv.device_pair().is_err());
    }

    #[test]
    fn install_device_swaps_in_decode_outputs() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        let shape = kv.shape();
        let n: usize = shape.iter().product();
        // Pretend these are the decode step's k/v output buffers.
        let k_new = HostTensor::f32(shape.clone(), vec![2.0; n]);
        let v_new = HostTensor::f32(shape.clone(), vec![4.0; n]);
        let kb = upload(&client, &k_new).unwrap();
        let vb = upload(&client, &v_new).unwrap();
        kv.install_device(kb, vb).unwrap();
        assert_eq!(kv.residency(), Residency::Device);

        kv.materialize_host().unwrap();
        assert_eq!(kv.host_k().unwrap().f32_at(n - 1), 2.0);
        assert_eq!(kv.host_v().unwrap().f32_at(0), 4.0);

        // Shape mismatches are rejected.
        let bad = upload(&client, &HostTensor::f32(vec![2], vec![0.0, 1.0])).unwrap();
        let ok = upload(&client, &k_new).unwrap();
        assert!(kv.install_device(bad, ok).is_err());
    }

    #[test]
    fn adopt_materializes_device_cache_first() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        kv.ensure_device(&client).unwrap();

        let shape = vec![c.n_layers, 1, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = shape.iter().product();
        let pk = HostTensor::f32(shape.clone(), vec![1.25; n]);
        let pv = HostTensor::f32(shape, vec![0.5; n]);
        kv.adopt_prefill_lane(&pk, &pv, 0, 1, 2).unwrap();

        assert_eq!(kv.residency(), Residency::Host, "adoption is a host operation");
        let off = kv.lane_offset(0, 1, 0);
        assert_eq!(kv.host_k().unwrap().read_f32_range(off, 2 * c.head_dim), vec![
            1.25;
            2 * c.head_dim
        ]);
    }

    #[test]
    fn prefix_keys_are_adapter_salted_and_prefix_stable() {
        let prompt = [3, 1, 4, 1, 5, 9, 2, 6];
        let base = prefix_block_keys(None, &prompt, 2);
        assert_eq!(base.len(), 4, "one key per full block");
        // Same tokens under a different adapter never share keys.
        let salted = prefix_block_keys(Some("road:a"), &prompt, 2);
        assert!(base.iter().zip(&salted).all(|(a, b)| a != b));
        // A longer prompt extends, not perturbs, the shorter prompt's keys.
        let longer = prefix_block_keys(None, &[3, 1, 4, 1, 5, 9, 2, 6, 7, 7], 2);
        assert_eq!(&longer[..4], &base[..]);
        // Diverging tokens diverge from the first affected block onward.
        let fork = prefix_block_keys(None, &[3, 1, 4, 1, 8, 9, 2, 6], 2);
        assert_eq!(fork[0], base[0]);
        assert_eq!(fork[1], base[1]);
        assert_ne!(fork[2], base[2]);
        assert_ne!(fork[3], base[3]);
        // No panic on degenerate block size; partial blocks yield no key.
        assert_eq!(prefix_block_keys(None, &[1], 0).len(), 1);
        assert!(prefix_block_keys(None, &[1, 2, 3], 4).is_empty());
    }

    /// Cold reserve -> bind -> publish -> release -> warm reserve hits the
    /// published prefix, and adoption reproduces the published payloads
    /// bit-for-bit in the new lane.
    #[test]
    fn paged_publish_then_hit_roundtrip() {
        let c = cfg(); // max_seq 8, n_layers 2, n_heads 2, head_dim 4
        let bs = 2;
        let mut kv = KvState::new(&c, 2);
        let mut paged = PagedKv::new(2, c.max_seq, bs, 8, true);
        let prompt = [11, 12, 13, 14, 15];

        // Cold: footprint ceil((5 + 3) / 2) = 4 blocks, no hits.
        let res = paged.try_reserve(Some("ad"), &prompt, 3).unwrap();
        assert_eq!(res.hit_blocks, 0);
        assert_eq!(res.n_blocks(), 4);
        paged.bind_lane(0, res).unwrap();
        assert!(paged.is_bound(0));

        // Pretend prefill wrote distinctive K/V rows for the prompt.
        let row = |t: usize| vec![t as f32 + 0.5; c.head_dim];
        for t in 0..prompt.len() {
            let mut k = Vec::new();
            for _ in 0..c.n_layers * c.n_heads {
                k.extend(row(t));
            }
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            kv.write_block(0, t, 1, &k, &v).unwrap();
        }

        // Publish: full = 5 / 2 = 2 blocks become cached (refs = 1).
        assert_eq!(paged.publish_prefix(&mut kv, 0, prompt.len()).unwrap(), 2);
        assert_eq!(paged.pool().n_cached(), 2);
        assert_eq!(paged.pool().total_refs(), 2);

        // Release returns all 4 blocks exactly once; cached entries stay.
        let rel = paged.release_lane(0).unwrap();
        assert_eq!(rel, KvRelease { private_freed: 2, shared_unrefs: 2 });
        assert!(paged.release_lane(0).is_err(), "double release must fail");
        paged.pool().check_conservation().unwrap();
        assert_eq!(paged.pool().total_refs(), 0);
        assert_eq!(paged.pool().n_cached(), 2);

        // Warm: same adapter + prompt hits floor((5 - 1) / 2) = 2 blocks.
        let res = paged.try_reserve(Some("ad"), &prompt, 3).unwrap();
        assert_eq!(res.hit_blocks, 2);
        // A different adapter over the same tokens must miss.
        assert_eq!(paged.try_reserve(Some("other"), &prompt, 3).map(|r| {
            let h = r.hit_blocks;
            paged.cancel_reservation(r).unwrap();
            h
        }), Some(0));
        paged.bind_lane(1, res).unwrap();
        let hit_tokens = paged.adopt_shared_prefix(&mut kv, 1).unwrap();
        assert_eq!(hit_tokens, 4);
        let (cold_k, cold_v) = kv.read_block(0, 0, 4).unwrap();
        let (warm_k, warm_v) = kv.read_block(1, 0, 4).unwrap();
        assert_eq!(cold_k, warm_k, "adopted prefix must be bit-identical");
        assert_eq!(cold_v, warm_v);
        paged.release_lane(1).unwrap();
        paged.pool().check_conservation().unwrap();
    }

    /// Referenced cached blocks pin against eviction: a reservation that
    /// would need them fails outright instead of stealing them, and a
    /// stalled admission rolls back to the pre-reserve state.
    #[test]
    fn reservation_pressure_respects_refcounts_and_rolls_back() {
        let c = cfg();
        let mut kv = KvState::new(&c, 2);
        // 4-block pool, block 2 tokens.
        let mut paged = PagedKv::new(2, c.max_seq, 2, 4, true);
        let prompt = [1, 2, 3, 4, 5];
        let res = paged.try_reserve(None, &prompt, 3).unwrap();
        assert_eq!(res.n_blocks(), 4, "pool is now fully occupied");
        paged.bind_lane(0, res).unwrap();
        paged.publish_prefix(&mut kv, 0, prompt.len()).unwrap();

        // All 4 blocks are held by lane 0 (2 private + 2 cached refs = 1):
        // nothing is evictable, so any new reservation must fail...
        assert!(paged.try_reserve(None, &[9, 9, 9], 1).is_none());
        paged.pool().check_conservation().unwrap();
        assert_eq!(paged.pool().n_free(), 0);

        // ...and after release the cached blocks (refs = 0) are fair game:
        // a 3-block reservation drains the 2 freed blocks and then must
        // evict an LRU cached block for the third.
        paged.release_lane(0).unwrap();
        let res = paged.try_reserve(None, &[9, 9, 9], 3).unwrap();
        assert_eq!(res.n_blocks(), 3);
        assert!(res.evictions > 0, "pressure must surface as evictions");
        paged.cancel_reservation(res).unwrap();
        paged.pool().check_conservation().unwrap();
    }

    /// Flat mode (`paged_kv = false`) charges every admission a full
    /// max_seq lane and never shares, making it the equal-budget baseline.
    #[test]
    fn flat_mode_charges_full_lanes_and_never_hits() {
        let c = cfg(); // max_seq 8
        let bs = 2;
        // Budget = 2 lanes * ceil(8 / 2) = 8 blocks.
        let mut paged = PagedKv::new(3, c.max_seq, bs, 8, false);
        let prompt = [1, 2, 3, 4];
        let r0 = paged.try_reserve(None, &prompt, 1).unwrap();
        assert_eq!(r0.n_blocks(), 4, "flat footprint is max_seq / block");
        assert_eq!(r0.hit_blocks, 0);
        paged.bind_lane(0, r0).unwrap();
        let r1 = paged.try_reserve(None, &prompt, 1).unwrap();
        paged.bind_lane(1, r1).unwrap();
        // Same prompt again: flat mode has no prefix cache to hit and no
        // free blocks left -> admission stalls.
        assert!(paged.try_reserve(None, &prompt, 1).is_none());
        paged.release_lane(0).unwrap();
        paged.release_lane(1).unwrap();
        paged.pool().check_conservation().unwrap();
        assert_eq!(paged.pool().n_free(), 8);
    }
}
