pub fn bank_row(data: &[f32], s: usize, row: usize) -> &[f32] {
    data.get(s * row..(s + 1) * row).unwrap()
}

pub fn slot_for(slots: &[usize], r: usize) -> usize {
    *slots.get(r).expect("row has a slot")
}

pub fn rotate_pair(z: &mut [f32]) {
    if z.len() % 2 != 0 {
        panic!("odd rotation dim {}", z.len());
    }
}

pub fn mode_dispatch(mode: &str) {
    match mode {
        "road" | "lora" | "ia3" => {}
        _ => unreachable!("validated at construction"),
    }
}

pub fn guarded_bank(m: &std::sync::Mutex<Vec<f32>>) -> usize {
    m.lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        Some(1usize).unwrap();
    }
}
