//! Multi-adapter serving comparison (Figure 4 in miniature): RoAd's
//! element-wise adapter path vs LoRA's bmm path vs the merged base model,
//! on the same heterogeneous workload — then the virtualized bank: far
//! more registered adapters than device slots, paged in on demand.
//!
//! ```bash
//! cargo run --release --example multi_adapter_serving
//! ```

use std::rc::Rc;

use anyhow::Result;

use road::bench;
use road::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    let new_tokens = 48;
    let distinct = 8;
    println!(
        "workload: 16 requests, {distinct} distinct adapters, {new_tokens} generated tokens each, 8 decode slots\n"
    );

    let mut points = Vec::new();
    for mode in ["base", "road", "lora"] {
        let d = if mode == "base" { 0 } else { distinct };
        let p = bench::measure_serving(&rt, "serve", mode, 8, d, 16, new_tokens, 7)?;
        println!(
            "{:<6} {:>8.1} tok/s   ({} decode steps, {:.2}s)",
            mode, p.tokens_per_sec, p.decode_steps, p.wall_secs
        );
        points.push(p);
    }

    let road_tps = points[1].tokens_per_sec;
    let lora_tps = points[2].tokens_per_sec;
    println!(
        "\nRoAd / unmerged-LoRA throughput ratio: {:.2}x (paper reports ~2x on A100)",
        road_tps / lora_tps
    );

    // Virtualized bank: 32 registered adapters served through 4 device
    // bank slots — registration always succeeds, admission pages LRU-style
    // and pins in-flight slots, and uploads move only the touched rows.
    // The number to compare is the uploaded KB (host-to-device bank
    // traffic); wall-clock on the offline stub also pays the device-side
    // scatter stand-in, so it is not the paging win.
    println!("\nadapter churn: 32 adapters paged through 4 bank slots (Zipf traffic)");
    for p in bench::bank_churn_study(&rt, 32, 4, 64, new_tokens, 7)? {
        println!(
            "{:<24} uploaded {:>9.1} KB   hits {} / misses {} / evictions {}",
            p.label,
            p.bank_upload_bytes as f64 / 1e3,
            p.bank_hits,
            p.bank_misses,
            p.bank_evictions,
        );
    }
    Ok(())
}
