//! The pretraining corpus: general byte-level competence for the backbone.
//!
//! The paper's PEFT methods adapt a *pretrained* LLM; our substitution
//! needs the same starting point.  `road pretrain` full-finetunes the
//! random-init backbone on this mixture — generic abilities (letter
//! statistics, copying, digit sequences, single-digit arithmetic, the
//! prompt/terminator format) WITHOUT the downstream task mappings — and
//! saves it as `artifacts/pretrained_<cfg>.bin`.  Every trainer/engine then
//! starts from it, so finetuning measures specialization, as in the paper.

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

/// Free-running "text": random words of mixed case joined by spaces.
pub struct WordsLm;

impl Task for WordsLm {
    fn name(&self) -> &'static str {
        "pt-words"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let mut text = String::new();
        while text.len() < 20 {
            let n = 2 + rng.below(5);
            for _ in 0..n {
                let c = b'a' + rng.below(16) as u8;
                text.push(if rng.chance(0.2) { c.to_ascii_uppercase() } else { c } as char);
            }
            text.push(' ');
        }
        // LM objective over the whole window: 1-token prompt, rest target.
        let prompt = text[..1].to_string();
        let completion = text[1..].to_string();
        Example::gen(&prompt, &completion)
    }
}

/// Copying: "c:xyz>xyz." — teaches the prompt format, '>' and '.' roles.
pub struct CopyTask;

impl Task for CopyTask {
    fn name(&self) -> &'static str {
        "pt-copy"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = 2 + rng.below(6);
        let word: String = (0..n)
            .map(|_| {
                let c = b'a' + rng.below(16) as u8;
                (if rng.chance(0.3) { c.to_ascii_uppercase() } else { c }) as char
            })
            .collect();
        Example::gen(&format!("c:{word}>"), &format!("{word}."))
    }
}

/// Digit runs: counting up/down by one, mod 10.
pub struct DigitRuns;

impl Task for DigitRuns {
    fn name(&self) -> &'static str {
        "pt-digits"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let start = rng.below(10) as u8;
        let dir: i32 = if rng.chance(0.5) { 1 } else { -1 };
        let seq: String = (0..10)
            .map(|i| (((start as i32 + dir * i).rem_euclid(10)) as u8 + b'0') as char)
            .collect();
        Example::gen(&seq[..2].to_string(), &seq[2..].to_string())
    }
}

/// Single-digit addition facts: "3+4=7." — digit-arithmetic primitives,
/// not the multi-digit compositions the arithmetic suite tests.
pub struct DigitAdd;

impl Task for DigitAdd {
    fn name(&self) -> &'static str {
        "pt-add1"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.below(10);
        let b = rng.below(10);
        Example::gen(&format!("{a}+{b}="), &format!("{}.", a + b))
    }
}

/// Punctuation/format glue: "k:v|k:v>" lists (teaches separators used by
/// the downstream suites).
pub struct KvFormat;

impl Task for KvFormat {
    fn name(&self) -> &'static str {
        "pt-kv"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let k1 = (b'a' + rng.below(8) as u8) as char;
        let v1 = (b'0' + rng.below(10) as u8) as char;
        let k2 = (b'a' + rng.below(8) as u8) as char;
        let v2 = (b'0' + rng.below(10) as u8) as char;
        // Recall the value of the *first* key.
        Example::gen(&format!("{k1}{v1}|{k2}{v2}|{k1}?"), &format!("{v1}."))
    }
}

/// Two-digit number copying: "n:47>47." — teaches multi-digit number
/// emission (the arithmetic suite needs it; sums themselves stay unseen).
pub struct NumberCopy;

impl Task for NumberCopy {
    fn name(&self) -> &'static str {
        "pt-numcopy"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = rng.range(10, 100);
        Example::gen(&format!("n:{n}>"), &format!("{n}."))
    }
}

/// Two-digit successor: "s:47>48." — number-line structure beyond single
/// digits.
pub struct NumberSucc;

impl Task for NumberSucc {
    fn name(&self) -> &'static str {
        "pt-numsucc"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = rng.range(10, 98);
        Example::gen(&format!("s:{n}>"), &format!("{}.", n + 1))
    }
}

pub fn corpus() -> Vec<Box<dyn Task>> {
    vec![
        Box::new(WordsLm),
        Box::new(CopyTask),
        Box::new(DigitRuns),
        Box::new(DigitAdd),
        Box::new(KvFormat),
        Box::new(NumberCopy),
        Box::new(NumberSucc),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tasks_fit_window_and_avoid_pad() {
        let mut rng = Rng::seed_from(17);
        for t in corpus() {
            for _ in 0..50 {
                let ex = t.sample(&mut rng);
                assert!(ex.prompt.len() + ex.completion.len() <= 32, "{}", t.name());
                assert!(ex.prompt.iter().chain(&ex.completion).all(|&t| t > 0));
            }
        }
    }

    #[test]
    fn copy_round_trips() {
        let mut rng = Rng::seed_from(18);
        let ex = CopyTask.sample(&mut rng);
        let p = crate::tokenizer::decode(&ex.prompt);
        let word = p.trim_start_matches("c:").trim_end_matches('>');
        assert_eq!(crate::tokenizer::decode(&ex.completion), format!("{word}."));
    }

    #[test]
    fn kv_recalls_first_key() {
        let mut rng = Rng::seed_from(19);
        for _ in 0..50 {
            let ex = KvFormat.sample(&mut rng);
            let p = crate::tokenizer::decode(&ex.prompt);
            let v1 = p.as_bytes()[1] as char;
            assert_eq!(crate::tokenizer::decode(&ex.completion), format!("{v1}."));
        }
    }
}
