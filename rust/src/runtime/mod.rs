//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the serving/training hot paths.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns them).
//!
//! `PjRtClient` is `Rc`-based (not Send), so a `Runtime` is owned by a
//! single engine thread; the coordinator front-end talks to it over
//! channels (DESIGN.md: std::thread + mpsc in lieu of tokio).
//!
//! # Host/device buffer lifecycle
//!
//! Three kinds of tensor flow through an [`Executable`]:
//!
//! * **Persistent device buffers** ([`Arg::Buffer`]) — parameters, adapter
//!   banks, frozen backbones.  Uploaded once by their owner (engine,
//!   trainer) and referenced by every subsequent call; they live as long as
//!   the owner holds the `xla::PjRtBuffer`.
//! * **Per-call host tensors** ([`Arg::Host`]) — step inputs (token ids,
//!   positions, adapter slot ids).  Uploaded inside [`Executable::run`] /
//!   [`Executable::run_device`] and dropped when the call returns; these
//!   are small (O(batch)) by design.
//! * **Loop-carried state** — the decode K/V caches.  These enter as
//!   `Arg::Buffer` and must *leave* as device buffers too, or the loop pays
//!   a full cache round-trip every step.  [`Executable::run`] downloads all
//!   outputs to host (fine for prefill/training, whose outputs are consumed
//!   host-side); the decode loop instead uses [`Executable::run_device`],
//!   which returns one `xla::PjRtBuffer` per output so the caller can feed
//!   the step-`t` K/V outputs straight back in as the step-`t+1` inputs and
//!   download only the logits ([`buffer_to_host`]).  Per-step transfer
//!   volume drops from O(layers·B·max_seq·d) to O(B·vocab).
//!
//! Ownership rule of thumb: whoever will pass the tensor to the *next* call
//! keeps the buffer; anything only read by the host is downloaded
//! immediately and the buffer dropped.
//!
//! # Backends
//!
//! [`Runtime`] is a backend abstraction ([`BackendKind`]):
//!
//! * [`BackendKind::Pjrt`] — the artifact path above: HLO text compiled
//!   and executed through PJRT.  Requires `make artifacts` (and, to
//!   actually execute, the native xla runtime instead of the vendored
//!   host-memory stub).
//! * [`BackendKind::Reference`] — [`reference`]: a pure-Rust,
//!   deterministic forward pass that synthesizes the same serving entries
//!   (names, signatures, `Arg` conventions) with **no artifacts at all**.
//!   Executables read their inputs back off the (host-memory) buffers,
//!   compute on host, and re-"upload" outputs, so the engine's
//!   buffer-lifecycle logic runs unchanged.
//!
//! The engine, server, benches, and tests are backend-agnostic; selection
//! happens at [`Runtime`] construction (`road serve --backend ref`,
//! `EngineConfig::backend`).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::{EntryInfo, Manifest};
use crate::tensor::{DType, HostTensor};

pub mod epilogue;
pub mod reference;

/// Which execution backend a [`Runtime`] (and its [`Executable`]s) uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Compiled HLO artifacts through PJRT (the production path).
    #[default]
    Pjrt,
    /// Pure-Rust reference model ([`reference`]): artifact-free, exact,
    /// slow — the golden oracle and CI backend.
    Reference,
}

impl BackendKind {
    /// Parse a CLI/wire name ("pjrt" | "ref"/"reference").
    pub fn from_name(s: &str) -> Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "ref" | "reference" => Ok(BackendKind::Reference),
            other => bail!("unknown backend {other:?} (pjrt|ref)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "ref",
        }
    }

    /// Environment-aware selection for test suites and tooling:
    /// `ROAD_TEST_BACKEND` (ref|pjrt) wins; otherwise PJRT when artifacts
    /// are built (the pre-backend behavior of the integration suites),
    /// reference when they are not (so suites execute instead of
    /// skipping).  The single source of truth for every suite's backend
    /// choice — tests must not reimplement this.
    pub fn auto() -> BackendKind {
        match std::env::var("ROAD_TEST_BACKEND").as_deref() {
            Ok("pjrt") => BackendKind::Pjrt,
            Ok("ref") | Ok("reference") => BackendKind::Reference,
            _ if Manifest::available() => BackendKind::Pjrt,
            _ => BackendKind::Reference,
        }
    }
}

/// Input argument: either host data (uploaded per call) or a persistent
/// device buffer (params/banks/loop-carried state — the decode hot path).
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Buffer(&'a xla::PjRtBuffer),
}

/// Backend-specific execution state behind an [`Executable`].
enum ExecImpl {
    Pjrt(xla::PjRtLoadedExecutable),
    Reference(reference::RefEntry),
}

pub struct Executable {
    pub info: EntryInfo,
    imp: ExecImpl,
    client: xla::PjRtClient,
    /// Cumulative execution statistics (perf accounting).
    pub calls: RefCell<usize>,
    pub total_exec: RefCell<std::time::Duration>,
}

impl Executable {
    /// Validate `args` against the manifest signature and upload the host
    /// args.  The returned uploads must stay alive until execution
    /// finishes; [`positional`] interleaves them back into argument order.
    fn upload_host_args(&self, args: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        if args.len() != self.info.inputs.len() {
            bail!(
                "entry {}: {} args provided, {} expected",
                self.info.name,
                args.len(),
                self.info.inputs.len()
            );
        }
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for (i, a) in args.iter().enumerate() {
            if let Arg::Host(t) = a {
                let spec = &self.info.inputs[i];
                if t.shape != spec.shape || t.dtype != spec.dtype {
                    bail!(
                        "entry {}: arg {} ({}/{}) shape/dtype mismatch: got {:?} want {:?}",
                        self.info.name,
                        i,
                        spec.group,
                        spec.name,
                        (&t.shape, t.dtype),
                        (&spec.shape, spec.dtype)
                    );
                }
                owned.push(upload(&self.client, t)?);
            }
        }
        Ok(owned)
    }

    /// Materialize every argument as a host tensor for the reference
    /// backend: `Arg::Host` is validated against the signature, and
    /// `Arg::Buffer` is read back off the (host-memory) buffer — the same
    /// direction a real device download would move.
    fn gather_host_args(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        if args.len() != self.info.inputs.len() {
            bail!(
                "entry {}: {} args provided, {} expected",
                self.info.name,
                args.len(),
                self.info.inputs.len()
            );
        }
        let mut out = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let spec = &self.info.inputs[i];
            match a {
                Arg::Host(t) => {
                    if t.shape != spec.shape || t.dtype != spec.dtype {
                        bail!(
                            "entry {}: arg {} ({}/{}) shape/dtype mismatch: got {:?} want {:?}",
                            self.info.name,
                            i,
                            spec.group,
                            spec.name,
                            (&t.shape, t.dtype),
                            (&spec.shape, spec.dtype)
                        );
                    }
                    out.push((*t).clone());
                }
                Arg::Buffer(b) => {
                    let want: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    if b.dims() != want {
                        bail!(
                            "entry {}: arg {} ({}/{}) buffer dims {:?}, want {:?}",
                            self.info.name,
                            i,
                            spec.group,
                            spec.name,
                            b.dims(),
                            want
                        );
                    }
                    out.push(buffer_to_host(b, spec.dtype)?);
                }
            }
        }
        Ok(out)
    }

    fn run_reference(&self, entry: &reference::RefEntry, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let ins = self.gather_host_args(args)?;
        // roadlint: allow(clock-discipline) -- profiles real kernel
        // execution time for the runtime's perf counters.
        let t0 = Instant::now();
        let outs = entry
            .execute(&ins)
            .with_context(|| format!("executing {} (reference backend)", self.info.name))?;
        *self.calls.borrow_mut() += 1;
        *self.total_exec.borrow_mut() += t0.elapsed();
        if outs.len() != self.info.outputs.len() {
            bail!(
                "entry {}: {} outputs, manifest says {}",
                self.info.name,
                outs.len(),
                self.info.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with mixed host/device inputs; **all outputs come back to
    /// host**.  Use for prefill/training/eval entries whose outputs are
    /// consumed host-side.  The lowered computations have a tuple root
    /// (`return_tuple=True`), so PJRT returns a single tuple buffer which
    /// we decompose into one `HostTensor` per declared output.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let exe = match &self.imp {
            ExecImpl::Pjrt(exe) => exe,
            ExecImpl::Reference(entry) => return self.run_reference(entry, args),
        };
        let owned = self.upload_host_args(args)?;
        let refs = positional(args, &owned);

        // roadlint: allow(clock-discipline) -- profiles real kernel
        // execution time for the runtime's perf counters.
        let t0 = Instant::now();
        let result = exe
            .execute_b(&refs)
            .with_context(|| format!("executing {}", self.info.name))?;
        let lit = result[0][0].to_literal_sync()?;
        *self.calls.borrow_mut() += 1;
        *self.total_exec.borrow_mut() += t0.elapsed();
        drop(owned);

        let parts = lit.to_tuple()?;
        if parts.len() != self.info.outputs.len() {
            bail!(
                "entry {}: {} outputs, manifest says {}",
                self.info.name,
                parts.len(),
                self.info.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.info.outputs) {
            outs.push(literal_to_host(&lit, spec.dtype)?);
        }
        Ok(outs)
    }

    /// Execute with mixed host/device inputs; **outputs stay on device**,
    /// one `xla::PjRtBuffer` per declared output (untupled execution).
    ///
    /// This is the decode hot path: the caller feeds the returned K/V
    /// buffers back in as the next step's `Arg::Buffer` inputs and
    /// downloads only what the host actually reads (the logits, via
    /// [`buffer_to_host`]).
    ///
    /// On the reference backend, outputs are computed on host and uploaded
    /// into fresh buffers — same ownership contract, host-memory payloads.
    pub fn run_device(&self, args: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = match &self.imp {
            ExecImpl::Pjrt(exe) => exe,
            ExecImpl::Reference(entry) => {
                let outs = self.run_reference(entry, args)?;
                return outs.iter().map(|t| upload(&self.client, t)).collect();
            }
        };
        let owned = self.upload_host_args(args)?;
        let refs = positional(args, &owned);

        // roadlint: allow(clock-discipline) -- profiles real kernel
        // execution time for the runtime's perf counters.
        let t0 = Instant::now();
        let outs = exe
            .execute_untupled(&refs)
            .with_context(|| format!("executing {} (device outputs)", self.info.name))?;
        *self.calls.borrow_mut() += 1;
        *self.total_exec.borrow_mut() += t0.elapsed();
        drop(owned);

        if outs.len() != self.info.outputs.len() {
            bail!(
                "entry {}: {} device outputs, manifest says {}",
                self.info.name,
                outs.len(),
                self.info.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Convenience: all-host-args execution.
    pub fn run_host(&self, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let wrapped: Vec<Arg> = args.iter().map(|t| Arg::Host(t)).collect();
        self.run(&wrapped)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Interleave per-call uploads back into positional argument order
/// alongside the caller-owned persistent buffers.
fn positional<'b>(args: &'b [Arg<'b>], owned: &'b [xla::PjRtBuffer]) -> Vec<&'b xla::PjRtBuffer> {
    let mut owned_iter = owned.iter();
    args.iter()
        .map(|a| match a {
            Arg::Buffer(b) => *b,
            Arg::Host(_) => owned_iter.next().expect("one upload per host arg"),
        })
        .collect()
}

pub fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    match t.dtype {
        DType::F32 => {
            if let Some(sl) = t.f32_slice() {
                Ok(client.buffer_from_host_buffer(sl, &t.shape, None)?)
            } else {
                let v = t.as_f32();
                Ok(client.buffer_from_host_buffer(&v, &t.shape, None)?)
            }
        }
        DType::I32 => {
            let v = t.as_i32();
            Ok(client.buffer_from_host_buffer(&v, &t.shape, None)?)
        }
    }
}

/// Download a device buffer to a host tensor (the only per-step transfer
/// the device-resident decode loop performs, on the logits).
pub fn buffer_to_host(buf: &xla::PjRtBuffer, dtype: DType) -> Result<HostTensor> {
    let lit = buf.to_literal_sync()?;
    literal_to_host(&lit, dtype)
}

fn literal_to_host(lit: &xla::Literal, dtype: DType) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match dtype {
        DType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
        DType::I32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
    }
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Which backend [`Runtime::load`] materializes entries on.
    pub backend: BackendKind,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Cumulative compile time (reported by `road stats`).
    pub total_compile: RefCell<std::time::Duration>,
    /// Reference-backend adapter epilogues: fused chunked kernel (default)
    /// or the scalar oracle (`road serve --fused-epilogue=false`).  Shared
    /// with every loaded [`reference::RefEntry`] — including already-cached
    /// ones — so flipping it re-routes the whole runtime.
    fused_epilogue: Rc<Cell<bool>>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        Runtime::with_backend(manifest, BackendKind::Pjrt)
    }

    /// Build a runtime over an explicit backend.  The PJRT backend needs
    /// a manifest that points at real artifact files; the reference
    /// backend accepts either a real manifest (serving the artifact's
    /// weights — the cross-backend oracle) or the synthetic one.
    pub fn with_backend(manifest: Manifest, backend: BackendKind) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            backend,
            cache: RefCell::new(HashMap::new()),
            total_compile: RefCell::new(Default::default()),
            fused_epilogue: Rc::new(Cell::new(true)),
        })
    }

    /// Select the reference backend's adapter-epilogue path: `true` = the
    /// fused chunked kernel, `false` = the scalar oracle.  Affects every
    /// entry this runtime has loaded or will load; a no-op on PJRT.
    pub fn set_fused_epilogue(&self, fused: bool) {
        self.fused_epilogue.set(fused);
    }

    /// Current epilogue selection (reference backend).
    pub fn fused_epilogue(&self) -> bool {
        self.fused_epilogue.get()
    }

    pub fn from_default_artifacts() -> Result<Runtime> {
        Runtime::new(Manifest::load(Manifest::default_dir())?)
    }

    /// The artifact-free reference runtime: synthetic manifest
    /// ([`reference::synthetic_manifest`]), deterministic synthetic
    /// parameters, pure-Rust execution.  Never touches the filesystem.
    pub fn reference() -> Runtime {
        Runtime::with_backend(reference::synthetic_manifest(), BackendKind::Reference)
            .expect("reference runtime construction is infallible")
    }

    /// Reference execution over a *real* artifact manifest: entry
    /// signatures and parameters come from the artifact set, the math runs
    /// in Rust — the golden oracle for cross-backend identity tests.
    pub fn reference_with(manifest: Manifest) -> Result<Runtime> {
        Runtime::with_backend(manifest, BackendKind::Reference)
    }

    /// Construct the runtime for `kind`: the reference backend needs
    /// nothing (and ignores `artifacts_dir`); PJRT loads the manifest
    /// from it.  The one construction path shared by the engine server,
    /// the CLI, and the test suites.
    pub fn for_backend(
        kind: BackendKind,
        artifacts_dir: impl AsRef<std::path::Path>,
    ) -> Result<Runtime> {
        match kind {
            BackendKind::Reference => Ok(Runtime::reference()),
            BackendKind::Pjrt => Runtime::new(Manifest::load(artifacts_dir)?),
        }
    }

    /// Load + compile an entry (cached).  On the reference backend this
    /// parses the entry signature instead of compiling HLO.
    pub fn load(&self, entry: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(entry) {
            return Ok(e.clone());
        }
        let info = self.manifest.entry(entry)?.clone();
        // roadlint: allow(clock-discipline) -- profiles real compile/load
        // latency; only ever reported, never fed into scheduling.
        let t0 = Instant::now();
        let imp = match self.backend {
            BackendKind::Pjrt => {
                let path = self.manifest.artifact_path(&info.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                ExecImpl::Pjrt(
                    self.client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compiling {}: {e:?}", entry))?,
                )
            }
            BackendKind::Reference => {
                let cfg = self.manifest.config(&info.config)?.clone();
                let mut entry = reference::RefEntry::from_info(&info, &cfg)?;
                entry.attach_fused(self.fused_epilogue.clone());
                ExecImpl::Reference(entry)
            }
        };
        *self.total_compile.borrow_mut() += t0.elapsed();
        let e = Rc::new(Executable {
            info,
            imp,
            client: self.client.clone(),
            calls: RefCell::new(0),
            total_exec: RefCell::new(Default::default()),
        });
        self.cache.borrow_mut().insert(entry.to_string(), e.clone());
        Ok(e)
    }

    pub fn is_loaded(&self, entry: &str) -> bool {
        self.cache.borrow().contains_key(entry)
    }

    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        upload(&self.client, t)
    }

    /// Load a golden record: (inputs, expected outputs) in signature order.
    pub fn load_golden(&self, entry: &str) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
        let g = self
            .manifest
            .golden
            .get(entry)
            .ok_or_else(|| anyhow!("no golden record for {entry}"))?;
        let info = self.manifest.entry(entry)?;
        let raw_in = std::fs::read(self.manifest.artifact_path(&g.in_file))?;
        let mut ins = Vec::new();
        let mut off = 0usize;
        for spec in &info.inputs {
            let n = spec.elem_count() * 4;
            ins.push(HostTensor::from_bytes(
                spec.shape.clone(),
                spec.dtype,
                raw_in[off..off + n].to_vec(),
            )?);
            off += n;
        }
        let raw_out = std::fs::read(self.manifest.artifact_path(&g.out_file))?;
        let mut outs = Vec::new();
        off = 0;
        for spec in &g.outputs {
            let n = spec.elem_count() * 4;
            outs.push(HostTensor::from_bytes(
                spec.shape.clone(),
                spec.dtype,
                raw_out[off..off + n].to_vec(),
            )?);
            off += n;
        }
        Ok((ins, outs))
    }
}

/// Compare two f32 tensors with relative+absolute tolerance; returns the
/// worst mismatch if any.
pub fn allclose(a: &HostTensor, b: &HostTensor, rtol: f32, atol: f32) -> Result<()> {
    if a.shape != b.shape {
        bail!("shape mismatch {:?} vs {:?}", a.shape, b.shape);
    }
    let av = a.as_f32();
    let bv = b.as_f32();
    let mut worst = 0f32;
    let mut worst_i = 0usize;
    for i in 0..av.len() {
        let diff = (av[i] - bv[i]).abs();
        let bound = atol + rtol * bv[i].abs();
        if diff > bound && diff > worst {
            worst = diff;
            worst_i = i;
        }
    }
    if worst > 0.0 {
        bail!(
            "allclose failed: |{} - {}| = {} at flat index {} (rtol={rtol}, atol={atol})",
            av[worst_i],
            bv[worst_i],
            worst,
            worst_i
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_download_roundtrip_via_stub() {
        let client = xla::PjRtClient::cpu().unwrap();
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let buf = upload(&client, &t).unwrap();
        let back = buffer_to_host(&buf, DType::F32).unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn allclose_tolerances() {
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::f32(vec![2], vec![1.0 + 1e-6, 2.0]);
        allclose(&a, &b, 1e-4, 1e-5).unwrap();
        let c = HostTensor::f32(vec![2], vec![1.5, 2.0]);
        assert!(allclose(&a, &c, 1e-4, 1e-5).is_err());
    }
}
