//! Serving workload generation + the Figure-4 / Table-D.1 sweep harness.
//!
//! Figure 4's three panels are throughput studies of the multi-adapter
//! serving engine:
//!   * Left   — merged vs unmerged LoRA vs rank (batch 1, long generation),
//!   * Middle — RoAd vs unmerged LoRA vs #generated tokens (batch 8,
//!              heterogeneous adapters),
//!   * Right  — RoAd vs unmerged LoRA vs #distinct adapters in the batch.
//!
//! The bank-churn study ([`bank_churn_study`]) goes past the paper's
//! figure: many more registered adapters than device bank slots, a
//! Zipf-distributed request-to-adapter assignment, and paged vs
//! whole-bank-upload engines compared on hit/miss/eviction counts and
//! host-to-device upload bytes.
//!
//! Table D.1 times the per-step cost of each finetuning method (RoAd's
//! inherent orthogonality vs OFT's Cayley solves) and reports the
//! optimizer-state footprint.

use std::rc::Rc;

use anyhow::Result;

use crate::adapters::{Adapter, LoraAdapter, RoadAdapter};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{Request, SamplingParams};
use crate::runtime::Runtime;
use crate::trainer::{Recipe, TrainBatch, Trainer};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// One serving measurement.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    pub label: String,
    pub batch: usize,
    pub distinct_adapters: usize,
    pub new_tokens: usize,
    pub requests: usize,
    pub wall_secs: f64,
    /// Generated tokens per second (the paper's throughput axis).
    pub tokens_per_sec: f64,
    pub decode_steps: usize,
    /// Time spent inside decode executions (see
    /// [`ServingPoint::ms_per_step`]; the KV residency comparison's axis).
    pub decode_secs: f64,
    /// Adapter-bank paging counters (the bank study's axes).
    pub bank_hits: usize,
    pub bank_misses: usize,
    pub bank_evictions: usize,
    pub bank_upload_bytes: usize,
}

impl ServingPoint {
    /// Mean decode-step cost in milliseconds; `None` when the run never
    /// decoded (e.g. every request finished at prefill).
    pub fn ms_per_step(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| self.decode_secs * 1e3 / self.decode_steps as f64)
    }
}

/// Build a heterogeneous workload: `n_requests` requests over
/// `distinct` registered adapters (round-robin), each generating
/// `new_tokens` tokens from a short prompt.
pub fn hetero_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if distinct > 0 {
                r = r.with_adapter(&format!("adapter-{}", i % distinct));
            }
            r
        })
        .collect()
}

/// Sample from a Zipf(s) distribution over ranks `0..n` (rank 0 most
/// popular): the canonical popularity skew for per-user adapter traffic —
/// a few hot adapters dominate while a long tail stays cold, which is the
/// regime an LRU-paged bank exploits.
pub fn zipf_sample(rng: &mut Rng, n: usize, s: f64) -> usize {
    rng.weighted(&zipf_weights(n, s))
}

/// Unnormalized Zipf(s) weights over ranks `0..n` (precompute once when
/// sampling repeatedly — [`zipf_workload`] does).
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    assert!(n > 0, "zipf distribution needs at least one rank");
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
}

/// Build an adapter-churn workload: `n_requests` requests over `distinct`
/// registered adapters with a Zipf(s)-distributed request→adapter
/// assignment (instead of [`hetero_workload`]'s uniform round-robin).
pub fn zipf_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    zipf_s: f64,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    let weights = (distinct > 0).then(|| zipf_weights(distinct, zipf_s));
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> = (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if let Some(w) = &weights {
                let k = rng.weighted(w);
                r = r.with_adapter(&format!("adapter-{k}"));
            }
            r
        })
        .collect()
}

/// Register `distinct` random adapters of the engine's mode.
pub fn register_adapters(engine: &mut Engine, distinct: usize, seed: u64) -> Result<()> {
    let mut rng = Rng::seed_from(seed);
    for i in 0..distinct {
        let adapter = match engine.econf.mode.as_str() {
            "road" => Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.2)),
            "lora" => Adapter::Lora(LoraAdapter::random(&engine.cfg, &mut rng, 0.05)),
            m => anyhow::bail!("no random adapter generator for mode {m}"),
        };
        engine.register_adapter(&format!("adapter-{i}"), &adapter)?;
    }
    Ok(())
}

/// Run one serving measurement: fresh engine in `mode`, `distinct`
/// adapters, `n_requests` requests × `new_tokens` tokens.
pub fn measure_serving(
    rt: &Rc<Runtime>,
    model: &str,
    mode: &str,
    slots: usize,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let econf = EngineConfig {
        model: model.into(),
        mode: mode.into(),
        decode_slots: slots,
        queue_capacity: 4096,
        ..Default::default()
    };
    measure_serving_cfg(rt, econf, distinct, n_requests, new_tokens, seed)
}

/// Like [`measure_serving`], but over an explicit engine config — the KV
/// residency comparison uses this to flip `kv_host_roundtrip` with
/// everything else held fixed.
pub fn measure_serving_cfg(
    rt: &Rc<Runtime>,
    econf: EngineConfig,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let mode = econf.mode.clone();
    let mut engine = Engine::new(rt.clone(), econf)?;
    if distinct > 0 {
        register_adapters(&mut engine, distinct, seed)?;
    }
    let mut rng = Rng::seed_from(seed ^ 0xbe7c);
    let prompt_len = 8;
    let reqs = hetero_workload(&mut rng, n_requests, distinct, prompt_len, new_tokens);
    run_workload(&mut engine, &format!("{mode}/d{distinct}"), distinct, new_tokens, reqs)
}

/// Drive `reqs` to completion on `engine` and package the measurement.
fn run_workload(
    engine: &mut Engine,
    label: &str,
    distinct: usize,
    new_tokens: usize,
    reqs: Vec<Request>,
) -> Result<ServingPoint> {
    let n_requests = reqs.len();
    let t0 = std::time::Instant::now();
    let outs = engine.run_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let gen_tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    Ok(ServingPoint {
        label: label.to_string(),
        batch: engine.econf.decode_slots,
        distinct_adapters: distinct,
        new_tokens,
        requests: n_requests,
        wall_secs: wall,
        tokens_per_sec: gen_tokens as f64 / wall,
        decode_steps: engine.metrics.decode_steps,
        decode_secs: engine.metrics.decode_time.as_secs_f64(),
        bank_hits: engine.metrics.bank_hits,
        bank_misses: engine.metrics.bank_misses,
        bank_evictions: engine.metrics.bank_evictions,
        bank_upload_bytes: engine.metrics.bank_upload_bytes,
    })
}

/// The adapter-churn study: `n_adapters` registered adapters paged through
/// a `bank_slots`-slot device bank (adapters ≫ slots) under a Zipf(1.1)
/// request mix, measured with paged per-slot uploads vs the whole-bank
/// re-upload baseline.  Every request must complete — registration can no
/// longer fail on capacity, and eviction never touches a pinned slot.
pub fn bank_churn_study(
    rt: &Rc<Runtime>,
    n_adapters: usize,
    bank_slots: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, paged) in [("road/paged-bank", true), ("road/whole-bank-upload", false)] {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            bank_slots: Some(bank_slots),
            paged_bank_uploads: paged,
            ..Default::default()
        };
        let mut engine = Engine::new(rt.clone(), econf)?;
        register_adapters(&mut engine, n_adapters, seed)?;
        let mut rng = Rng::seed_from(seed ^ 0x21f7);
        let reqs = zipf_workload(&mut rng, n_requests, n_adapters, 1.1, 8, new_tokens);
        out.push(run_workload(&mut engine, label, n_adapters, new_tokens, reqs)?);
    }
    Ok(out)
}

/// Device-resident vs host-round-trip decode on an otherwise identical
/// heterogeneous workload (batch 8, road mode).  The second point is the
/// pre-refactor baseline that moved the full K/V cache host↔device every
/// step; `decode_secs / decode_steps` is the per-step cost to compare.
pub fn kv_residency_comparison(
    rt: &Rc<Runtime>,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, kv_host_roundtrip) in
        [("road/device-resident", false), ("road/host-roundtrip", true)]
    {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            kv_host_roundtrip,
            ..Default::default()
        };
        let mut p = measure_serving_cfg(rt, econf, 8, 16, new_tokens, seed)?;
        p.label = label.into();
        out.push(p);
    }
    Ok(out)
}

/// One streaming-serving measurement (the open-loop study's row).
#[derive(Clone, Debug)]
pub struct StreamingPoint {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub cancelled: usize,
    /// Requests that never reached a `Finished` event (submit rejected or
    /// stream ended in `Error`) — kept out of `completed` so the
    /// run-to-completion vs cancel comparison stays honest.
    pub errored: usize,
    /// Token events observed client-side across all requests.
    pub tokens_streamed: usize,
    pub wall_secs: f64,
    /// Client-observed TTFT (submit call → first `Token` event received),
    /// in milliseconds — the latency a real caller sees through the
    /// channel, not the engine's internal stamp.
    pub observed_ttft_p50_ms: f64,
    pub observed_ttft_p90_ms: f64,
}

/// Open-loop streaming study over the threaded server: clients submit on
/// an arrival clock (independent of completions), consume `StreamEvent`s,
/// and measure *observed* TTFT.  The second scenario cancels every other
/// request after `cancel_after` observed tokens — the cancellation-reclaim
/// comparison: reclaimed decode lanes shrink wall time and streamed-token
/// volume versus running every request to completion.
pub fn streaming_study(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    n_requests: usize,
    new_tokens: usize,
    cancel_after: usize,
    seed: u64,
) -> Result<Vec<StreamingPoint>> {
    use crate::coordinator::request::StreamEvent;
    use crate::coordinator::server::EngineServer;

    let distinct = 8usize;
    let mut out = Vec::new();
    for (label, cancel_half) in [
        ("stream/run-to-completion", false),
        ("stream/cancel-half", true),
    ] {
        let econf = EngineConfig {
            model: model.into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            ..Default::default()
        };
        let (server, client) = EngineServer::start(econf, artifacts_dir.clone(), move |eng| {
            register_adapters(eng, distinct, seed)
        })?;
        let mut rng = Rng::seed_from(seed ^ 0x57e4);
        let reqs = hetero_workload(&mut rng, n_requests, distinct, 8, new_tokens);

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for (i, req) in reqs.into_iter().enumerate() {
            let client = client.clone();
            let cancel_at = (cancel_half && i % 2 == 1).then_some(cancel_after);
            // Per-request terminal outcome: Some(true) = cancelled,
            // Some(false) = completed, None = submit rejected or the
            // stream ended in an Error event.
            handles.push(std::thread::spawn(move || -> (Option<f64>, usize, Option<bool>) {
                // Open-loop arrival clock: request i enters at i*2ms
                // whether or not earlier requests have finished.
                std::thread::sleep(std::time::Duration::from_millis(2 * i as u64));
                let submitted = std::time::Instant::now();
                let Ok(mut generation) = client.submit(req) else {
                    return (None, 0, None);
                };
                let mut ttft = None;
                let mut seen = 0usize;
                let mut cancel_sent = false;
                let mut outcome = None;
                while let Some(ev) = generation.recv() {
                    match ev {
                        StreamEvent::Token { .. } => {
                            ttft.get_or_insert_with(|| submitted.elapsed().as_secs_f64());
                            seen += 1;
                            if !cancel_sent && cancel_at.is_some_and(|k| seen >= k) {
                                generation.cancel();
                                cancel_sent = true;
                            }
                        }
                        StreamEvent::Finished(o) => {
                            let c = crate::coordinator::request::FinishReason::Cancelled;
                            outcome = Some(o.finish == c);
                            break;
                        }
                        StreamEvent::Error { .. } => break,
                        StreamEvent::Admitted { .. } => {}
                    }
                }
                (ttft, seen, outcome)
            }));
        }
        let mut ttfts_ms = Vec::new();
        let (mut completed, mut cancelled, mut errored) = (0usize, 0usize, 0usize);
        let mut tokens_streamed = 0usize;
        for h in handles {
            let (ttft, seen, outcome) = h.join().expect("client thread panicked");
            if let Some(t) = ttft {
                ttfts_ms.push(t * 1e3);
            }
            tokens_streamed += seen;
            match outcome {
                Some(true) => cancelled += 1,
                Some(false) => completed += 1,
                None => errored += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        server.shutdown()?;
        let s = crate::util::stats::summarize(&ttfts_ms);
        out.push(StreamingPoint {
            label: label.into(),
            requests: n_requests,
            completed,
            cancelled,
            errored,
            tokens_streamed,
            wall_secs: wall,
            observed_ttft_p50_ms: s.p50,
            observed_ttft_p90_ms: s.p90,
        });
    }
    Ok(out)
}

/// Render the streaming study; the cancel row's smaller streamed-token
/// volume and wall time are the reclaim the study exists to show.
pub fn render_streaming_points(title: &str, points: &[StreamingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "reqs", "completed", "cancelled", "errored", "tok-streamed", "wall(s)",
        "obs-ttft p50(ms)", "obs-ttft p90(ms)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.requests.to_string(),
            p.completed.to_string(),
            p.cancelled.to_string(),
            p.errored.to_string(),
            p.tokens_streamed.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.observed_ttft_p50_ms, 1),
            fmt_f(p.observed_ttft_p90_ms, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nobs-ttft is measured at the client (submit call → first Token \
         event through the channel); cancelled lanes are reclaimed for waiting work, \
         which is the wall/token delta between the rows.\n",
        t.render()
    )
}

/// Figure 4 (Left): merged vs unmerged LoRA.  The merged path is the base
/// model (adapter folded into W, paper §4.2); the unmerged path pays the
/// per-layer bmm epilogue.  Rank is compile-time-fixed in the artifacts,
/// so the sweep axis here is the serving mode; the rank effect is covered
/// by the adapter_ops microbench.
pub fn fig4_left(rt: &Rc<Runtime>, new_tokens: usize, seed: u64) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    // batch 1, single adapter — the paper's configuration.
    let mut merged = measure_serving(rt, "serve", "base", 1, 0, 4, new_tokens, seed)?;
    merged.label = "lora-merged(base)".into();
    out.push(merged);
    let mut unmerged = measure_serving(rt, "serve", "lora", 1, 1, 4, new_tokens, seed)?;
    unmerged.label = "lora-unmerged".into();
    out.push(unmerged);
    let mut road = measure_serving(rt, "serve", "road", 1, 1, 4, new_tokens, seed)?;
    road.label = "road-unmerged".into();
    out.push(road);
    Ok(out)
}

/// Figure 4 (Middle): throughput vs #generated tokens at batch 8, eight
/// distinct adapters (fully heterogeneous).
pub fn fig4_middle(
    rt: &Rc<Runtime>,
    token_counts: &[usize],
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &nt in token_counts {
        for mode in ["road", "lora"] {
            let mut p = measure_serving(rt, "serve", mode, 8, 8, 16, nt, seed)?;
            p.label = format!("{mode}/t{nt}");
            out.push(p);
        }
    }
    Ok(out)
}

/// Figure 4 (Right): throughput vs #distinct adapters at batch 8.
pub fn fig4_right(
    rt: &Rc<Runtime>,
    distinct_counts: &[usize],
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &d in distinct_counts {
        for mode in ["road", "lora"] {
            out.push(measure_serving(rt, "serve", mode, 8, d, 16, new_tokens, seed)?);
        }
    }
    Ok(out)
}

/// Render the bank-churn study with its paging counters; the `upload(KB)`
/// column is the comparison the study exists for (paged rows strictly
/// below the whole-bank baseline).
pub fn render_bank_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "reqs", "tok/s", "hits", "misses", "evictions",
        "upload(KB)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.requests.to_string(),
            fmt_f(p.tokens_per_sec, 1),
            p.bank_hits.to_string(),
            p.bank_misses.to_string(),
            p.bank_evictions.to_string(),
            fmt_f(p.bank_upload_bytes as f64 / 1e3, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nupload(KB) is the comparison axis (host-to-device bank traffic). \
         On the offline stub, paged wall-time additionally pays the device-side scatter \
         stand-in (see AdapterBank::upload_dirty), so tok/s there favors no side.\n",
        t.render()
    )
}

pub fn render_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "new-toks", "reqs", "wall(s)", "tok/s", "ms/step",
    ]);
    for p in points {
        let ms_per_step = p.ms_per_step().unwrap_or(0.0);
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.new_tokens.to_string(),
            p.requests.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.tokens_per_sec, 1),
            fmt_f(ms_per_step, 3),
        ]);
    }
    format!("## {title}\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Table D.1: finetuning efficiency (RoAd vs OFT Cayley)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct TrainEfficiency {
    pub method: String,
    pub n_trainable: usize,
    pub iters: usize,
    pub wall_secs: f64,
    pub secs_per_step: f64,
    /// Trainable + AdamW state footprint in bytes (the part that scales
    /// with the method; the paper's "peak GPU memory" analogue on a
    /// host-state basis).
    pub state_bytes: usize,
}

/// Time `iters` optimizer steps of `method` on random LM batches.
pub fn measure_train_efficiency(
    rt: &Rc<Runtime>,
    config: &str,
    method: &str,
    iters: usize,
    seed: u64,
) -> Result<TrainEfficiency> {
    let mut tr = Trainer::new(rt.clone(), config, method)?;
    let (b, l) = (tr.batch, tr.seq_len);
    let mut rng = Rng::seed_from(seed);
    let recipe = Recipe::default().with_steps(iters);

    // Warm-up step excluded from timing (compile/caches).
    let mk = |rng: &mut Rng| -> TrainBatch {
        let tokens: Vec<i32> = (0..b * l).map(|_| 1 + rng.below(255) as i32).collect();
        let mut targets = vec![0i32; b * l];
        for row in 0..b {
            for p in 0..l - 1 {
                targets[row * l + p] = tokens[row * l + p + 1];
            }
        }
        TrainBatch { tokens, targets, mask: vec![1.0; b * l] }
    };
    let warm = mk(&mut rng);
    tr.step(&warm, recipe.lr_at(0))?;

    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let batch = mk(&mut rng);
        tr.step(&batch, recipe.lr_at(i))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let state_bytes = tr.n_trainable * 4 * 3; // params + m + v
    Ok(TrainEfficiency {
        method: method.to_string(),
        n_trainable: tr.n_trainable,
        iters,
        wall_secs: wall,
        secs_per_step: wall / iters as f64,
        state_bytes,
    })
}

pub fn render_train_efficiency(rows: &[TrainEfficiency]) -> String {
    let mut t = Table::new(&[
        "method", "#trainable", "iters", "wall(s)", "s/step", "state(KB)",
    ]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            r.n_trainable.to_string(),
            r.iters.to_string(),
            fmt_f(r.wall_secs, 2),
            fmt_f(r.secs_per_step, 4),
            fmt_f(r.state_bytes as f64 / 1024.0, 1),
        ]);
    }
    format!("## Table D.1 analogue: finetuning efficiency\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_round_robins_adapters() {
        let mut rng = Rng::seed_from(1);
        let reqs = hetero_workload(&mut rng, 8, 4, 8, 16);
        assert_eq!(reqs.len(), 8);
        assert_eq!(reqs[0].adapter.as_deref(), Some("adapter-0"));
        assert_eq!(reqs[5].adapter.as_deref(), Some("adapter-1"));
        assert!(reqs.iter().all(|r| r.prompt.len() == 8));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t > 0)));
    }

    #[test]
    fn workload_without_adapters_is_base() {
        let mut rng = Rng::seed_from(2);
        let reqs = hetero_workload(&mut rng, 3, 0, 4, 8);
        assert!(reqs.iter().all(|r| r.adapter.is_none()));
    }

    #[test]
    fn render_produces_rows() {
        let p = ServingPoint {
            label: "road/d8".into(),
            batch: 8,
            distinct_adapters: 8,
            new_tokens: 128,
            requests: 16,
            wall_secs: 1.5,
            tokens_per_sec: 1365.3,
            decode_steps: 256,
            decode_secs: 1.28,
            bank_hits: 12,
            bank_misses: 4,
            bank_evictions: 1,
            bank_upload_bytes: 8192,
        };
        let s = render_points("Fig 4 (Right)", &[p.clone()]);
        assert!(s.contains("road/d8"));
        assert!(s.contains("1365.3"));
        let b = render_bank_points("Bank churn", &[p]);
        assert!(b.contains("hits"), "{b}");
        assert!(b.contains("12"), "{b}");
        assert!(b.contains("8.2"), "upload KB column: {b}");
    }

    #[test]
    fn render_streaming_table_has_reclaim_columns() {
        let p = StreamingPoint {
            label: "stream/cancel-half".into(),
            requests: 16,
            completed: 7,
            cancelled: 8,
            errored: 1,
            tokens_streamed: 512,
            wall_secs: 2.5,
            observed_ttft_p50_ms: 12.5,
            observed_ttft_p90_ms: 31.0,
        };
        let s = render_streaming_points("Streaming", &[p]);
        for needle in ["cancelled", "errored", "tok-streamed", "obs-ttft p50(ms)", "12.5", "512"] {
            assert!(s.contains(needle), "missing {needle:?} in\n{s}");
        }
    }

    #[test]
    fn zipf_workload_skews_to_head_adapters() {
        let mut rng = Rng::seed_from(5);
        let n = 64;
        let reqs = zipf_workload(&mut rng, 512, n, 1.1, 8, 16);
        assert_eq!(reqs.len(), 512);
        let mut counts = vec![0usize; n];
        for r in &reqs {
            let name = r.adapter.as_deref().unwrap();
            let k: usize = name.strip_prefix("adapter-").unwrap().parse().unwrap();
            counts[k] += 1;
        }
        let head: usize = counts[..4].iter().sum();
        let tail: usize = counts[n - 4..].iter().sum();
        assert!(head > tail * 4, "zipf head {head} should dominate tail {tail}");
        // Rank 0 is the most popular adapter.
        let max = counts.iter().copied().max().unwrap();
        assert_eq!(counts[0], max, "{counts:?}");
    }

    #[test]
    fn zipf_sample_in_range_and_deterministic() {
        let mut a = Rng::seed_from(9);
        let mut b = Rng::seed_from(9);
        for _ in 0..200 {
            let x = zipf_sample(&mut a, 7, 1.0);
            assert!(x < 7);
            assert_eq!(x, zipf_sample(&mut b, 7, 1.0));
        }
    }
}
