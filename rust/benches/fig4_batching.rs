//! Figure 4 bench: end-to-end heterogeneous-batching throughput sweeps
//! (merged vs unmerged; vs #generated tokens; vs #distinct adapters).
//!
//! Plain `harness = false` binary (no criterion in the offline image):
//! each point is a full engine run; results print as the paper's series.
//!
//! ```bash
//! cargo bench --bench fig4_batching            # all three panels
//! cargo bench --bench fig4_batching -- quick   # reduced sweep
//! ```

use std::rc::Rc;

use road::bench;
use road::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    let seed = 7;

    let tokens = if quick { 24 } else { 64 };
    println!("# Figure 4 (Left): merged vs unmerged, batch 1, {tokens} tokens");
    let pts = bench::fig4_left(&rt, tokens, seed)?;
    println!("{}", bench::render_points("fig4-left", &pts));

    let counts: Vec<usize> = if quick { vec![16, 48] } else { vec![16, 32, 64, 128] };
    println!("# Figure 4 (Middle): throughput vs #generated tokens (batch 8, 8 adapters)");
    let pts = bench::fig4_middle(&rt, &counts, seed)?;
    println!("{}", bench::render_points("fig4-middle", &pts));
    summarize_ratio(&pts);

    let distinct: Vec<usize> = if quick { vec![1, 8] } else { vec![1, 2, 4, 8] };
    println!("# Figure 4 (Right): throughput vs #distinct adapters (batch 8, {tokens} tokens)");
    let pts = bench::fig4_right(&rt, &distinct, tokens, seed)?;
    println!("{}", bench::render_points("fig4-right", &pts));
    summarize_ratio(&pts);
    Ok(())
}

/// Print the road/lora throughput ratio per matched sweep point — the
/// paper's headline "2x LoRA" claim, on this substrate.
fn summarize_ratio(pts: &[road::bench::ServingPoint]) {
    for pair in pts.chunks(2) {
        if pair.len() == 2 {
            let (road, lora) = (&pair[0], &pair[1]);
            println!(
                "  ratio @ (d={}, t={}): road/lora = {:.2}x",
                road.distinct_adapters,
                road.new_tokens,
                road.tokens_per_sec / lora.tokens_per_sec
            );
        }
    }
}
