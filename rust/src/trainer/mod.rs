//! The PEFT trainer: drives `train_<method>_<cfg>` HLO step graphs in a
//! loop, owning the optimizer state and the learning-rate schedule.
//!
//! Training is part of the reproduced system (Tables 2–6, Figure 2/5,
//! Table D.1): the fwd+bwd+AdamW step is a single AOT-lowered XLA
//! computation; this module feeds it batches, recycles the returned
//! (trainable, m, v) state, and exports the result as a serving
//! [`Adapter`] or a merged [`ParamStore`].
//!
//! Python never runs here — the step graph was lowered once by
//! `python/compile/aot.py`.

pub mod loop_;
pub mod recipe;

pub use loop_::{train, TrainReport};
pub use recipe::{linear_lr, Recipe};

use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::adapters::{Adapter, Ia3Adapter, LoraAdapter, RoadAdapter};
use crate::manifest::ModelConfigInfo;
use crate::model::ParamStore;
use crate::runtime::{Arg, Executable, Runtime};
use crate::tensor::{dump_flat, load_flat_f32, DType, HostTensor};

/// One training micro-batch in the fixed train-bucket shape.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    /// [B, L] input tokens (flattened row-major).
    pub tokens: Vec<i32>,
    /// [B, L] next-token targets.
    pub targets: Vec<i32>,
    /// [B, L] loss mask (1.0 = counted).
    pub mask: Vec<f32>,
}

impl TrainBatch {
    pub fn zeros(b: usize, l: usize) -> TrainBatch {
        TrainBatch { tokens: vec![0; b * l], targets: vec![0; b * l], mask: vec![0.0; b * l] }
    }
}

/// A PEFT trainer bound to one (config, method) step graph.
pub struct Trainer {
    pub rt: Rc<Runtime>,
    pub cfg: ModelConfigInfo,
    pub method: String,
    pub batch: usize,
    pub seq_len: usize,
    /// Number of trainable scalars (the paper's #Params axis).
    pub n_trainable: usize,
    train_exe: Rc<Executable>,
    /// Frozen backbone, device-resident (uploaded once). Empty for "full".
    frozen: Option<ParamStore>,
    frozen_bufs: BTreeMap<String, xla::PjRtBuffer>,
    /// Current trainable values in manifest flattening (sorted-key) order.
    trainable: Vec<(String, HostTensor)>,
    opt_m: Vec<HostTensor>,
    opt_v: Vec<HostTensor>,
    /// Element-wise gradient masks (road1_masked / composability only).
    grad_mask: Option<Vec<HostTensor>>,
    pub steps_done: usize,
    pub loss_history: Vec<f32>,
    pub step_time: Duration,
}

impl Trainer {
    /// Build a trainer with the pretrained backbone + identity-init
    /// trainables from the artifact dumps.
    pub fn new(rt: Rc<Runtime>, config: &str, method: &str) -> Result<Trainer> {
        let backbone = ParamStore::load_pretrained(&rt.manifest, config)?;
        if method == "full" {
            // Full finetuning: the backbone itself is the trainable set.
            let trainable: Vec<(String, HostTensor)> = backbone
                .names
                .iter()
                .cloned()
                .zip(backbone.tensors.iter().cloned())
                .collect();
            return Trainer::with_state(rt, config, method, None, trainable);
        }
        let mut trainable = load_trainable_init(&rt.manifest, config, method)?;
        // Methods whose trainables are slices of the backbone (bitfit's
        // biases/norm scales) must start from the *pretrained* values, not
        // the dump taken at random init.
        for (name, t) in trainable.iter_mut() {
            if let Ok(src) = backbone.get(name) {
                *t = src.clone();
            }
        }
        Trainer::with_state(rt, config, method, Some(backbone), trainable)
    }

    /// Build over explicit state (resume / warm-start / custom backbone).
    pub fn with_state(
        rt: Rc<Runtime>,
        config: &str,
        method: &str,
        frozen: Option<ParamStore>,
        trainable: Vec<(String, HostTensor)>,
    ) -> Result<Trainer> {
        let cfg = rt.manifest.config(config)?.clone();
        let entry = format!("train_{method}_{config}");
        let train_exe =
            rt.load(&entry).with_context(|| format!("loading train entry {entry}"))?;
        let info = train_exe.info.clone();
        let batch = info.batch.ok_or_else(|| anyhow!("train entry lacks batch"))?;
        let seq_len = info.seq_len.unwrap_or(0);

        // Validate the trainable list against the entry signature.
        let (ts, te) = info.group_range("trainable");
        if te - ts != trainable.len() {
            bail!("{entry}: {} trainables supplied, signature has {}", trainable.len(), te - ts);
        }
        for (spec, (name, t)) in info.inputs[ts..te].iter().zip(&trainable) {
            if &spec.name != name || spec.shape != t.shape {
                bail!("{entry}: trainable mismatch at {} vs {}", spec.name, name);
            }
        }
        let n_trainable = trainable.iter().map(|(_, t)| t.elem_count()).sum();

        let (fs, fe) = info.group_range("frozen");
        let mut frozen_bufs = BTreeMap::new();
        if fe > fs {
            let store = frozen
                .as_ref()
                .ok_or_else(|| anyhow!("{entry} expects frozen params but none supplied"))?;
            for spec in &info.inputs[fs..fe] {
                frozen_bufs.insert(spec.name.clone(), rt.upload(store.get(&spec.name)?)?);
            }
        }

        let opt_m: Vec<HostTensor> =
            trainable.iter().map(|(_, t)| HostTensor::zeros(t.shape.clone(), DType::F32)).collect();
        let opt_v = opt_m.clone();
        let (gs, ge) = info.group_range("grad_mask");
        let grad_mask = if ge > gs {
            Some(
                trainable
                    .iter()
                    .map(|(_, t)| HostTensor::f32(t.shape.clone(), vec![1.0; t.elem_count()]))
                    .collect(),
            )
        } else {
            None
        };

        Ok(Trainer {
            rt,
            cfg,
            method: method.to_string(),
            batch,
            seq_len,
            n_trainable,
            train_exe,
            frozen,
            frozen_bufs,
            trainable,
            opt_m,
            opt_v,
            grad_mask,
            steps_done: 0,
            loss_history: Vec::new(),
            step_time: Duration::default(),
        })
    }

    pub fn trainable(&self) -> &[(String, HostTensor)] {
        &self.trainable
    }

    pub fn set_trainable(&mut self, named: Vec<(String, HostTensor)>) -> Result<()> {
        if named.len() != self.trainable.len() {
            bail!("trainable count mismatch");
        }
        for ((n0, t0), (n1, t1)) in self.trainable.iter().zip(&named) {
            if n0 != n1 || t0.shape != t1.shape {
                bail!("trainable mismatch at {n0} vs {n1}");
            }
        }
        self.trainable = named;
        Ok(())
    }

    pub fn frozen(&self) -> Option<&ParamStore> {
        self.frozen.as_ref()
    }

    /// Set the per-tensor element-wise gradient mask (road1_masked only):
    /// `f(name, flat_index) -> keep?`. This is the composability experiment's
    /// subspace partitioning (Fig 5): disjoint 2×2 blocks of R are trained
    /// on different tasks by masking the complementary blocks' gradients.
    pub fn set_grad_mask(&mut self, f: impl Fn(&str, usize) -> bool) -> Result<()> {
        let masks = self
            .grad_mask
            .as_mut()
            .ok_or_else(|| anyhow!("method {} has no grad_mask input", self.method))?;
        for ((name, t), m) in self.trainable.iter().zip(masks.iter_mut()) {
            let vals: Vec<f32> =
                (0..t.elem_count()).map(|i| if f(name, i) { 1.0 } else { 0.0 }).collect();
            *m = HostTensor::f32(t.shape.clone(), vals);
        }
        Ok(())
    }

    /// One AdamW step on `batch` at learning rate `lr`; returns the loss.
    pub fn step(&mut self, batch: &TrainBatch, lr: f32) -> Result<f32> {
        let (b, l) = (self.batch, self.seq_len);
        if batch.tokens.len() != b * l {
            bail!("batch size mismatch: {} vs {}x{}", batch.tokens.len(), b, l);
        }
        let step_no = (self.steps_done + 1) as f32;
        let step_t = HostTensor::scalar_f32(step_no);
        let lr_t = HostTensor::scalar_f32(lr);
        let tokens = HostTensor::i32(vec![b, l], batch.tokens.clone());
        let targets = HostTensor::i32(vec![b, l], batch.targets.clone());
        let mask = HostTensor::f32(vec![b, l], batch.mask.clone());

        let info = self.train_exe.info.clone();
        let mut args: Vec<Arg> = Vec::with_capacity(info.inputs.len());
        let mut ti = 0usize;
        let mut mi = 0usize;
        let mut vi = 0usize;
        let mut gi = 0usize;
        for spec in &info.inputs {
            match spec.group.as_str() {
                "frozen" => args.push(Arg::Buffer(
                    self.frozen_bufs
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("missing frozen buffer {}", spec.name))?,
                )),
                "trainable" => {
                    args.push(Arg::Host(&self.trainable[ti].1));
                    ti += 1;
                }
                "opt_m" => {
                    args.push(Arg::Host(&self.opt_m[mi]));
                    mi += 1;
                }
                "opt_v" => {
                    args.push(Arg::Host(&self.opt_v[vi]));
                    vi += 1;
                }
                "grad_mask" => {
                    let gm = self.grad_mask.as_ref().unwrap();
                    args.push(Arg::Host(&gm[gi]));
                    gi += 1;
                }
                "data" => args.push(Arg::Host(match spec.name.as_str() {
                    "step" => &step_t,
                    "lr" => &lr_t,
                    "tokens" => &tokens,
                    "targets" => &targets,
                    "mask" => &mask,
                    other => bail!("unexpected train data input {other}"),
                })),
                g => bail!("unexpected input group {g} in {}", info.name),
            }
        }

        // roadlint: allow(clock-discipline) -- accumulates real step time
        // for the training-efficiency report.
        let t0 = Instant::now();
        let outs = self.train_exe.run(&args)?;
        self.step_time += t0.elapsed();

        let nt = self.trainable.len();
        if outs.len() != 3 * nt + 1 {
            bail!("train step returned {} outputs, expected {}", outs.len(), 3 * nt + 1);
        }
        let mut it = outs.into_iter();
        for i in 0..nt {
            self.trainable[i].1 = it.next().unwrap();
        }
        for m in self.opt_m.iter_mut() {
            *m = it.next().unwrap();
        }
        for v in self.opt_v.iter_mut() {
            *v = it.next().unwrap();
        }
        let loss = it.next().unwrap().f32_at(0);
        self.steps_done += 1;
        self.loss_history.push(loss);
        Ok(loss)
    }

    /// Evaluate mean + per-example NLL on a batch through the
    /// `eval_loss_<method>_<cfg>` graph.
    pub fn eval_loss(&self, batch: &TrainBatch) -> Result<(Vec<f32>, f32)> {
        let name = format!("eval_loss_{}_{}", self.eval_method(), self.cfg.name);
        let exe = self.rt.load(&name)?;
        let (b, l) = (self.batch, self.seq_len);
        let tokens = HostTensor::i32(vec![b, l], batch.tokens.clone());
        let targets = HostTensor::i32(vec![b, l], batch.targets.clone());
        let mask = HostTensor::f32(vec![b, l], batch.mask.clone());
        let data: Vec<(&str, &HostTensor)> =
            vec![("tokens", &tokens), ("targets", &targets), ("mask", &mask)];
        let outs = self.run_eval(&exe, &data)?;
        let per_ex = outs[0].as_f32();
        let total = outs[1].f32_at(0);
        Ok((per_ex, total))
    }

    /// Vocab logits at each example's last valid position (classification
    /// eval). `tokens` is [B, L] flattened, `lengths` per-example.
    pub fn last_logits(&self, tokens: &[i32], lengths: &[i32]) -> Result<HostTensor> {
        let name = format!("last_logits_{}_{}", self.eval_method(), self.cfg.name);
        let exe = self.rt.load(&name)?;
        let (b, l) = (self.batch, self.seq_len);
        if tokens.len() != b * l || lengths.len() != b {
            bail!("last_logits input shape mismatch");
        }
        let tok = HostTensor::i32(vec![b, l], tokens.to_vec());
        let len = HostTensor::i32(vec![b], lengths.to_vec());
        let data: Vec<(&str, &HostTensor)> = vec![("tokens", &tok), ("lengths", &len)];
        let mut outs = self.run_eval(&exe, &data)?;
        Ok(outs.remove(0))
    }

    /// road1_masked trains through its own graph but evaluates through
    /// road1's (identical forward; no grad_mask input there).
    fn eval_method(&self) -> &str {
        if self.method == "road1_masked" {
            "road1"
        } else {
            &self.method
        }
    }

    /// Shared eval-arg assembly: frozen buffers + current trainables + data.
    fn run_eval(&self, exe: &Executable, data: &[(&str, &HostTensor)]) -> Result<Vec<HostTensor>> {
        let info = &exe.info;
        let mut args: Vec<Arg> = Vec::with_capacity(info.inputs.len());
        let mut ti = 0usize;
        for spec in &info.inputs {
            match spec.group.as_str() {
                "frozen" => args.push(Arg::Buffer(
                    self.frozen_bufs
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("missing frozen buffer {}", spec.name))?,
                )),
                "trainable" => {
                    if self.trainable[ti].0 != spec.name {
                        bail!("eval trainable order mismatch at {}", spec.name);
                    }
                    args.push(Arg::Host(&self.trainable[ti].1));
                    ti += 1;
                }
                "data" => {
                    let t = data
                        .iter()
                        .find(|(n, _)| *n == spec.name)
                        .map(|(_, t)| *t)
                        .ok_or_else(|| anyhow!("missing eval data {}", spec.name))?;
                    args.push(Arg::Host(t));
                }
                g => bail!("unexpected eval input group {g}"),
            }
        }
        exe.run(&args)
    }

    /// Export the trained state as a serving adapter (road/lora/ia3 only).
    pub fn export_adapter(&self) -> Result<Adapter> {
        match self.method.as_str() {
            m if m.starts_with("road") => {
                let variant = match m {
                    "road2" => 2,
                    "road4" => 4,
                    _ => 1,
                };
                Ok(Adapter::Road(RoadAdapter::from_trainable(variant, &self.trainable)?))
            }
            "lora" => Ok(Adapter::Lora(LoraAdapter::from_trainable(&self.trainable)?)),
            "ia3" => {
                let mut a = Ia3Adapter::identity(&self.cfg);
                for (name, t) in &self.trainable {
                    if let Some(base) = name.strip_suffix(".s") {
                        a.per_proj.insert(base.to_string(), t.as_f32());
                    }
                }
                Ok(Adapter::Ia3(a))
            }
            m => bail!("method {m} does not export a serving adapter"),
        }
    }

    /// Produce a merged, serving-ready parameter store (paper §3.2:
    /// zero-overhead inference after folding the adapter into W⁰).
    pub fn merged_params(&self) -> Result<ParamStore> {
        match self.method.as_str() {
            "full" => Ok(ParamStore::from_tensors(self.cfg.clone(), self.trainable.clone())),
            "bitfit" => {
                let mut store =
                    self.frozen.clone().ok_or_else(|| anyhow!("bitfit needs frozen params"))?;
                for (name, t) in &self.trainable {
                    store.set(name, t.clone())?;
                }
                Ok(store)
            }
            m if m.starts_with("road") => {
                let mut store =
                    self.frozen.clone().ok_or_else(|| anyhow!("road needs frozen params"))?;
                if let Adapter::Road(a) = self.export_adapter()? {
                    store.merge_road(&a)?;
                }
                Ok(store)
            }
            "lora" => {
                let mut store =
                    self.frozen.clone().ok_or_else(|| anyhow!("lora needs frozen params"))?;
                if let Adapter::Lora(a) = self.export_adapter()? {
                    store.merge_lora(&a)?;
                }
                Ok(store)
            }
            m => bail!("merge not supported for method {m}"),
        }
    }

    /// Save trainables (flat f32, manifest order) for later reload.
    pub fn save_trainable(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let refs: Vec<&HostTensor> = self.trainable.iter().map(|(_, t)| t).collect();
        std::fs::write(path, dump_flat(&refs))?;
        Ok(())
    }

    pub fn load_trainable(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let bytes = std::fs::read(path)?;
        let specs: Vec<(String, Vec<usize>)> =
            self.trainable.iter().map(|(n, t)| (n.clone(), t.shape.clone())).collect();
        self.trainable = load_flat_f32(&bytes, &specs)?;
        Ok(())
    }

    /// Reset optimizer state + step counter (fresh run, same weights).
    pub fn reset_optimizer(&mut self) {
        for t in self.opt_m.iter_mut().chain(self.opt_v.iter_mut()) {
            *t = HostTensor::zeros(t.shape.clone(), DType::F32);
        }
        self.steps_done = 0;
        self.loss_history.clear();
    }
}

/// Load a method's identity-preserving trainable init from the artifacts.
pub fn load_trainable_init(
    manifest: &crate::manifest::Manifest,
    config: &str,
    method: &str,
) -> Result<Vec<(String, HostTensor)>> {
    let entry = manifest.entry(&format!("train_{method}_{config}"))?;
    let (ts, te) = entry.group_range("trainable");
    let specs: Vec<(String, Vec<usize>)> =
        entry.inputs[ts..te].iter().map(|s| (s.name.clone(), s.shape.clone())).collect();
    // road1_masked shares road1's init dump.
    let file_method = if method == "road1_masked" { "road1" } else { method };
    let key = format!("{config}/{file_method}");
    let file = manifest
        .trainable_files
        .get(&key)
        .ok_or_else(|| anyhow!("no trainable init dump for {key}"))?;
    let bytes = std::fs::read(manifest.artifact_path(file))?;
    load_flat_f32(&bytes, &specs)
}

/// Train methods available in the artifact set for a config.
pub fn available_methods(manifest: &crate::manifest::Manifest, config: &str) -> Vec<String> {
    let suffix = format!("_{config}");
    manifest
        .entries
        .values()
        .filter(|e| e.kind == "train_step" && e.config == config)
        .filter_map(|e| {
            e.name.strip_prefix("train_").and_then(|s| s.strip_suffix(suffix.as_str()))
        })
        .map(String::from)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_batch_zeros_shapes() {
        let b = TrainBatch::zeros(2, 4);
        assert_eq!(b.tokens.len(), 8);
        assert_eq!(b.mask.len(), 8);
        assert!(b.mask.iter().all(|&m| m == 0.0));
    }
}
