//! Generic LRU slot paging and the paged KV block pool built on it.
//!
//! Two serving-side caches page fixed device capacity over unbounded
//! demand: the adapter bank (PR 2) pages registered adapters over
//! `n_slots` bank rows, and the paged KV cache pages token blocks over a
//! fixed block budget.  Both need the same mechanics — keyed residency,
//! pin counts that veto eviction, and least-recently-used victim
//! selection — so the mechanics live here once as [`LruPager`] and both
//! callers ([`crate::adapters::AdapterRegistry`] and [`BlockPool`])
//! compose it.
//!
//! # Block pool states
//!
//! Every block is in exactly one of three states at all times (the
//! conservation invariant the proptests pump):
//!
//! * **Free** — on the free list, available to any lane.
//! * **Private** — held by exactly one in-flight lane (its block table);
//!   never shared, never evicted, returned to Free exactly once when the
//!   lane is reaped.
//! * **Cached** — holds a published shared-prefix block, keyed by token
//!   hash in the pager; `refs` (= pager pins) counts in-flight lanes
//!   reading it.  Evictable by LRU only while `refs == 0`, so eviction
//!   can never touch a block a live lane depends on.
//!
//! Copy-on-write is by construction: admission *copies* cached block
//! contents into the hitting lane's contiguous region and takes a ref for
//! accounting, so the cached original is immutable for its whole life —
//! there is no write path to a Cached block, only publish (Private →
//! Cached) and evict (Cached → Free).

use std::borrow::Borrow;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One pageable slot: an optional resident key, a pin count (pinned slots
/// are never eviction victims), and an LRU stamp.
#[derive(Clone, Debug)]
struct PagerSlot<K> {
    key: Option<K>,
    pins: usize,
    last_used: u64,
}

impl<K> PagerSlot<K> {
    fn empty() -> PagerSlot<K> {
        PagerSlot { key: None, pins: 0, last_used: 0 }
    }
}

/// Keyed LRU residency over a fixed slot range, with pinning.
///
/// Slots `base..limit` are pageable; slots below `base` (the adapter
/// bank's reserved identity slot 0) are never offered as victims but can
/// still be pinned/queried so callers keep one indexing scheme.
pub struct LruPager<K: Ord + Clone> {
    slots: Vec<PagerSlot<K>>,
    resident: BTreeMap<K, usize>,
    tick: u64,
    base: usize,
    limit: usize,
}

impl<K: Ord + Clone> LruPager<K> {
    /// Pager over `n` slots of which `base..limit` are pageable (`limit`
    /// is clamped to `n`).
    pub fn new(n: usize, base: usize, limit: usize) -> LruPager<K> {
        let limit = limit.min(n);
        LruPager {
            slots: (0..n).map(|_| PagerSlot::empty()).collect(),
            resident: BTreeMap::new(),
            tick: 0,
            base: base.min(limit),
            limit,
        }
    }

    /// Resident slot of `key` without refreshing its LRU stamp.
    pub fn get<Q>(&self, key: &Q) -> Option<usize>
    where
        K: Borrow<Q>,
        Q: Ord + ?Sized,
    {
        self.resident.get(key).copied()
    }

    /// Resident slot of `key`, refreshing its LRU stamp on hit.
    pub fn touch<Q>(&mut self, key: &Q) -> Option<usize>
    where
        K: Borrow<Q>,
        Q: Ord + ?Sized,
    {
        let slot = self.resident.get(key).copied()?;
        self.tick += 1;
        if let Some(s) = self.slots.get_mut(slot) {
            s.last_used = self.tick;
        }
        Some(slot)
    }

    /// First unoccupied pageable slot, if any.
    pub fn free_slot(&self) -> Option<usize> {
        (self.base..self.limit).find(|&s| self.slots[s].key.is_none())
    }

    /// Least-recently-used *occupied, unpinned* pageable slot — the
    /// eviction victim when no slot is free.  Never returns an unkeyed
    /// slot, so callers tracking non-pager state (the block pool's
    /// Private blocks) cannot lose it to eviction.
    pub fn evict_lru(&self) -> Option<usize> {
        let mut victim: Option<usize> = None;
        for s in self.base..self.limit {
            let cand = &self.slots[s];
            if cand.key.is_none() || cand.pins > 0 {
                continue;
            }
            let better = match victim {
                None => true,
                Some(v) => cand.last_used < self.slots[v].last_used,
            };
            if better {
                victim = Some(s);
            }
        }
        victim
    }

    /// Bind `key` to `slot` with a fresh LRU stamp and zero pins.  The
    /// slot must be unoccupied (unbind the old key first).
    pub fn bind(&mut self, slot: usize, key: K) -> Result<()> {
        let n = self.slots.len();
        let Some(s) = self.slots.get_mut(slot) else {
            bail!("pager slot {slot} out of range ({n})");
        };
        if s.key.is_some() {
            bail!("pager slot {slot} is already occupied");
        }
        self.tick += 1;
        s.key = Some(key.clone());
        s.pins = 0;
        s.last_used = self.tick;
        self.resident.insert(key, slot);
        Ok(())
    }

    /// Clear `slot`, returning the key that occupied it (if any).  Pins
    /// are reset — callers must only unbind unpinned slots.
    pub fn unbind(&mut self, slot: usize) -> Option<K> {
        let s = self.slots.get_mut(slot)?;
        let key = s.key.take();
        s.pins = 0;
        s.last_used = 0;
        if let Some(k) = &key {
            self.resident.remove(k);
        }
        key
    }

    /// Pin `slot` against eviction (no-op below `base` or out of range —
    /// the adapter bank's identity slot never needs protection).
    pub fn pin(&mut self, slot: usize) {
        if slot >= self.base {
            if let Some(s) = self.slots.get_mut(slot) {
                s.pins += 1;
            }
        }
    }

    /// Release one pin on `slot` (no-op below `base` or out of range).
    pub fn unpin(&mut self, slot: usize) {
        if slot >= self.base {
            if let Some(s) = self.slots.get_mut(slot) {
                debug_assert!(s.pins > 0, "unpin of unpinned slot {slot}");
                s.pins = s.pins.saturating_sub(1);
            }
        }
    }

    pub fn is_pinned(&self, slot: usize) -> bool {
        self.slots.get(slot).map(|s| s.pins > 0).unwrap_or(false)
    }

    /// Pin count of `slot` (0 for out-of-range slots).
    pub fn pins(&self, slot: usize) -> usize {
        self.slots.get(slot).map(|s| s.pins).unwrap_or(0)
    }

    /// Key resident in `slot`, if any.
    pub fn key_of(&self, slot: usize) -> Option<&K> {
        self.slots.get(slot).and_then(|s| s.key.as_ref())
    }

    /// Number of resident keys.
    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    /// Size of the pageable slot range.
    pub fn pageable_len(&self) -> usize {
        self.limit - self.base
    }

    /// All resident keys (BTreeMap order: sorted by key).
    pub fn resident_keys(&self) -> Vec<&K> {
        self.resident.keys().collect()
    }

    /// Total pins across all slots (the live-reference gauge).
    pub fn total_pins(&self) -> usize {
        self.slots.iter().map(|s| s.pins).sum()
    }

    /// Resident keys with zero pins — how many victims `evict_lru` could
    /// supply before stalling.
    pub fn evictable_len(&self) -> usize {
        self.slots[self.base..self.limit]
            .iter()
            .filter(|s| s.key.is_some() && s.pins == 0)
            .count()
    }
}

/// What [`BlockPool::alloc_private`] did to satisfy the allocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrivateAlloc {
    pub block: usize,
    /// Prefix-cache key evicted to make room, when the free list was dry.
    pub evicted: Option<u64>,
}

/// Fixed-capacity pool of KV blocks (`block_size` tokens each) shared by
/// every decode lane: free list + per-lane Private accounting +
/// token-hash-keyed prefix cache paged by an [`LruPager`].  See the
/// module docs for the three-state model and conservation invariant.
pub struct BlockPool {
    pager: LruPager<u64>,
    private: Vec<bool>,
    free: Vec<usize>,
    block_size: usize,
}

impl BlockPool {
    pub fn new(n_blocks: usize, block_size: usize) -> BlockPool {
        BlockPool {
            pager: LruPager::new(n_blocks, 0, n_blocks),
            private: vec![false; n_blocks],
            free: (0..n_blocks).rev().collect(),
            block_size: block_size.max(1),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.private.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_private(&self) -> usize {
        self.private.iter().filter(|&&p| p).count()
    }

    /// Cached (published) blocks, referenced or not.
    pub fn n_cached(&self) -> usize {
        self.pager.resident_len()
    }

    /// Total in-flight references onto cached blocks (the refcount gauge).
    pub fn total_refs(&self) -> usize {
        self.pager.total_pins()
    }

    /// Blocks an allocation could still obtain: free now, or cached with
    /// no live references (evictable on demand).
    pub fn available(&self) -> usize {
        self.free.len() + self.pager.evictable_len()
    }

    /// Is `key` published in the cache? (No LRU refresh — admission uses
    /// this to probe coverage before committing to a reservation.)
    pub fn lookup(&self, key: u64) -> Option<usize> {
        self.pager.get(&key)
    }

    /// True when `block` is privately held by some lane.
    pub fn is_private(&self, block: usize) -> bool {
        self.private.get(block).copied().unwrap_or(false)
    }

    /// Live reference count of a cached block (0 if not cached).
    pub fn refs_of(&self, block: usize) -> usize {
        if self.pager.key_of(block).is_some() { self.pager.pins(block) } else { 0 }
    }

    /// Cache key stored in `block`, if it is a cached block.
    pub fn key_of(&self, block: usize) -> Option<u64> {
        self.pager.key_of(block).copied()
    }

    /// Allocate one Private block for a lane: free list first, else evict
    /// the LRU unreferenced cached block.  `None` means every block is
    /// either Private or referenced by a live lane — the admission gate's
    /// stall signal.
    pub fn alloc_private(&mut self) -> Option<PrivateAlloc> {
        if let Some(b) = self.free.pop() {
            if let Some(p) = self.private.get_mut(b) {
                *p = true;
            }
            return Some(PrivateAlloc { block: b, evicted: None });
        }
        let victim = self.pager.evict_lru()?;
        let evicted = self.pager.unbind(victim);
        if let Some(p) = self.private.get_mut(victim) {
            *p = true;
        }
        Some(PrivateAlloc { block: victim, evicted })
    }

    /// Return a Private block to the free list.  Double releases and
    /// releases of non-private blocks are typed errors, not corruption.
    pub fn release_private(&mut self, block: usize) -> Result<()> {
        let n = self.private.len();
        let Some(p) = self.private.get_mut(block) else {
            bail!("block {block} out of range ({n})");
        };
        if !*p {
            bail!("double release of block {block} (not privately held)");
        }
        *p = false;
        self.free.push(block);
        Ok(())
    }

    /// Publish a lane's Private block as a cached shared-prefix block
    /// under `key`, keeping one reference for the publishing lane.
    /// Returns `false` (and leaves the block Private) when `key` is
    /// already cached — two cold lanes with the same prefix in one batch
    /// both compute it, but only the first publishes.
    pub fn publish(&mut self, block: usize, key: u64) -> Result<bool> {
        if self.pager.get(&key).is_some() {
            return Ok(false);
        }
        let n = self.private.len();
        let Some(p) = self.private.get_mut(block) else {
            bail!("block {block} out of range ({n})");
        };
        if !*p {
            bail!("publish of block {block} which is not privately held");
        }
        *p = false;
        self.pager.bind(block, key)?;
        self.pager.pin(block);
        Ok(true)
    }

    /// Take a reference on the cached block for `key` (LRU-refreshing
    /// it), for a lane admitted over a shared prefix.
    pub fn ref_cached(&mut self, key: u64) -> Option<usize> {
        let b = self.pager.touch(&key)?;
        self.pager.pin(b);
        Some(b)
    }

    /// Drop one reference on cached `block`.  The block stays cached (and
    /// becomes evictable at zero refs) — this is the release path that
    /// must never free the shared original.
    pub fn unref_cached(&mut self, block: usize) -> Result<()> {
        if self.pager.key_of(block).is_none() {
            bail!("unref of block {block} which holds no cached key");
        }
        if self.pager.pins(block) == 0 {
            bail!("unref of block {block} with zero references");
        }
        self.pager.unpin(block);
        Ok(())
    }

    /// Conservation check: every block is exactly one of Free / Private /
    /// Cached.  Cheap enough to assert after every mutation in tests.
    pub fn check_conservation(&self) -> Result<()> {
        let (n, f, p, c) = (self.n_blocks(), self.n_free(), self.n_private(), self.n_cached());
        if f + p + c != n {
            bail!("block conservation violated: free {f} + private {p} + cached {c} != {n}");
        }
        for (b, &priv_) in self.private.iter().enumerate() {
            let keyed = self.pager.key_of(b).is_some();
            let freed = self.free.contains(&b);
            let states = usize::from(priv_) + usize::from(keyed) + usize::from(freed);
            if states != 1 {
                bail!(
                    "block {b} in {states} states (private={priv_}, cached={keyed}, \
                     free={freed})"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pager_free_first_then_lru_eviction() {
        let mut p: LruPager<&'static str> = LruPager::new(3, 1, 3);
        assert_eq!(p.free_slot(), Some(1));
        p.bind(1, "a").unwrap();
        assert_eq!(p.free_slot(), Some(2));
        p.bind(2, "b").unwrap();
        assert_eq!(p.free_slot(), None);
        // "a" was bound first, but touching it makes "b" the LRU victim.
        assert_eq!(p.touch(&"a"), Some(1));
        assert_eq!(p.evict_lru(), Some(2));
        // Pinning "b" leaves only "a" as a victim; pinning both stalls.
        p.pin(2);
        assert_eq!(p.evict_lru(), Some(1));
        p.pin(1);
        assert_eq!(p.evict_lru(), None);
        p.unpin(2);
        assert_eq!(p.evict_lru(), Some(2));
        assert_eq!(p.unbind(2), Some("b"));
        assert_eq!(p.get(&"b"), None);
        assert_eq!(p.resident_len(), 1);
    }

    #[test]
    fn pager_base_slots_are_not_victims() {
        let mut p: LruPager<u32> = LruPager::new(3, 1, 3);
        // Slot 0 is below base: bindable by hand but never a victim.
        p.bind(0, 99).unwrap();
        assert_eq!(p.free_slot(), Some(1));
        p.bind(1, 1).unwrap();
        p.bind(2, 2).unwrap();
        let v = p.evict_lru().unwrap();
        assert!(v >= 1, "identity-range slot offered as victim");
        // Double-bind of an occupied slot is a typed error.
        assert!(p.bind(1, 7).is_err());
        assert!(p.bind(9, 7).is_err(), "out-of-range bind");
    }

    #[test]
    fn block_pool_alloc_release_cycle_conserves() {
        let mut pool = BlockPool::new(4, 8);
        assert_eq!(pool.block_size(), 8);
        pool.check_conservation().unwrap();
        let a = pool.alloc_private().unwrap();
        let b = pool.alloc_private().unwrap();
        assert_ne!(a.block, b.block);
        assert_eq!(pool.n_free(), 2);
        assert_eq!(pool.n_private(), 2);
        pool.check_conservation().unwrap();
        pool.release_private(a.block).unwrap();
        assert!(pool.release_private(a.block).is_err(), "double release caught");
        assert!(pool.release_private(99).is_err(), "out of range caught");
        pool.check_conservation().unwrap();
        assert_eq!(pool.n_free(), 3);
    }

    #[test]
    fn publish_ref_unref_and_eviction_protocol() {
        let mut pool = BlockPool::new(3, 4);
        let a = pool.alloc_private().unwrap();
        assert!(pool.publish(a.block, 0xfeed).unwrap());
        assert_eq!(pool.refs_of(a.block), 1, "publisher keeps one ref");
        assert_eq!(pool.n_cached(), 1);
        pool.check_conservation().unwrap();

        // A second lane references the same key.
        let hit = pool.ref_cached(0xfeed).unwrap();
        assert_eq!(hit, a.block);
        assert_eq!(pool.refs_of(a.block), 2);
        assert_eq!(pool.total_refs(), 2);

        // While referenced, the cached block is not an eviction victim:
        // exhaust the free list, then the next alloc must fail.
        let b = pool.alloc_private().unwrap();
        let c = pool.alloc_private().unwrap();
        assert!(b.evicted.is_none() && c.evicted.is_none());
        assert_eq!(pool.available(), 0);
        assert!(pool.alloc_private().is_none(), "referenced cache block must survive");

        // Dropping both refs makes it evictable; the original is still
        // cached until pressure actually takes it.
        pool.unref_cached(a.block).unwrap();
        pool.unref_cached(a.block).unwrap();
        assert!(pool.unref_cached(a.block).is_err(), "ref underflow caught");
        assert_eq!(pool.lookup(0xfeed), Some(a.block), "zero refs keeps the cache entry");
        let d = pool.alloc_private().unwrap();
        assert_eq!(d.block, a.block);
        assert_eq!(d.evicted, Some(0xfeed));
        assert_eq!(pool.lookup(0xfeed), None);
        pool.check_conservation().unwrap();
        assert_eq!(pool.n_private(), 3);
    }

    #[test]
    fn publish_of_existing_key_is_a_noop_keeping_private() {
        let mut pool = BlockPool::new(4, 4);
        let a = pool.alloc_private().unwrap();
        let b = pool.alloc_private().unwrap();
        assert!(pool.publish(a.block, 7).unwrap());
        assert!(!pool.publish(b.block, 7).unwrap(), "duplicate key is not re-published");
        assert!(pool.is_private(b.block), "loser keeps its private block");
        assert!(pool.publish(99, 8).is_err());
        let c = pool.alloc_private().unwrap();
        assert!(pool.publish(c.block, 9).unwrap());
        assert_eq!(pool.n_cached(), 2);
        pool.check_conservation().unwrap();
    }
}
