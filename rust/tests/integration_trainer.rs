//! Trainer integration tests on the tiny config: loss decreases, state
//! round-trips, adapters export/merge consistently, and the composability
//! gradient mask really freezes the complementary subspace.

use std::rc::Rc;

use road::runtime::Runtime;
use road::tasks::{lm_batch, Example};
use road::trainer::{linear_lr, TrainBatch, Trainer};
use road::util::rng::Rng;
use road::require_artifacts;

fn rt() -> Rc<Runtime> {
    Rc::new(Runtime::from_default_artifacts().expect("run `make artifacts` first"))
}

/// A fixed simple mapping batch on the tiny train bucket [4, 16]:
/// "ab...>" followed by a constant answer byte.
fn tiny_batch(rng: &mut Rng) -> TrainBatch {
    let exs: Vec<Example> = (0..4)
        .map(|_| {
            let c = 97 + rng.below(4) as u8;
            // answer = the prompt letter, uppercased (deterministic task)
            let p = format!("{}>", c as char);
            let a = format!("{}", (c - 32) as char);
            Example::gen(&p, &a)
        })
        .collect();
    lm_batch(&exs, 4, 16)
}

#[test]
fn road1_training_reduces_loss_on_tiny() {
    require_artifacts!();
    let rt = rt();
    let mut tr = Trainer::new(rt, "tiny", "road1").unwrap();
    assert_eq!((tr.batch, tr.seq_len), (4, 16));
    let mut rng = Rng::seed_from(1);
    let mut first = None;
    let mut last = 0.0;
    for i in 0..25 {
        let b = tiny_batch(&mut rng);
        let lr = linear_lr(i, 25, 0.1, 5e-3);
        last = tr.step(&b, lr).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert_eq!(tr.steps_done, 25);
    assert_eq!(tr.loss_history.len(), 25);
}

#[test]
fn trainable_save_load_roundtrip_preserves_eval() {
    require_artifacts!();
    let rt = rt();
    let mut tr = Trainer::new(rt.clone(), "tiny", "road1").unwrap();
    let mut rng = Rng::seed_from(2);
    for _ in 0..5 {
        let b = tiny_batch(&mut rng);
        tr.step(&b, 3e-3).unwrap();
    }
    let eval_batch = tiny_batch(&mut rng);
    let (_, loss_before) = tr.eval_loss(&eval_batch).unwrap();

    let tmp = std::env::temp_dir().join("road_test_trainable.bin");
    tr.save_trainable(&tmp).unwrap();

    let mut tr2 = Trainer::new(rt, "tiny", "road1").unwrap();
    tr2.load_trainable(&tmp).unwrap();
    let (_, loss_after) = tr2.eval_loss(&eval_batch).unwrap();
    assert!((loss_before - loss_after).abs() < 1e-6, "{loss_before} vs {loss_after}");
    std::fs::remove_file(tmp).ok();
}

#[test]
fn identity_init_matches_base_model_loss() {
    require_artifacts!();
    // theta=0, alpha=1 must be a no-op (the paper's "preserve the starting
    // point" init): eval through road1 == eval through the base model.
    let rt = rt();
    let tr = Trainer::new(rt, "tiny", "road1").unwrap();
    let mut rng = Rng::seed_from(3);
    let b = tiny_batch(&mut rng);
    let (per_ex, total) = tr.eval_loss(&b).unwrap();
    assert!(total.is_finite());
    assert_eq!(per_ex.len(), 4);
    // A second evaluation must be bit-identical (pure function of state).
    let (_, total2) = tr.eval_loss(&b).unwrap();
    assert_eq!(total, total2);
}

#[test]
fn exported_adapter_has_identity_blocks_before_training() {
    require_artifacts!();
    let rt = rt();
    let tr = Trainer::new(rt, "tiny", "road1").unwrap();
    match tr.export_adapter().unwrap() {
        road::adapters::Adapter::Road(a) => {
            for (k, v) in &a.per_proj {
                assert!(v.r1.iter().all(|&x| (x - 1.0).abs() < 1e-6), "{k}");
                assert!(v.r2.iter().all(|&x| x.abs() < 1e-6), "{k}");
            }
        }
        _ => panic!("road1 must export a Road adapter"),
    }
}

#[test]
fn last_logits_shape_and_determinism() {
    require_artifacts!();
    let rt = rt();
    let tr = Trainer::new(rt, "tiny", "road1").unwrap();
    let (b, l) = (tr.batch, tr.seq_len);
    let tokens: Vec<i32> = (0..b * l).map(|i| 1 + (i % 200) as i32).collect();
    let lengths: Vec<i32> = (0..b).map(|i| (3 + i) as i32).collect();
    let lg = tr.last_logits(&tokens, &lengths).unwrap();
    assert_eq!(lg.shape, vec![b, tr.cfg.vocab]);
    let lg2 = tr.last_logits(&tokens, &lengths).unwrap();
    assert_eq!(lg.as_f32(), lg2.as_f32());
}

#[test]
fn grad_mask_freezes_complementary_subspace() {
    require_artifacts!();
    // road1_masked exists on the "train" config: mask the lower half and
    // verify those theta/alpha entries never move (the composability
    // mechanism, Fig 5).
    let rt = rt();
    let mut tr = Trainer::new(rt, "train", "road1_masked").unwrap();
    road::compose::set_half_mask(&mut tr, road::compose::Half::Upper).unwrap();

    let init: Vec<Vec<f32>> =
        tr.trainable().iter().map(|(_, t)| t.as_f32()).collect();
    let (b, l) = (tr.batch, tr.seq_len);
    let mut rng = Rng::seed_from(4);
    for _ in 0..3 {
        let exs: Vec<Example> = (0..b)
            .map(|_| {
                let c = 97 + rng.below(8) as u8;
                Example::gen(&format!("{}>", c as char), "Z")
            })
            .collect();
        let batch = lm_batch(&exs, b, l);
        tr.step(&batch, 5e-3).unwrap();
    }

    let mut upper_moved = false;
    for ((_, t), before) in tr.trainable().iter().zip(&init) {
        let after = t.as_f32();
        let n = after.len();
        for i in 0..n {
            let moved = (after[i] - before[i]).abs() > 1e-7;
            if i < n / 2 {
                upper_moved |= moved;
            } else {
                assert!(!moved, "masked (lower) element {i}/{n} moved");
            }
        }
    }
    assert!(upper_moved, "unmasked (upper) subspace never moved");
}

#[test]
fn available_methods_cover_the_paper_baselines() {
    require_artifacts!();
    let rt = rt();
    let methods = road::trainer::available_methods(&rt.manifest, "train");
    for want in [
        "full", "lora", "ia3", "bitfit", "oft2", "oft16", "road1", "road2", "road4",
        "road1_fc1", "road1_masked",
    ] {
        assert!(methods.iter().any(|m| m == want), "missing {want}: {methods:?}");
    }
}

#[test]
fn road1_fc1_has_fewer_trainables_than_road1() {
    require_artifacts!();
    // Table 2's RoAd1(fc1) row: adapter on the first feed-forward layer
    // only -> a strict subset of the parameters.
    let rt = rt();
    let full = Trainer::new(rt.clone(), "train", "road1").unwrap();
    let fc1 = Trainer::new(rt, "train", "road1_fc1").unwrap();
    assert!(fc1.n_trainable < full.n_trainable);
}
