//! Serving metrics: throughput, TTFT, per-token and end-to-end latency,
//! step-time accounting split by phase.

use std::time::{Duration, Instant};

use crate::util::stats::{LatencyRecorder, Summary};

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    pub prefill_batches: usize,
    pub decode_steps: usize,
    pub ttft: LatencyRecorder,
    pub e2e: LatencyRecorder,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => (f - s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of wall time — Figure 4's y-axis.
    pub fn throughput(&self) -> f64 {
        let w = self.wall();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttft.summary()
    }

    pub fn e2e_summary(&self) -> Summary {
        self.e2e.summary()
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s \
             prefill_batches={} decode_steps={} \
             ttft(p50/p90)={:.1}/{:.1}ms e2e(p50/p90)={:.1}/{:.1}ms \
             prefill={:.2}s decode={:.2}s",
            self.requests_completed,
            self.tokens_generated,
            self.wall(),
            self.throughput(),
            self.prefill_batches,
            self.decode_steps,
            t.p50 / 1e3,
            t.p90 / 1e3,
            e.p50 / 1e3,
            e.p90 / 1e3,
            self.prefill_time.as_secs_f64(),
            self.decode_time.as_secs_f64(),
        )
    }
}
