//! Figure 4 bench: end-to-end heterogeneous-batching throughput sweeps
//! (merged vs unmerged; vs #generated tokens; vs #distinct adapters), plus
//! the KV residency comparison (device-resident decode vs the full
//! host-round-trip baseline).
//!
//! Plain `harness = false` binary (no criterion in the offline image):
//! each point is a full engine run; results print as the paper's series.
//! Skips cleanly when the AOT artifacts have not been built.
//!
//! ```bash
//! cargo bench --bench fig4_batching            # all panels
//! cargo bench --bench fig4_batching -- quick   # reduced sweep
//! ```

use std::rc::Rc;

use road::bench;
use road::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !road::Manifest::available_or_note() {
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "quick");
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    let seed = 7;

    let tokens = if quick { 24 } else { 64 };
    println!("# Figure 4 (Left): merged vs unmerged, batch 1, {tokens} tokens");
    let pts = bench::fig4_left(&rt, tokens, seed)?;
    println!("{}", bench::render_points("fig4-left", &pts));

    let counts: Vec<usize> = if quick { vec![16, 48] } else { vec![16, 32, 64, 128] };
    println!("# Figure 4 (Middle): throughput vs #generated tokens (batch 8, 8 adapters)");
    let pts = bench::fig4_middle(&rt, &counts, seed)?;
    println!("{}", bench::render_points("fig4-middle", &pts));
    summarize_ratio(&pts);

    let distinct: Vec<usize> = if quick { vec![1, 8] } else { vec![1, 2, 4, 8] };
    println!("# Figure 4 (Right): throughput vs #distinct adapters (batch 8, {tokens} tokens)");
    let pts = bench::fig4_right(&rt, &distinct, tokens, seed)?;
    println!("{}", bench::render_points("fig4-right", &pts));
    summarize_ratio(&pts);

    println!("# KV residency: device-resident decode vs host-roundtrip baseline");
    let pts = bench::kv_residency_comparison(&rt, tokens, seed)?;
    println!("{}", bench::render_points("kv-residency", &pts));
    summarize_residency(&pts);
    Ok(())
}

/// Print the road/lora throughput ratio per matched sweep point — the
/// paper's headline "2x LoRA" claim, on this substrate.
fn summarize_ratio(pts: &[road::bench::ServingPoint]) {
    for pair in pts.chunks(2) {
        if pair.len() == 2 {
            let (road, lora) = (&pair[0], &pair[1]);
            println!(
                "  ratio @ (d={}, t={}): road/lora = {:.2}x",
                road.distinct_adapters,
                road.new_tokens,
                road.tokens_per_sec / lora.tokens_per_sec
            );
        }
    }
}

/// Per-decode-step cost with the cache device-resident vs round-tripped;
/// the device-resident step must be strictly cheaper (it moves O(B·vocab)
/// logits instead of the O(layers·B·max_seq·d) caches).
fn summarize_residency(pts: &[road::bench::ServingPoint]) {
    let [device, host] = pts else { return };
    let (Some(d_ms), Some(h_ms)) = (device.ms_per_step(), host.ms_per_step()) else {
        println!("  decode step comparison unavailable: a run performed no decode steps");
        return;
    };
    println!(
        "  decode step: device-resident {d_ms:.3} ms vs host-roundtrip {h_ms:.3} ms \
         ({:.2}x) — device-resident strictly faster: {}",
        h_ms / d_ms,
        d_ms < h_ms
    );
}
