//! Host-side tensors: the CPU representation flowing between the rust
//! coordinator and the PJRT runtime.
//!
//! Only the two dtypes the AOT contract uses (f32, i32) are supported —
//! artifacts/manifest.json is the source of truth for shapes and ordering.

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }

    pub fn size(self) -> usize {
        4
    }
}

/// A dense host tensor.  Data is kept as raw little-endian bytes so uploads
/// and binary-file loads are zero-conversion.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    data: Vec<u8>,
}

/// Bulk-copy a scalar slice into little-endian bytes.  All supported
/// targets are little-endian, so this is a single memcpy (the previous
/// per-element `to_le_bytes` loop dominated the decode hot path when
/// converting multi-MB KV caches — see EXPERIMENTS.md §Perf).
fn scalars_to_bytes<T: Copy>(values: &[T]) -> Vec<u8> {
    debug_assert!(cfg!(target_endian = "little"));
    let n = std::mem::size_of_val(values);
    let mut data = vec![0u8; n];
    // SAFETY: T is a plain scalar (f32/i32); sizes match by construction.
    unsafe {
        std::ptr::copy_nonoverlapping(values.as_ptr() as *const u8, data.as_mut_ptr(), n);
    }
    data
}

fn bytes_to_scalars<T: Copy + Default>(bytes: &[u8]) -> Vec<T> {
    debug_assert!(cfg!(target_endian = "little"));
    let n = bytes.len() / std::mem::size_of::<T>();
    let mut out = vec![T::default(); n];
    // SAFETY: out is freshly allocated with exactly n elements; byte count
    // matches; T is a plain scalar so any bit pattern is valid.
    unsafe {
        std::ptr::copy_nonoverlapping(
            bytes.as_ptr(),
            out.as_mut_ptr() as *mut u8,
            n * std::mem::size_of::<T>(),
        );
    }
    out
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, values: Vec<f32>) -> HostTensor {
        assert_eq!(values.len(), shape.iter().product::<usize>().max(1));
        HostTensor { shape, dtype: DType::F32, data: scalars_to_bytes(&values) }
    }

    pub fn i32(shape: Vec<usize>, values: Vec<i32>) -> HostTensor {
        assert_eq!(values.len(), shape.iter().product::<usize>().max(1));
        HostTensor { shape, dtype: DType::I32, data: scalars_to_bytes(&values) }
    }

    pub fn zeros(shape: Vec<usize>, dtype: DType) -> HostTensor {
        let n = shape.iter().product::<usize>().max(1);
        HostTensor { shape, dtype, data: vec![0u8; n * 4] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn from_bytes(shape: Vec<usize>, dtype: DType, data: Vec<u8>) -> Result<HostTensor> {
        let n = shape.iter().product::<usize>().max(1);
        if data.len() != n * 4 {
            bail!("byte count {} != 4 * {}", data.len(), n);
        }
        Ok(HostTensor { shape, dtype, data })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        bytes_to_scalars(&self.data)
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        bytes_to_scalars(&self.data)
    }

    /// Borrow the payload as an f32 slice (alignment-safe: `Vec<u8>` from
    /// our constructors is 4-aligned on all supported platforms via
    /// realloc, but we fall back to a copy if not).
    pub fn f32_slice(&self) -> Option<&[f32]> {
        assert_eq!(self.dtype, DType::F32);
        let ptr = self.data.as_ptr();
        if (ptr as usize) % std::mem::align_of::<f32>() == 0 {
            Some(unsafe { std::slice::from_raw_parts(ptr as *const f32, self.elem_count()) })
        } else {
            None
        }
    }

    pub fn f32_at(&self, idx: usize) -> f32 {
        let o = idx * 4;
        f32::from_le_bytes([self.data[o], self.data[o + 1], self.data[o + 2], self.data[o + 3]])
    }

    pub fn set_f32(&mut self, idx: usize, v: f32) {
        let o = idx * 4;
        self.data[o..o + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Overwrite a contiguous element range from an f32 slice (bulk copy).
    pub fn write_f32_range(&mut self, start_elem: usize, src: &[f32]) {
        let o = start_elem * 4;
        self.data[o..o + 4 * src.len()].copy_from_slice(&scalars_to_bytes(src));
    }

    /// Copy a contiguous element range into an f32 vec (bulk copy).
    pub fn read_f32_range(&self, start_elem: usize, n: usize) -> Vec<f32> {
        bytes_to_scalars(&self.data[start_elem * 4..(start_elem + n) * 4])
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }
}

/// Load a concatenated flat binary (params_*.bin etc) into tensors
/// according to `specs` (name, shape) in order.  All-f32 by contract.
pub fn load_flat_f32(
    bytes: &[u8],
    specs: &[(String, Vec<usize>)],
) -> Result<Vec<(String, HostTensor)>> {
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for (name, shape) in specs {
        let n = shape.iter().product::<usize>().max(1);
        let end = off + 4 * n;
        if end > bytes.len() {
            bail!("flat file too short at {name} (need {end}, have {})", bytes.len());
        }
        out.push((
            name.clone(),
            HostTensor::from_bytes(shape.clone(), DType::F32, bytes[off..end].to_vec())?,
        ));
        off = end;
    }
    if off != bytes.len() {
        bail!("flat file has {} trailing bytes", bytes.len() - off);
    }
    Ok(out)
}

/// Serialize tensors back to the concatenated flat format.
pub fn dump_flat(tensors: &[&HostTensor]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tensors {
        out.extend_from_slice(t.bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.elem_count(), 6);
        assert_eq!(t.as_f32(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.f32_at(4), 5.0);
    }

    #[test]
    fn roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![-1, 0, 7, 255]);
        assert_eq!(t.as_i32(), vec![-1, 0, 7, 255]);
    }

    #[test]
    fn scalar() {
        let t = HostTensor::scalar_f32(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.elem_count(), 1);
        assert_eq!(t.as_f32(), vec![3.5]);
    }

    #[test]
    fn flat_load() {
        let specs = vec![("a".to_string(), vec![2]), ("b".to_string(), vec![1, 3])];
        let mut bytes = Vec::new();
        for v in [1f32, 2.0, 10.0, 20.0, 30.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let out = load_flat_f32(&bytes, &specs).unwrap();
        assert_eq!(out[0].1.as_f32(), vec![1.0, 2.0]);
        assert_eq!(out[1].1.as_f32(), vec![10.0, 20.0, 30.0]);
        assert!(load_flat_f32(&bytes[..12], &specs).is_err());
    }

    #[test]
    fn write_read_range() {
        let mut t = HostTensor::zeros(vec![8], DType::F32);
        t.write_f32_range(2, &[1.0, 2.0, 3.0]);
        assert_eq!(t.read_f32_range(2, 3), vec![1.0, 2.0, 3.0]);
        assert_eq!(t.f32_at(0), 0.0);
    }
}
