"""Layer-2 JAX model: a tiny LLaMA-style transformer with pluggable
per-request adapter modes.

This is the compute graph the rust coordinator serves.  It is written once
here, lowered by aot.py to HLO text, and never imported at runtime.

Conventions
-----------
* Parameters live in a FLAT dict[str, Array] with dotted keys
  ("blocks.0.wq", ...).  Flattening order = sorted(keys); this order is
  recorded in artifacts/manifest.json and is the contract with rust.
* Linear layers use the inputs-left convention: y = x @ W + b, with
  W [d_in, d_out].  All linears carry a bias (needed for the BitFit
  baseline; initialized to zero so the base model matches a bias-less one).
* Adapter modes:
    "base"  — no adapter inputs (merged weights / pretrained model)
    "road"  — RoAd banks: per proj r1/r2 [n_adapters, d_out]; applied with
              the Layer-1 Pallas element-wise kernel (Eq. 4)
    "lora"  — unmerged LoRA banks: lb [n, d_in, r], la [n, r, d_out];
              applied with the Layer-1 bmm kernel (the Figure-4 baseline)
    "ia3"   — scaling banks: s [n, d_out]
    "oft"   — Cayley-orthogonal block-diagonal banks: q [n, d/w, w, w]
* Entry points (prefill / decode / reps / logits) take adapter ids [B] so a
  single executable serves heterogeneous batches — the paper's batching
  scenario.
* KV caches are [n_layers, B, n_heads, max_seq, head_dim]; decode writes at
  per-slot positions so the rust engine can run continuous batching over
  slots that sit at different sequence offsets.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PROJS, proj_dims
from .kernels.road import road_batched_apply
from .kernels.lora import lora_batched_apply
from .kernels.ia3 import ia3_batched_apply
from .kernels import ref as kref

ADAPTER_MODES = ("base", "road", "lora", "ia3", "oft")


# ---------------------------------------------------------------------------
# Parameter init / flattening helpers
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    """Random 'pretrained' parameters (flat dict, deterministic layout)."""
    params = {}
    k_emb, k_head, key = jax.random.split(key, 3)
    scale = cfg.d_model ** -0.5
    params["tok_emb"] = jax.random.normal(k_emb, (cfg.vocab, cfg.d_model)) * scale
    params["final_norm"] = jnp.ones((cfg.d_model,))
    params["lm_head"] = jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) * scale
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        params[f"{pre}.attn_norm"] = jnp.ones((cfg.d_model,))
        params[f"{pre}.ffn_norm"] = jnp.ones((cfg.d_model,))
        for proj in PROJS:
            d_in, d_out = proj_dims(cfg, proj)
            key, sub = jax.random.split(key)
            params[f"{pre}.{proj}"] = jax.random.normal(sub, (d_in, d_out)) * (d_in ** -0.5)
            params[f"{pre}.{proj}.bias"] = jnp.zeros((d_out,))
    return params


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """(name, shape) in flattening order, without materializing arrays."""
    shapes = {
        "tok_emb": (cfg.vocab, cfg.d_model),
        "final_norm": (cfg.d_model,),
        "lm_head": (cfg.d_model, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        shapes[f"{pre}.attn_norm"] = (cfg.d_model,)
        shapes[f"{pre}.ffn_norm"] = (cfg.d_model,)
        for proj in PROJS:
            d_in, d_out = proj_dims(cfg, proj)
            shapes[f"{pre}.{proj}"] = (d_in, d_out)
            shapes[f"{pre}.{proj}.bias"] = (d_out,)
    return [(k, shapes[k]) for k in sorted(shapes)]


def init_adapters(cfg: ModelConfig, mode: str, n: int | None = None,
                  oft_w: int = 2) -> dict:
    """Identity-initialized adapter banks for `mode` (theta=0, alpha=1)."""
    n = n if n is not None else cfg.n_adapters
    banks = {}
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        for proj in PROJS:
            d_in, d_out = proj_dims(cfg, proj)
            if mode == "road":
                banks[f"{pre}.{proj}.r1"] = jnp.ones((n, d_out))
                banks[f"{pre}.{proj}.r2"] = jnp.zeros((n, d_out))
            elif mode == "lora":
                banks[f"{pre}.{proj}.lb"] = jnp.zeros((n, d_in, cfg.lora_rank))
                banks[f"{pre}.{proj}.la"] = jnp.zeros((n, cfg.lora_rank, d_out))
            elif mode == "ia3":
                banks[f"{pre}.{proj}.s"] = jnp.ones((n, d_out))
            elif mode == "oft":
                banks[f"{pre}.{proj}.q"] = jnp.zeros((n, d_out // oft_w, oft_w, oft_w))
            elif mode == "base":
                pass
            else:
                raise ValueError(mode)
    return banks


def adapter_specs(cfg: ModelConfig, mode: str, n: int | None = None,
                  oft_w: int = 2) -> list[tuple[str, tuple[int, ...]]]:
    banks = init_adapters(cfg, mode, n, oft_w)
    return [(k, tuple(banks[k].shape)) for k in sorted(banks)]


def flatten(d: dict) -> list:
    return [d[k] for k in sorted(d)]


def unflatten(keys: list[str], leaves) -> dict:
    return dict(zip(sorted(keys), leaves))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(cfg: ModelConfig, positions):
    """cos/sin tables [..., head_dim/2] for integer positions [...]."""
    hd = cfg.head_dim
    inv = cfg.rope_theta ** (-jnp.arange(0, hd, 2) / hd)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv   # [..., hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, H, L, hd]; cos/sin [B, L, hd/2] (or broadcastable)."""
    xr = x.reshape(*x.shape[:-1], -1, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    c = cos[:, None, :, :]
    s = sin[:, None, :, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _linear(params, name, x, mode, adapters, ids, oft_w, use_kernels=True):
    """Adapted linear layer: frozen matmul + per-request adapter epilogue.

    use_kernels=False routes through the pure-jnp oracles instead of the
    Pallas kernels — required on the training path (interpret-mode Pallas
    has no reverse-mode autodiff rule); numerics are identical.
    """
    z = x @ params[name] + params[f"{name}.bias"]
    if mode == "base":
        return z
    road_f = road_batched_apply if use_kernels else kref.road_batched_apply
    lora_f = lora_batched_apply if use_kernels else kref.lora_batched_apply
    ia3_f = ia3_batched_apply if use_kernels else kref.ia3_batched_apply
    if mode == "road":
        return road_f(z, adapters[f"{name}.r1"], adapters[f"{name}.r2"], ids)
    if mode == "lora":
        return z + lora_f(x, adapters[f"{name}.lb"], adapters[f"{name}.la"],
                          ids)
    if mode == "ia3":
        return ia3_f(z, adapters[f"{name}.s"], ids)
    if mode == "oft":
        # Baseline path: build R via Cayley per call (the cost the paper's
        # Tab D.1 charges OFT for).  Batched over requests via gather.
        q = adapters[f"{name}.q"][ids]           # [B, nb, w, w]
        r = kref.oft_cayley_blocks(q.reshape(-1, oft_w, oft_w))
        r = r.reshape(*q.shape)
        b, l, d = z.shape
        zb = z.reshape(b, l, -1, oft_w)
        out = jnp.einsum("blnw,bnvw->blnv", zb, r)
        return out.reshape(b, l, d)
    raise ValueError(mode)


def _block(cfg, params, i, x, mode, adapters, ids, cos, sin, kv_mask,
           k_cache, v_cache, write_onehot, oft_w, use_kernels=True):
    """One transformer block; returns (x, new_k_cache, new_v_cache).

    k_cache/v_cache: [B, H, T, hd] for this layer.  write_onehot
    [B, 1, T, 1] marks the cache positions written by this call (prefill
    writes L positions; decode writes one per slot).  kv_mask [B, 1, q, T]
    is the attention visibility mask.
    """
    pre = f"blocks.{i}"
    b, l, _ = x.shape
    h = rmsnorm(x, params[f"{pre}.attn_norm"])
    lin = lambda nm, inp: _linear(params, f"{pre}.{nm}", inp, mode, adapters,
                                  ids, oft_w, use_kernels)
    q = lin("wq", h).reshape(b, l, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = lin("wk", h).reshape(b, l, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = lin("wv", h).reshape(b, l, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # Scatter new K/V into the cache at the positions marked by write_onehot.
    # (One-hot blend keeps the graph shape-static for AOT compilation.)
    t = k_cache.shape[2]
    if l == t:
        k_new = jnp.where(write_onehot > 0, k, k_cache)
        v_new = jnp.where(write_onehot > 0, v, v_cache)
    else:
        # l < t: expand the written rows into cache positions.
        # write_onehot here is [B, 1, T, L]: cache position t receives row j.
        keep = 1.0 - write_onehot.sum(-1, keepdims=True)     # [B,1,T,1]
        k_new = jnp.einsum("bhld,botl->bhtd", k, write_onehot) + k_cache * keep
        v_new = jnp.einsum("bhld,botl->bhtd", v, write_onehot) + v_cache * keep

    scores = jnp.einsum("bhqd,bhtd->bhqt", q, k_new) * (cfg.head_dim ** -0.5)
    scores = jnp.where(kv_mask > 0, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqt,bhtd->bhqd", attn, v_new)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, l, cfg.d_model)
    x = x + lin("wo", ctx)

    h2 = rmsnorm(x, params[f"{pre}.ffn_norm"])
    gate = lin("wgate", h2)
    up = lin("wup", h2)
    x = x + lin("wdown", jax.nn.silu(gate) * up)
    return x, k_new, v_new


def _embed(params, tokens):
    return params["tok_emb"][tokens]


def _head(params, x):
    return rmsnorm(x, params["final_norm"]) @ params["lm_head"]


# ---------------------------------------------------------------------------
# Entry points (lowered to HLO by aot.py)
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, mode: str, params: dict, adapters: dict,
            ids, tokens, lengths, oft_w: int = 2):
    """Process prompts, fill KV caches, return last-valid-token logits.

    tokens [B, L] int32 (right-padded); lengths [B] int32 (valid lengths).
    Returns (logits [B, V], k_caches [n_layers,B,H,T,hd], v_caches same).
    """
    b, l = tokens.shape
    t = cfg.max_seq
    x = _embed(params, tokens)
    pos = jnp.broadcast_to(jnp.arange(l), (b, l))
    cos, sin = rope_tables(cfg, pos)
    # Causal mask over the cache: query j attends cache positions <= j,
    # and only positions < L have been written.
    q_idx = jnp.arange(l)[:, None]           # [L,1]
    t_idx = jnp.arange(t)[None, :]           # [1,T]
    mask = (t_idx <= q_idx) & (t_idx < l)
    kv_mask = jnp.broadcast_to(mask[None, None], (b, 1, l, t)).astype(jnp.float32)
    # Cache scatter: cache position p <- row p for p < L.
    write = (jnp.arange(t)[:, None] == jnp.arange(l)[None, :]).astype(jnp.float32)
    write_onehot = jnp.broadcast_to(write[None, None], (b, 1, t, l))

    kcs, vcs = [], []
    for i in range(cfg.n_layers):
        kc = jnp.zeros((b, cfg.n_heads, t, cfg.head_dim))
        vc = jnp.zeros((b, cfg.n_heads, t, cfg.head_dim))
        x, kc, vc = _block(cfg, params, i, x, mode, adapters, ids, cos, sin,
                           kv_mask, kc, vc, write_onehot, oft_w)
        kcs.append(kc)
        vcs.append(vc)
    logits_all = _head(params, x)                       # [B, L, V]
    last = jnp.clip(lengths - 1, 0, l - 1)
    logits = jnp.take_along_axis(
        logits_all, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return logits, jnp.stack(kcs), jnp.stack(vcs)


def decode(cfg: ModelConfig, mode: str, params: dict, adapters: dict,
           ids, token, pos, k_caches, v_caches, oft_w: int = 2):
    """One decode step for B slots at per-slot positions.

    token [B] int32; pos [B] int32 (cache position to write / attend up to);
    k_caches/v_caches [n_layers, B, H, T, hd].
    Returns (logits [B, V], k_caches', v_caches').
    """
    b = token.shape[0]
    t = cfg.max_seq
    x = _embed(params, token[:, None])                  # [B,1,D]
    cos, sin = rope_tables(cfg, pos[:, None])           # [B,1,hd/2]
    t_idx = jnp.arange(t)[None, None, None, :]          # [1,1,1,T]
    kv_mask = (t_idx <= pos[:, None, None, None]).astype(jnp.float32)
    write_onehot = (jnp.arange(t)[None, None, :, None]
                    == pos[:, None, None, None]).astype(jnp.float32)  # [B,1,T,1]

    nkc, nvc = [], []
    for i in range(cfg.n_layers):
        x, kc, vc = _block(cfg, params, i, x, mode, adapters, ids, cos, sin,
                           kv_mask, k_caches[i], v_caches[i],
                           write_onehot, oft_w)
        nkc.append(kc)
        nvc.append(vc)
    logits = _head(params, x)[:, 0]
    return logits, jnp.stack(nkc), jnp.stack(nvc)


def full_forward(cfg: ModelConfig, mode: str, params: dict, adapters: dict,
                 ids, tokens, oft_w: int = 2, use_kernels: bool = True):
    """Causal logits for ALL positions (training / eval-loss path).

    tokens [B, L] -> logits [B, L, V].  No KV cache materialization: plain
    causal attention (cheaper to differentiate).
    """
    b, l = tokens.shape
    x = _embed(params, tokens)
    pos = jnp.broadcast_to(jnp.arange(l), (b, l))
    cos, sin = rope_tables(cfg, pos)
    causal = (jnp.arange(l)[None, :] <= jnp.arange(l)[:, None])
    kv_mask = jnp.broadcast_to(causal[None, None], (b, 1, l, l)).astype(jnp.float32)
    write_onehot = jnp.ones((b, 1, l, 1))
    for i in range(cfg.n_layers):
        kc = jnp.zeros((b, cfg.n_heads, l, cfg.head_dim))
        vc = jnp.zeros((b, cfg.n_heads, l, cfg.head_dim))
        x, _, _ = _block(cfg, params, i, x, mode, adapters, ids, cos, sin,
                         kv_mask, kc, vc, write_onehot, oft_w, use_kernels)
    return _head(params, x)


def hidden_states(cfg: ModelConfig, mode: str, params: dict, adapters: dict,
                  ids, tokens, lengths, oft_w: int = 2):
    """Per-layer last-valid-token hidden states (pilot study, Fig 2/B.1).

    Returns [B, n_layers + 1, D]: embedding output plus each block output.
    """
    b, l = tokens.shape
    x = _embed(params, tokens)
    pos = jnp.broadcast_to(jnp.arange(l), (b, l))
    cos, sin = rope_tables(cfg, pos)
    causal = (jnp.arange(l)[None, :] <= jnp.arange(l)[:, None])
    kv_mask = jnp.broadcast_to(causal[None, None], (b, 1, l, l)).astype(jnp.float32)
    write_onehot = jnp.ones((b, 1, l, 1))
    last = jnp.clip(lengths - 1, 0, l - 1).astype(jnp.int32)

    def take_last(h):
        return jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]

    outs = [take_last(x)]
    for i in range(cfg.n_layers):
        kc = jnp.zeros((b, cfg.n_heads, l, cfg.head_dim))
        vc = jnp.zeros((b, cfg.n_heads, l, cfg.head_dim))
        x, _, _ = _block(cfg, params, i, x, mode, adapters, ids, cos, sin,
                         kv_mask, kc, vc, write_onehot, oft_w)
        outs.append(take_last(x))
    return jnp.stack(outs, axis=1)
