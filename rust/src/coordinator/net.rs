//! NDJSON-over-TCP front end: the engine's wire protocol (std::net +
//! threads; the image carries no tokio or HTTP stack — docs/DESIGN.md
//! §Substitutions).
//!
//! One JSON object per line in, one JSON event per line out
//! (docs/DESIGN.md §Streaming protocol for the full grammar):
//!
//! ```text
//! → {"op":"generate","text":"hello","max_new_tokens":8,"adapter":"a","tag":1}
//! ← {"event":"admitted","id":3,"tag":1}
//! ← {"event":"token","id":3,"token":104,"pos":0,"ttft_ms":2.1,"tag":1}
//! ← {"event":"finished","id":3,"finish":"max_tokens","tokens":[...],"text":"...","tag":1}
//! → {"op":"cancel","id":3}
//! → {"op":"stats"}
//! ← {"event":"stats","stats":{...},"active_connections":1,"replicas":[...]}
//! ```
//!
//! The listener fronts a [`Router`] (docs/DESIGN.md §Data plane), so the
//! same protocol serves one engine or a fleet: `admitted` events carry
//! the serving `replica`, and `stats` answers with the merged fleet
//! aggregate under the legacy `stats` key plus per-replica
//! state/load/metrics rows under `replicas` and the listener's
//! `active_connections` gauge.  Single-replica fleets keep the wire
//! shape — clients that only read `stats` never notice a fleet.
//!
//! Requests on one connection run concurrently (each `generate` gets a
//! streaming thread; lines are interleaved per event, never split).  The
//! optional `tag` is echoed verbatim on every event of that request so
//! clients can correlate before they learn the engine-issued id.  A
//! dropped connection cancels its in-flight requests via the
//! [`FleetGeneration`] drop path — a hung-up client frees its decode
//! slots and releases its replica's load gauge.
//!
//! Peer input is treated as hostile: request lines are capped at
//! [`MAX_LINE_BYTES`] (overflow is discarded, not buffered) and the JSON
//! parser bounds its recursion depth, so no line a peer can send panics
//! or exhausts the connection thread — every malformed input comes back
//! as a typed `invalid` event on the same connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

use super::queue::EngineError;
use super::request::{Request, RequestOutput, SamplingParams, StreamEvent};
use super::router::{FleetGeneration, Router};

/// RAII increment of the listener's `active_connections` gauge: one per
/// live connection-handler thread, released on any exit path.
struct ConnGuard(Arc<AtomicUsize>);

impl ConnGuard {
    fn new(gauge: Arc<AtomicUsize>) -> ConnGuard {
        gauge.fetch_add(1, Ordering::AcqRel);
        ConnGuard(gauge)
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        // Saturating: the gauge can never underflow even if a guard
        // outlives a reset elsewhere.
        let _ =
            self.0.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| Some(v.saturating_sub(1)));
    }
}

/// Accept loop: one handler thread per connection, forever.  Callers bind
/// the listener themselves (so `--listen 127.0.0.1:0` can report the
/// chosen port before entering the loop).  The router decides which
/// replica serves each request; a single-replica fleet degenerates to the
/// pre-fleet behavior.
pub fn serve(listener: TcpListener, router: Router) -> Result<()> {
    let active: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let router = router.clone();
                let gauge = Arc::clone(&active);
                let spawned =
                    std::thread::Builder::new().name("road-conn".into()).spawn(move || {
                        let _guard = ConnGuard::new(Arc::clone(&gauge));
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "<unknown>".into());
                        if let Err(e) = handle_conn(stream, router, gauge) {
                            eprintln!("[serve] connection {peer}: {e:#}");
                        }
                    });
                // A transient spawn failure (fd/thread pressure) costs one
                // connection, not the whole front door — same policy as an
                // accept error below.
                if let Err(e) = spawned {
                    eprintln!("[serve] could not spawn connection thread: {e}");
                }
            }
            Err(e) => eprintln!("[serve] accept error: {e}"),
        }
    }
    Ok(())
}

/// One parsed request line.
enum WireCmd {
    Generate(Request, Option<Json>),
    Cancel(u64),
    Stats,
}

/// Upper bound on one request line.  `BufRead::lines` buffers however
/// many bytes the peer sends before the next `\n`, so an endless
/// newline-free stream would grow the connection thread's memory without
/// limit.  Past this cap the rest of the line is *discarded* (never
/// buffered), the peer gets a typed `invalid` event, and the connection
/// resyncs at the next newline.
const MAX_LINE_BYTES: usize = 1 << 20;

/// One bounded read from the wire (see [`MAX_LINE_BYTES`]).
enum LineRead {
    /// A complete line (without its `\n`), within the cap.
    Line(String),
    /// The line ran past the cap; payload is the total length seen.  The
    /// overflow was discarded chunk-by-chunk, and the reader is
    /// positioned just after the terminating newline (or at EOF).
    TooLong(usize),
    Eof,
}

/// Read up to the next `\n` without ever holding more than
/// [`MAX_LINE_BYTES`] + one `BufReader` chunk in memory.
fn read_line_bounded(r: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let (consumed, saw_newline) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(if dropped > 0 {
                    LineRead::TooLong(line.len() + dropped)
                } else if line.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line(String::from_utf8_lossy(&line).into_owned())
                });
            }
            let upto = chunk.iter().position(|&b| b == b'\n');
            let n = upto.unwrap_or(chunk.len());
            if dropped == 0 && line.len() + n <= MAX_LINE_BYTES {
                line.extend_from_slice(&chunk[..n]);
            } else {
                dropped += n;
            }
            // +1 swallows the newline itself.
            (n + usize::from(upto.is_some()), upto.is_some())
        };
        r.consume(consumed);
        if saw_newline {
            return Ok(if dropped > 0 {
                LineRead::TooLong(line.len() + dropped)
            } else {
                LineRead::Line(String::from_utf8_lossy(&line).into_owned())
            });
        }
    }
}

fn handle_conn(stream: TcpStream, router: Router, active: Arc<AtomicUsize>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(stream));
    loop {
        let line = match read_line_bounded(&mut reader)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong(n) => {
                let err = EngineError::Invalid {
                    reason: format!(
                        "request line of {n} bytes exceeds the {MAX_LINE_BYTES}-byte limit"
                    ),
                };
                write_line(&writer, &error_event(None, None, &err))?;
                continue;
            }
            LineRead::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(WireCmd::Generate(req, tag)) => {
                let router = router.clone();
                let writer = writer.clone();
                std::thread::Builder::new().name("road-stream".into()).spawn(move || {
                    stream_generation(&router, req, tag, &writer);
                })?;
            }
            Ok(WireCmd::Cancel(id)) => {
                // Best-effort; unknown/finished ids are no-ops by design.
                // The id's stride residue names its replica — no fan-out.
                let _ = router.cancel(id);
            }
            Ok(WireCmd::Stats) => {
                // Merged fleet aggregate under the legacy `stats` key, plus
                // the per-replica rows and the listener's connection gauge.
                let fleet = router.stats();
                let line = json::obj(vec![
                    ("event", json::s("stats")),
                    ("stats", fleet.merged.to_json()),
                    ("active_connections", json::num(active.load(Ordering::Acquire) as f64)),
                    ("replicas", fleet.replicas_json()),
                ]);
                write_line(&writer, &line)?;
            }
            Err(e) => {
                let err = EngineError::Invalid { reason: format!("{e:#}") };
                write_line(&writer, &error_event(None, None, &err))?;
            }
        }
    }
}

/// Drive one generation, relaying every stream event as an NDJSON line.
/// A failed write means the client hung up: returning drops the
/// [`FleetGeneration`], which auto-cancels the request in the engine and
/// releases the replica's load gauge.
fn stream_generation(
    router: &Router,
    req: Request,
    tag: Option<Json>,
    writer: &Arc<Mutex<TcpStream>>,
) {
    let mut generation: FleetGeneration = match router.submit(req) {
        Ok(g) => g,
        Err(e) => {
            let _ = write_line(writer, &error_event(None, tag.as_ref(), &e));
            return;
        }
    };
    let replica = generation.replica();
    while let Some(ev) = generation.recv() {
        if write_line(writer, &event_json(&ev, tag.as_ref(), Some(replica))).is_err() {
            return;
        }
        if ev.is_terminal() {
            return;
        }
    }
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, v: &Json) -> Result<()> {
    let mut line = v.to_string_compact();
    line.push('\n');
    let mut w = writer.lock().map_err(|_| anyhow!("writer poisoned"))?;
    w.write_all(line.as_bytes())?;
    w.flush()?;
    Ok(())
}

fn parse_line(line: &str) -> Result<WireCmd> {
    let v = Json::parse(line)?;
    let op = v.opt("op").map(|o| o.as_str()).transpose()?.unwrap_or("generate");
    match op {
        "generate" => {
            let req = parse_generate(&v)?;
            Ok(WireCmd::Generate(req, v.opt("tag").cloned()))
        }
        "cancel" => {
            let id = v.get("id")?.as_f64()? as u64;
            Ok(WireCmd::Cancel(id))
        }
        "stats" => Ok(WireCmd::Stats),
        other => bail!("unknown op {other:?} (generate|cancel|stats)"),
    }
}

fn parse_generate(v: &Json) -> Result<Request> {
    let prompt: Vec<i32> = match (v.opt("prompt"), v.opt("text")) {
        (Some(arr), _) => arr
            .as_arr()?
            .iter()
            .map(|t| t.as_f64().map(|f| f as i32))
            .collect::<Result<_>>()?,
        (None, Some(text)) => crate::tokenizer::encode(text.as_str()?),
        (None, None) => bail!("generate needs \"prompt\" (token array) or \"text\""),
    };
    let max_new = v.opt("max_new_tokens").map(|n| n.as_usize()).transpose()?.unwrap_or(16);
    let mut req = Request::new(prompt, max_new);
    if let Some(a) = v.opt("adapter") {
        req = req.with_adapter(a.as_str()?);
    }
    if let Some(p) = v.opt("priority") {
        let p = p.as_f64()?;
        // The priority policy's tiers are a u8; anything else is a typed
        // `invalid` error event, not a silent clamp.
        if !(0.0..=255.0).contains(&p) || p.fract() != 0.0 {
            bail!("priority must be an integer in [0, 255], got {p}");
        }
        req = req.with_priority(p as u8);
    }
    if let Some(ms) = v.opt("deadline_ms") {
        let ms = ms.as_f64()?;
        // Validate before Duration::from_secs_f64, which panics on
        // negative/NaN/overflowing input — a malformed field must produce
        // the typed `invalid` error event, not kill the connection thread.
        if !ms.is_finite() || !(0.0..=1e13).contains(&ms) {
            bail!("deadline_ms must be a finite number of milliseconds in [0, 1e13], got {ms}");
        }
        req = req.with_deadline(Duration::from_secs_f64(ms / 1e3));
    }
    let sampling = SamplingParams {
        temperature: v.opt("temperature").map(|t| t.as_f64()).transpose()?.unwrap_or(0.0) as f32,
        top_k: v.opt("top_k").map(|t| t.as_usize()).transpose()?.unwrap_or(0),
        seed: v.opt("seed").map(|t| t.as_f64()).transpose()?.unwrap_or(0.0) as u64,
        // `null` means "no stop token"; anything else must be a number —
        // swallowing a malformed value here would silently run the request
        // to max_new_tokens while every other field errors loudly.
        stop_token: v
            .opt("stop_token")
            .filter(|t| !matches!(t, Json::Null))
            .map(|t| t.as_f64().map(|f| f as i32))
            .transpose()?,
    };
    Ok(req.with_sampling(sampling))
}

fn with_tag(mut pairs: Vec<(&'static str, Json)>, tag: Option<&Json>) -> Json {
    if let Some(t) = tag {
        pairs.push(("tag", t.clone()));
    }
    json::obj(pairs)
}

/// `replica` stamps `admitted` events with the serving replica (fleet
/// placement is decided by then; later events correlate by id).
fn event_json(ev: &StreamEvent, tag: Option<&Json>, replica: Option<usize>) -> Json {
    match ev {
        StreamEvent::Admitted { id } => {
            let mut pairs = vec![("event", json::s("admitted")), ("id", json::num(*id as f64))];
            if let Some(r) = replica {
                pairs.push(("replica", json::num(r as f64)));
            }
            with_tag(pairs, tag)
        }
        StreamEvent::Token { id, token, pos, ttft_hint } => {
            let mut pairs = vec![
                ("event", json::s("token")),
                ("id", json::num(*id as f64)),
                ("token", json::num(*token as f64)),
                ("pos", json::num(*pos as f64)),
            ];
            if let Some(t) = ttft_hint {
                pairs.push(("ttft_ms", json::num(t * 1e3)));
            }
            with_tag(pairs, tag)
        }
        StreamEvent::Finished(out) => finished_event(out, tag),
        StreamEvent::Error { id, error } => with_tag(
            vec![
                ("event", json::s("error")),
                ("id", json::num(*id as f64)),
                ("error", json::s(error.kind())),
                ("message", json::s(&error.to_string())),
            ],
            tag,
        ),
    }
}

fn finished_event(out: &RequestOutput, tag: Option<&Json>) -> Json {
    let mut pairs = vec![
        ("event", json::s("finished")),
        ("id", json::num(out.id as f64)),
        ("finish", json::s(out.finish.as_str())),
        (
            "tokens",
            json::arr(out.tokens.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        ("text", json::s(&crate::tokenizer::decode(&out.tokens))),
        ("ttft_ms", json::num(out.ttft * 1e3)),
        ("e2e_ms", json::num(out.e2e * 1e3)),
    ];
    if let Some(a) = &out.adapter {
        pairs.push(("adapter", json::s(a)));
    }
    with_tag(pairs, tag)
}

fn error_event(id: Option<u64>, tag: Option<&Json>, e: &EngineError) -> Json {
    with_tag(
        vec![
            ("event", json::s("error")),
            ("id", id.map(|i| json::num(i as f64)).unwrap_or(Json::Null)),
            ("error", json::s(e.kind())),
            ("message", json::s(&e.to_string())),
        ],
        tag,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn parses_generate_with_all_fields() {
        let line = r#"{"op":"generate","prompt":[1,2,3],"max_new_tokens":5,"adapter":"a",
                       "temperature":0.5,"top_k":4,"seed":9,"stop_token":46,
                       "deadline_ms":250,"priority":2,"tag":"x"}"#
            .replace('\n', " ");
        let WireCmd::Generate(req, tag) = parse_line(&line).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.adapter.as_deref(), Some("a"));
        assert_eq!(req.sampling.top_k, 4);
        assert_eq!(req.sampling.seed, 9);
        assert_eq!(req.sampling.stop_token, Some(46));
        assert_eq!(req.deadline, Some(Duration::from_millis(250)));
        assert_eq!(req.priority, 2);
        assert_eq!(tag, Some(json::s("x")));
    }

    #[test]
    fn priority_is_validated_not_clamped() {
        let WireCmd::Generate(req, _) = parse_line(r#"{"text":"x"}"#).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.priority, 0, "default tier");
        assert!(parse_line(r#"{"text":"x","priority":999}"#).is_err());
        assert!(parse_line(r#"{"text":"x","priority":-1}"#).is_err());
        assert!(parse_line(r#"{"text":"x","priority":1.5}"#).is_err());
        let WireCmd::Generate(req, _) = parse_line(r#"{"text":"x","priority":255}"#).unwrap()
        else {
            panic!("expected generate")
        };
        assert_eq!(req.priority, 255);
    }

    #[test]
    fn generate_is_the_default_op_and_text_tokenizes() {
        let WireCmd::Generate(req, tag) = parse_line(r#"{"text":"hi"}"#).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.prompt, crate::tokenizer::encode("hi"));
        assert_eq!(req.max_new_tokens, 16, "default budget");
        assert!(tag.is_none());
    }

    #[test]
    fn rejects_missing_prompt_and_unknown_op() {
        assert!(parse_line(r#"{"op":"generate"}"#).is_err());
        assert!(parse_line(r#"{"op":"frobnicate"}"#).is_err());
        assert!(parse_line("not json").is_err());
    }

    #[test]
    fn rejects_unconvertible_deadlines_instead_of_panicking() {
        // Duration::from_secs_f64 panics on these; the parser must turn
        // them into typed errors before they reach it.
        assert!(parse_line(r#"{"text":"x","deadline_ms":-5}"#).is_err());
        assert!(parse_line(r#"{"text":"x","deadline_ms":1e300}"#).is_err());
        assert!(parse_line(r#"{"text":"x","deadline_ms":0}"#).is_ok(), "zero budget is valid");
    }

    #[test]
    fn stop_token_is_strict_but_nullable() {
        let WireCmd::Generate(req, _) =
            parse_line(r#"{"text":"x","stop_token":null}"#).unwrap()
        else {
            panic!("expected generate")
        };
        assert_eq!(req.sampling.stop_token, None, "null means no stop token");
        assert!(
            parse_line(r#"{"text":"x","stop_token":"."}"#).is_err(),
            "non-numeric stop_token must error loudly, not run to max_new_tokens"
        );
    }

    #[test]
    fn parses_cancel_and_stats() {
        assert!(matches!(parse_line(r#"{"op":"cancel","id":7}"#).unwrap(), WireCmd::Cancel(7)));
        assert!(matches!(parse_line(r#"{"op":"stats"}"#).unwrap(), WireCmd::Stats));
        assert!(parse_line(r#"{"op":"cancel"}"#).is_err(), "cancel needs an id");
    }

    /// Wire-level robustness over a real loopback connection (reference
    /// backend, no artifacts): malformed JSON, an unknown op, a missing
    /// prompt, an out-of-range priority, an oversized prompt, a
    /// stack-hostile deeply nested document, and a line past the
    /// [`MAX_LINE_BYTES`] wire cap each yield a typed `invalid` error
    /// event — no panic, no disconnect — and the same connection then
    /// serves a valid request to completion.
    #[test]
    fn bad_lines_yield_typed_invalid_and_connection_survives() {
        use crate::coordinator::engine::EngineConfig;
        use crate::coordinator::router::{Fleet, PlaceKind};
        use std::net::TcpListener;

        let econf = EngineConfig {
            model: "tiny".into(),
            mode: "base".into(),
            decode_slots: 2,
            queue_capacity: 16,
            backend: crate::runtime::BackendKind::Reference,
            ..Default::default()
        };
        let (fleet, router) = Fleet::start(
            econf,
            crate::manifest::Manifest::default_dir(),
            1,
            PlaceKind::Affinity,
            |_| Ok(()),
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = serve(listener, router);
        });

        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut round_trip = |line: &str| -> Json {
            conn.write_all(line.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut out = String::new();
            assert!(reader.read_line(&mut out).unwrap() > 0, "connection closed after {line:?}");
            Json::parse(out.trim()).unwrap()
        };

        // The tiny model's largest prefill bucket is 16 tokens; 99 zeros
        // overflow it — rejected by the engine, not the parser.
        let oversized = format!(
            "{{\"op\":\"generate\",\"prompt\":[{}]}}",
            vec!["1"; 99].join(",")
        );
        // Deep enough to overflow the connection thread's stack if the
        // JSON parser recursed without a depth cap.
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let bad_lines = [
            "this is not json",
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"generate"}"#,
            r#"{"op":"generate","text":"x","priority":999}"#,
            oversized.as_str(),
            deep.as_str(),
        ];
        for line in bad_lines {
            let ev = round_trip(line);
            assert_eq!(
                ev.get("event").unwrap().as_str().unwrap(),
                "error",
                "expected error event for {line:?}"
            );
            assert_eq!(
                ev.get("error").unwrap().as_str().unwrap(),
                EngineError::Invalid { reason: String::new() }.kind(),
                "stable `invalid` kind for {line:?}"
            );
        }

        // A line past the wire cap is discarded without being buffered
        // and answered with the same typed event; the connection resyncs
        // at the next newline.
        let huge = format!("{{\"text\":\"{}\"}}", "x".repeat(MAX_LINE_BYTES));
        let ev = round_trip(&huge);
        assert_eq!(ev.get("event").unwrap().as_str().unwrap(), "error");
        assert_eq!(ev.get("error").unwrap().as_str().unwrap(), "invalid");
        assert!(
            ev.get("message").unwrap().as_str().unwrap().contains("exceeds"),
            "oversized line should name the cap: {ev:?}"
        );

        // The connection is still usable: a valid request streams to a
        // finished event, and `admitted` names the serving replica.
        conn.write_all(b"{\"op\":\"generate\",\"prompt\":[3,4,5],\"max_new_tokens\":2}\n")
            .unwrap();
        let mut kinds = Vec::new();
        loop {
            let mut out = String::new();
            assert!(reader.read_line(&mut out).unwrap() > 0, "closed mid-stream");
            let ev = Json::parse(out.trim()).unwrap();
            let kind = ev.get("event").unwrap().as_str().unwrap().to_string();
            assert_ne!(kind, "error", "valid request errored: {out}");
            if kind == "admitted" {
                assert_eq!(ev.get("replica").unwrap().as_usize().unwrap(), 0, "{out}");
            }
            kinds.push(kind.clone());
            if kind == "finished" {
                assert_eq!(ev.get("tokens").unwrap().as_arr().unwrap().len(), 2);
                break;
            }
        }
        assert_eq!(kinds.first().map(String::as_str), Some("admitted"));
        assert_eq!(kinds.iter().filter(|k| *k == "token").count(), 2);

        // The fleet `stats` shape: merged aggregate under the legacy key,
        // per-replica rows, and this very connection on the gauge.
        conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
        let mut out = String::new();
        assert!(reader.read_line(&mut out).unwrap() > 0, "closed on stats");
        let stats = Json::parse(out.trim()).unwrap();
        assert_eq!(stats.get("event").unwrap().as_str().unwrap(), "stats");
        assert!(
            stats.get("stats").unwrap().get("requests_completed").unwrap().as_usize().unwrap()
                >= 1,
            "{stats:?}"
        );
        assert!(stats.get("active_connections").unwrap().as_usize().unwrap() >= 1);
        let replicas = stats.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(replicas.len(), 1);
        assert_eq!(replicas[0].get("replica").unwrap().as_usize().unwrap(), 0);
        assert_eq!(replicas[0].get("state").unwrap().as_str().unwrap(), "ready");
        fleet.shutdown().unwrap();
    }

    #[test]
    fn event_lines_are_single_line_json_with_tag_echo() {
        let tag = json::num(42.0);
        let events = [
            StreamEvent::Admitted { id: 3 },
            StreamEvent::Token { id: 3, token: 104, pos: 0, ttft_hint: Some(0.002) },
            StreamEvent::Finished(RequestOutput {
                id: 3,
                adapter: Some("a".into()),
                tokens: vec![104, 105],
                finish: FinishReason::MaxTokens,
                ttft: 0.002,
                e2e: 0.01,
            }),
            StreamEvent::Error { id: 3, error: EngineError::DeadlineExceeded },
        ];
        for ev in &events {
            let line = event_json(ev, Some(&tag), Some(1)).to_string_compact();
            assert!(!line.contains('\n'), "{line}");
            let back = Json::parse(&line).unwrap();
            assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 3);
            assert_eq!(back.get("tag").unwrap().as_usize().unwrap(), 42);
        }
        // Only `admitted` carries the replica stamp; later events
        // correlate by id.
        let adm = event_json(&events[0], None, Some(1));
        assert_eq!(adm.get("replica").unwrap().as_usize().unwrap(), 1);
        assert!(event_json(&events[1], None, Some(1)).opt("replica").is_none());
        let fin = event_json(&events[2], None, None);
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "max_tokens");
        assert_eq!(fin.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        let err = event_json(&events[3], None, None);
        assert_eq!(err.get("error").unwrap().as_str().unwrap(), "deadline_exceeded");
    }
}
