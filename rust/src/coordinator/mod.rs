//! Layer-3 coordinator: the paper's serving contribution as a running
//! system — request admission under a pluggable scheduling policy
//! (FCFS / EDF / priority / fair-share on a substitutable clock), a
//! virtualized adapter registry (host store + LRU-paged device bank),
//! continuous batching over decode slots, KV-slot management, sampling,
//! metrics, a streaming client API with first-class cancellation and
//! deadlines, and an NDJSON-over-TCP front end for external clients.

pub mod engine;
pub mod kv;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod queue;
pub mod replica;
pub mod request;
pub mod router;
pub mod sampler;
pub mod sched;
pub mod server;
pub mod step;

pub use engine::{Engine, EngineConfig};
pub use metrics::MetricsSnapshot;
pub use queue::EngineError;
pub use replica::{Replica, ReplicaHealth, ReplicaState};
pub use request::{FinishReason, Request, RequestOutput, SamplingParams, StreamEvent};
pub use router::{
    Fleet, FleetGeneration, FleetSim, FleetSimConfig, FleetStats, PlaceKind, Placement, Placer,
    ReplicaView, Router,
};
pub use sched::{PolicyKind, PrefillModel, SchedPolicy, SchedSim};
pub use server::{EngineClient, EngineServer, Generation};
