pub fn parse(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn arity(v: Option<u32>) -> u32 {
    v.expect("three outputs")
}

pub fn boom() {
    panic!("connection thread dies here");
}

pub fn guarded(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
