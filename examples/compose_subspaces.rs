//! Composability demo (Figure 5): train two tasks simultaneously into
//! disjoint halves of R, then show each half and their combination.
//!
//! ```bash
//! cargo run --release --example compose_subspaces
//! ```

use std::rc::Rc;

use anyhow::Result;

use road::compose;
use road::coordinator::engine::{Engine, EngineConfig};
use road::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    println!("training both subspaces (upper half: foreign echo, lower half: native reverse)...");
    let out = compose::train_composed(&rt, "train", 300, 0)?;
    println!("losses: A={:.3} B={:.3}", out.loss_a, out.loss_b);

    let econf = EngineConfig {
        model: "train".into(),
        mode: "road".into(),
        decode_slots: 8,
        queue_capacity: 256,
        ..Default::default()
    };
    let mut engine = Engine::new(rt, econf)?;
    let a = compose::ForeignEcho;
    let b = compose::NativeReverse;
    for (name, adapter) in [
        ("upper-half(A)", &out.adapter_a),
        ("lower-half(B)", &out.adapter_b),
        ("combined", &out.combined),
    ] {
        let sa = compose::score_adapter(&mut engine, name, adapter, &a, 24, 1)?;
        let sb = compose::score_adapter(&mut engine, name, adapter, &b, 24, 2)?;
        println!("{name:<16} task-A EM {sa:.3}   task-B EM {sb:.3}");
    }

    println!("\nqualitative samples with the combined adapter:");
    for t in compose::sample_responses(
        &mut engine,
        "combined",
        &["g:fa>".to_string(), "i:fa>".to_string()],
        10,
    )? {
        println!("  {}  ->  {}", t.prompt, t.response);
    }
    Ok(())
}
