//! Offline shim of the `anyhow` API surface the `road` crate uses.
//!
//! The build image carries no crates.io registry, so this path crate stands
//! in for the real `anyhow`.  It provides:
//!
//! * [`Error`] — a context-carrying error with `downcast_ref` to the
//!   original typed error (used by the engine to detect
//!   `EngineError::QueueFull` without string matching),
//! * [`Result`] with a defaulted error type,
//! * [`anyhow!`] / [`bail!`] macros,
//! * the [`Context`] extension trait (`.context` / `.with_context`).
//!
//! Display intentionally renders the full context chain outermost-first
//! ("loading x: reading y: No such file"); the real anyhow reserves that for
//! `{:#}` and shows only the outermost layer in `{}`.  Every call site in
//! this repository treats the message as human-facing text, so the richer
//! default is the safer substitution.

use std::any::Any;
use std::fmt;

/// Object-safe carrier for the original error: formatting plus `Any` for
/// typed downcasts.  Blanket-implemented for anything `Display + Debug`.
trait ErrObj: Any + Send + Sync {
    fn msg(&self) -> String;
    fn as_any(&self) -> &dyn Any;
}

impl<E: fmt::Display + fmt::Debug + Send + Sync + 'static> ErrObj for E {
    fn msg(&self) -> String {
        format!("{self}")
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// A dynamic error with a stack of context strings around a typed root.
pub struct Error {
    /// Context layers, outermost first.
    ctx: Vec<String>,
    root: Box<dyn ErrObj>,
}

/// Root payload for errors born from a message (`anyhow!("...")`).
#[derive(Debug)]
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Error {
    /// Build an error from a plain message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { ctx: Vec::new(), root: Box::new(Message(m.to_string())) }
    }

    /// Wrap with an outer context layer (what `Context::context` uses).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.ctx.insert(0, c.to_string());
        self
    }

    /// Borrow the original typed root error, if it is a `T`.
    ///
    /// Context layers do not change the root, so an `EngineError` pushed
    /// through several `.context(...)` wrappers still downcasts.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.root.as_any().downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.ctx {
            write!(f, "{c}: ")?;
        }
        f.write_str(&self.root.msg())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { ctx: Vec::new(), root: Box::new(e) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    #[derive(Debug, PartialEq)]
    struct Marker(u32);

    impl std::fmt::Display for Marker {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "marker {}", self.0)
        }
    }

    impl std::error::Error for Marker {}

    #[test]
    fn message_and_context_chain() {
        let e: Error = crate::anyhow!("root {}", 7);
        assert_eq!(e.to_string(), "root 7");
        let r: Result<()> = Err(e);
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root 7");
    }

    #[test]
    fn downcast_survives_context() {
        let r: Result<()> = Err(Marker(3).into());
        let e = r.with_context(|| "wrapped").unwrap_err();
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(3)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        assert_eq!(e.to_string(), "wrapped: marker 3");
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner() -> Result<()> {
            crate::bail!("boom {}", 1)
        }
        fn outer() -> Result<()> {
            inner().context("ctx")?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "ctx: boom 1");
    }
}
