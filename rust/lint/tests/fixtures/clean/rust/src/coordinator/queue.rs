pub enum EngineError {
    QueueFull,
    Invalid,
}

impl EngineError {
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::QueueFull => "queue_full",
            EngineError::Invalid => "invalid",
        }
    }
}
