"""Training-graph correctness: every PEFT method's step graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, train
from compile.kernels import ref

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, CFG.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((4, 16)).at[:, -1].set(0.0)
    return toks, tgts, mask


def opt_state(t):
    return ({k: jnp.zeros_like(v) for k, v in t.items()},
            {k: jnp.zeros_like(v) for k, v in t.items()})


class TestAllMethodsTrain:
    @pytest.mark.parametrize("method", train.METHODS)
    def test_loss_decreases(self, params, batch, method):
        toks, tgts, mask = batch
        t = train.init_trainable(CFG, method, jax.random.PRNGKey(2), params)
        m, v = opt_state(t)
        frozen = {} if method == "full" else params
        gm = {k: jnp.ones_like(x) for k, x in t.items()} \
            if method == "road1_masked" else None
        losses = []
        for step in range(4):
            if gm is not None:
                t, m, v, loss = train.train_step(
                    CFG, method, frozen, t, m, v, jnp.float32(step + 1),
                    jnp.float32(3e-3), toks, tgts, mask, grad_mask=gm)
            else:
                t, m, v, loss = train.train_step(
                    CFG, method, frozen, t, m, v, jnp.float32(step + 1),
                    jnp.float32(3e-3), toks, tgts, mask)
            losses.append(float(loss))
        assert losses[-1] < losses[0], (method, losses)

    @pytest.mark.parametrize("method",
                             ["road1", "road2", "road4", "lora", "ia3",
                              "oft2", "bitfit"])
    def test_init_preserves_base_model(self, params, batch, method):
        """Step-0 loss equals the frozen base model's loss (paper: 'we
        always initialize alpha=1 and theta=0')."""
        toks, tgts, mask = batch
        t = train.init_trainable(CFG, method, jax.random.PRNGKey(2), params)
        _, base_loss = train.eval_loss(CFG, method, params, t, toks, tgts,
                                       mask)
        ids = jnp.zeros((4,), dtype=jnp.int32)
        logits = model.full_forward(CFG, "base", params, {}, ids, toks)
        _, ref_loss = train.masked_nll(logits, tgts, mask)
        np.testing.assert_allclose(float(base_loss), float(ref_loss),
                                   rtol=1e-4)


class TestGradMask:
    def test_masked_blocks_stay_identity(self, params, batch):
        """Composability protocol (Fig 5): gradient-masked halves of R must
        remain exactly at identity while the others train."""
        toks, tgts, mask = batch
        t = train.init_trainable(CFG, "road1_masked", jax.random.PRNGKey(2),
                                 params)
        m, v = opt_state(t)
        gm = {}
        for k, x in t.items():
            g = jnp.zeros_like(x)
            half = x.shape[0] // 2
            gm[k] = g.at[:half].set(1.0)  # only the UPPER half trains
        for step in range(3):
            t, m, v, _ = train.train_step(
                CFG, "road1_masked", params, t, m, v, jnp.float32(step + 1),
                jnp.float32(5e-3), toks, tgts, mask, grad_mask=gm)
        for k, x in t.items():
            half = x.shape[0] // 2
            if k.endswith(".theta"):
                np.testing.assert_allclose(x[half:], jnp.zeros(half),
                                           atol=1e-7)
                assert float(jnp.abs(x[:half]).max()) > 1e-5
            else:
                np.testing.assert_allclose(x[half:], jnp.ones(half),
                                           atol=1e-7)


class TestEvalEntries:
    def test_eval_loss_per_example_consistent_with_mean(self, params, batch):
        toks, tgts, mask = batch
        t = train.init_trainable(CFG, "road1", jax.random.PRNGKey(2), params)
        per_ex, total = train.eval_loss(CFG, "road1", params, t, toks, tgts,
                                        mask)
        assert per_ex.shape == (4,)
        # total is token-weighted; with uniform mask rows it equals row mean
        np.testing.assert_allclose(float(per_ex.mean()), float(total),
                                   rtol=1e-4)

    def test_last_logits_matches_full_forward(self, params, batch):
        toks, _, _ = batch
        lens = jnp.array([16, 9, 5, 1], dtype=jnp.int32)
        t = train.init_trainable(CFG, "road1", jax.random.PRNGKey(2), params)
        lg = train.last_logits(CFG, "road1", params, t, toks, lens)
        ids = jnp.zeros((4,), dtype=jnp.int32)
        full = model.full_forward(CFG, "base", params, {}, ids, toks)
        for i, ln in enumerate([16, 9, 5, 1]):
            np.testing.assert_allclose(lg[i], full[i, ln - 1], rtol=2e-4,
                                       atol=2e-4)


class TestAdamW:
    def test_matches_manual_two_steps(self):
        g = jnp.array([0.5, -1.0])
        p = jnp.array([1.0, 1.0])
        m = jnp.zeros(2)
        v = jnp.zeros(2)
        p1, m1, v1 = train.adamw_update(g, p, m, v, jnp.float32(1.0),
                                        jnp.float32(0.1))
        # bias-corrected first step = full sgd-like step of size lr*sign(g)
        np.testing.assert_allclose(p1, p - 0.1 * jnp.sign(g) *
                                   (jnp.abs(g) / (jnp.abs(g) + 1e-8)),
                                   rtol=1e-4)
        m_exp = 0.1 * g
        v_exp = 0.001 * g * g
        np.testing.assert_allclose(m1, m_exp, rtol=1e-5)
        np.testing.assert_allclose(v1, v_exp, rtol=1e-5)


class TestDisentangleHead:
    @pytest.mark.parametrize("head_mode", train.HEAD_MODES)
    def test_head_trains(self, head_mode):
        d, k, b = 16, 4, 64
        key = jax.random.PRNGKey(0)
        head = train.head_init(d, k, key)
        m, v = opt_state(head)
        # Separable synthetic reps: class determined by direction.
        dirs = jax.random.normal(jax.random.PRNGKey(1), (k, d))
        labels = jax.random.randint(jax.random.PRNGKey(2), (b,), 0, k)
        reps = dirs[labels] + 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                                      (b, d))
        losses = []
        for step in range(30):
            head, m, v, loss = train.head_train_step(
                head, m, v, jnp.float32(step + 1), jnp.float32(1e-2), reps,
                labels, head_mode)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        if head_mode in ("normal", "angle"):
            # direction-coded labels are learnable without magnitude
            logits = train.head_logits(head, reps, head_mode)
            acc = float((logits.argmax(-1) == labels).mean())
            assert acc > 0.5, (head_mode, acc)

    def test_mag_mode_ignores_direction(self):
        """Magnitude-only scoring cannot separate classes that differ only
        in direction — the pilot study's point (Fig 2 Right)."""
        d, k = 16, 4
        head = train.head_init(d, k, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, d))
        rot = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(2),
                                              (d, d)))[0]
        x_rot = x @ rot  # same norm, different direction
        lg1 = train.head_logits(head, x, "mag")
        lg2 = train.head_logits(head, x_rot, "mag")
        np.testing.assert_allclose(lg1, lg2, rtol=1e-3, atol=1e-4)
