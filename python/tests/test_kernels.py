"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/batch compositions; explicit tests pin down the
algebraic invariants of the RoAd transform (Eq. 2-4 of the paper).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, road, lora, ia3

TOL = dict(rtol=1e-4, atol=1e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# pairswap
# ---------------------------------------------------------------------------

class TestPairswap:
    def test_example(self):
        h = jnp.array([1.0, 2.0, 3.0, 4.0])
        np.testing.assert_allclose(ref.pairswap(h), [-2.0, 1.0, -4.0, 3.0])

    def test_double_swap_negates(self):
        h = rand(0, (3, 8))
        np.testing.assert_allclose(ref.pairswap(ref.pairswap(h)), -h, **TOL)

    def test_norm_preserved(self):
        h = rand(1, (5, 16))
        np.testing.assert_allclose(
            jnp.linalg.norm(ref.pairswap(h), axis=-1),
            jnp.linalg.norm(h, axis=-1), **TOL)

    def test_orthogonal_to_input_per_pair(self):
        # Each 2D pair of pairswap(h) is orthogonal to the same pair of h.
        h = rand(2, (4, 12))
        hp = h.reshape(4, 6, 2)
        sp = ref.pairswap(h).reshape(4, 6, 2)
        dots = (hp * sp).sum(-1)
        np.testing.assert_allclose(dots, jnp.zeros_like(dots), atol=1e-5)


# ---------------------------------------------------------------------------
# RoAd variant parameterizations
# ---------------------------------------------------------------------------

class TestRoadVectors:
    def test_identity_init(self):
        for var, shape in [(1, (8,)), (2, (8, 2)), (4, (8, 4))]:
            theta = jnp.zeros(shape)
            alpha = jnp.ones(shape)
            r1, r2 = ref.ROAD_VECTOR_FNS[var](theta, alpha)
            np.testing.assert_allclose(r1, jnp.ones(16))
            np.testing.assert_allclose(r2, jnp.zeros(16))

    def test_road1_pure_rotation_preserves_pair_norm(self):
        theta = rand(3, (8,))
        alpha = jnp.ones((8,))
        r1, r2 = ref.road_vectors_1(theta, alpha)
        h = rand(4, (5, 16))
        z = ref.road_apply(h, r1, r2)
        np.testing.assert_allclose(
            jnp.linalg.norm(z.reshape(5, 8, 2), axis=-1),
            jnp.linalg.norm(h.reshape(5, 8, 2), axis=-1), **TOL)

    def test_road1_alpha_scales_magnitude(self):
        theta = jnp.zeros((4,))
        alpha = jnp.full((4,), 2.0)
        r1, r2 = ref.road_vectors_1(theta, alpha)
        h = rand(5, (3, 8))
        np.testing.assert_allclose(ref.road_apply(h, r1, r2), 2.0 * h, **TOL)

    def test_road2_reduces_to_road1_when_shared(self):
        theta = rand(6, (8,))
        alpha = 1.0 + 0.1 * rand(7, (8,))
        r1a, r2a = ref.road_vectors_1(theta, alpha)
        t2 = jnp.stack([theta, theta], axis=-1)
        a2 = jnp.stack([alpha, alpha], axis=-1)
        r1b, r2b = ref.road_vectors_2(t2, a2)
        np.testing.assert_allclose(r1a, r1b, **TOL)
        np.testing.assert_allclose(r2a, r2b, **TOL)

    def test_road4_reduces_to_road2(self):
        t2 = rand(8, (8, 2))
        a2 = 1.0 + 0.1 * rand(9, (8, 2))
        r1a, r2a = ref.road_vectors_2(t2, a2)
        t4 = jnp.stack([t2[:, 0], t2[:, 0], t2[:, 1], t2[:, 1]], axis=-1)
        a4 = jnp.stack([a2[:, 0], a2[:, 0], a2[:, 1], a2[:, 1]], axis=-1)
        r1b, r2b = ref.road_vectors_4(t4, a4)
        np.testing.assert_allclose(r1a, r1b, **TOL)
        np.testing.assert_allclose(r2a, r2b, **TOL)

    def test_trainable_counts_match_table1(self):
        d = 32
        # Table 1: d, 2d, 4d trainable parameters for RoAd_1/2/4 (theta and
        # alpha together: road1 stores d/2 theta + d/2 alpha = d, etc).
        assert 2 * (d // 2) == d
        assert 2 * (d // 2) * 2 == 2 * d
        assert 2 * (d // 2) * 4 == 4 * d


# ---------------------------------------------------------------------------
# Dense-matrix / sparse-apply equivalence (Eq. 4)
# ---------------------------------------------------------------------------

class TestDenseEquivalence:
    def test_apply_matches_dense_matmul(self):
        theta = rand(10, (8,))
        alpha = 1.0 + 0.2 * rand(11, (8,))
        r1, r2 = ref.road_vectors_1(theta, alpha)
        m = ref.road_dense_matrix(r1, r2)
        h = rand(12, (5, 16))
        np.testing.assert_allclose(ref.road_apply(h, r1, r2), h @ m.T, **TOL)

    def test_dense_matrix_orthogonal_when_pure_rotation(self):
        theta = rand(13, (8,))
        r1, r2 = ref.road_vectors_1(theta, jnp.ones((8,)))
        m = ref.road_dense_matrix(r1, r2)
        np.testing.assert_allclose(m @ m.T, jnp.eye(16), atol=1e-5)

    def test_merge_equals_apply(self):
        theta = rand(14, (8,))
        alpha = 1.0 + 0.2 * rand(15, (8,))
        r1, r2 = ref.road_vectors_1(theta, alpha)
        w0 = rand(16, (12, 16))
        x = rand(17, (5, 12))
        merged = ref.road_merge(w0, r1, r2)
        np.testing.assert_allclose(
            x @ merged, ref.road_apply(x @ w0, r1, r2), **TOL)

    def test_lora_merge_equals_apply(self):
        w0 = rand(18, (12, 16))
        lb = rand(19, (12, 4))
        la = rand(20, (4, 16))
        x = rand(21, (5, 12))
        np.testing.assert_allclose(
            x @ ref.lora_merge(w0, lb, la),
            x @ w0 + (x @ lb) @ la, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles (hypothesis shape sweeps)
# ---------------------------------------------------------------------------

shapes = st.tuples(st.integers(1, 5), st.sampled_from([1, 2, 3, 4, 8, 16]),
                   st.sampled_from([2, 4, 8, 16, 64]))


class TestPallasVsRef:
    @settings(max_examples=15, deadline=None)
    @given(shapes, st.integers(1, 6), st.integers(0, 10 ** 6))
    def test_road_batched(self, shp, n_adapters, seed):
        b, l, d = shp
        k = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(k, 4)
        h = jax.random.normal(k1, (b, l, d))
        r1 = jax.random.normal(k2, (n_adapters, d))
        r2 = jax.random.normal(k3, (n_adapters, d))
        ids = jax.random.randint(k4, (b,), 0, n_adapters)
        np.testing.assert_allclose(
            road.road_batched_apply(h, r1, r2, ids),
            ref.road_batched_apply(h, r1, r2, ids), **TOL)

    @settings(max_examples=10, deadline=None)
    @given(shapes, st.integers(0, 10 ** 6))
    def test_road_single(self, shp, seed):
        b, l, d = shp
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        h = jax.random.normal(k1, (b, l, d))
        r1 = jax.random.normal(k2, (d,))
        r2 = jax.random.normal(k3, (d,))
        np.testing.assert_allclose(road.road_apply(h, r1, r2),
                                   ref.road_apply(h, r1, r2), **TOL)

    @settings(max_examples=10, deadline=None)
    @given(shapes, st.integers(1, 4), st.sampled_from([1, 2, 4, 8]),
           st.integers(0, 10 ** 6))
    def test_lora_batched(self, shp, n_adapters, rank, seed):
        b, l, d1 = shp
        d2 = d1  # output dim
        k = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(k, 4)
        h = jax.random.normal(k1, (b, l, d1))
        lb = jax.random.normal(k2, (n_adapters, d1, rank))
        la = jax.random.normal(k3, (n_adapters, rank, d2))
        ids = jax.random.randint(k4, (b,), 0, n_adapters)
        np.testing.assert_allclose(
            lora.lora_batched_apply(h, lb, la, ids),
            ref.lora_batched_apply(h, lb, la, ids), rtol=1e-3, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(shapes, st.integers(1, 6), st.integers(0, 10 ** 6))
    def test_ia3_batched(self, shp, n_adapters, seed):
        b, l, d = shp
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        h = jax.random.normal(k1, (b, l, d))
        s = jax.random.normal(k2, (n_adapters, d))
        ids = jax.random.randint(k3, (b,), 0, n_adapters)
        np.testing.assert_allclose(ia3.ia3_batched_apply(h, s, ids),
                                   ref.ia3_batched_apply(h, s, ids), **TOL)

    def test_heterogeneous_equals_per_request_loop(self):
        """Paper §3.2 batching: one batched call == per-request calls."""
        b, l, d, n = 4, 8, 16, 4
        h = rand(30, (b, l, d))
        r1 = rand(31, (n, d))
        r2 = rand(32, (n, d))
        ids = jnp.array([3, 1, 0, 2], dtype=jnp.int32)
        batched = road.road_batched_apply(h, r1, r2, ids)
        for i in range(b):
            solo = ref.road_apply(h[i], r1[ids[i]], r2[ids[i]])
            np.testing.assert_allclose(batched[i], solo, **TOL)


# ---------------------------------------------------------------------------
# OFT baseline (Cayley)
# ---------------------------------------------------------------------------

class TestOft:
    @pytest.mark.parametrize("w", [2, 4, 8, 16])
    def test_cayley_orthogonal(self, w):
        q = 0.3 * rand(40 + w, (5, w, w))
        r = ref.oft_cayley_blocks(q)
        eye = jnp.broadcast_to(jnp.eye(w), (5, w, w))
        np.testing.assert_allclose(
            jnp.einsum("nij,nkj->nik", r, r), eye, atol=1e-4)

    def test_gauss_jordan_matches_numpy(self):
        a = np.eye(8, dtype=np.float32)[None] + \
            0.2 * np.random.default_rng(0).standard_normal((3, 8, 8)).astype(np.float32)
        a = a + np.transpose(a, (0, 2, 1))  # symmetric + dominant-ish
        a += 8 * np.eye(8, dtype=np.float32)
        inv = ref._gauss_jordan_inverse(jnp.asarray(a))
        np.testing.assert_allclose(inv, np.linalg.inv(a), rtol=1e-3,
                                   atol=1e-4)

    def test_closed_form_w2_matches_general(self):
        q = 0.4 * rand(50, (6, 2, 2))
        r2 = ref.oft_cayley_blocks(q)
        # general Gauss-Jordan path
        skew = q - jnp.swapaxes(q, -1, -2)
        eye = jnp.broadcast_to(jnp.eye(2), (6, 2, 2))
        inv = ref._gauss_jordan_inverse(eye - skew)
        rg = jnp.einsum("nij,njk->nik", eye + skew, inv)
        np.testing.assert_allclose(r2, rg, rtol=1e-4, atol=1e-5)

    def test_identity_at_init(self):
        q = jnp.zeros((4, 2, 2))
        h = rand(51, (3, 8))
        np.testing.assert_allclose(ref.oft_apply(h, q), h, **TOL)

    def test_oft_w2_is_2d_rotation(self):
        """RoAd == OFT_{w=2} (paper §3.2): same orbit, different params."""
        q = jnp.array([[[0.0, 0.7], [0.0, 0.0]]])
        r = ref.oft_cayley_blocks(q)[0]
        # r is [[cos a, sin a], [-sin a, cos a]] for a = 2*atan(0.7)
        a = 2 * np.arctan(0.7)
        np.testing.assert_allclose(
            r, [[np.cos(a), np.sin(a)], [-np.sin(a), np.cos(a)]], atol=1e-5)


# ---------------------------------------------------------------------------
# DII framing (Eq. 1, paper §2.3/§3.2)
# ---------------------------------------------------------------------------

class TestDII:
    def test_road_is_dii_with_source_h(self):
        """Phi(h) = R h = h + R^T(R h - R h) ... wait — verify the paper's
        claim via the rotation form: with orthonormal R rows and s = h,
        DII(b=h, s=h, R) = h; RoAd instead *rotates* in the kept subspace.
        We verify the DII identity itself and that pure-rotation RoAd
        preserves the complement of the intervened subspace."""
        d, k = 16, 4
        r = jnp.linalg.qr(rand(60, (d, d)))[0][:k]  # orthonormal rows [k,d]
        b = rand(61, (3, d))
        s = rand(62, (3, d))
        out = ref.dii(b, s, r)
        # Projection onto rowspace(r) equals s's projection:
        np.testing.assert_allclose(out @ r.T, s @ r.T, atol=1e-4)
        # Complement untouched:
        comp = jnp.eye(d) - r.T @ r
        np.testing.assert_allclose(out @ comp, b @ comp, atol=1e-4)

    def test_subspace_rotation_locality(self):
        """Rotating blocks i<d/4 leaves dims >= d/2 untouched — the basis of
        the composability protocol (train disjoint halves of R)."""
        d = 16
        theta = jnp.zeros((d // 2,)).at[: d // 4].set(0.5)
        r1, r2 = ref.road_vectors_1(theta, jnp.ones((d // 2,)))
        h = rand(63, (5, d))
        z = ref.road_apply(h, r1, r2)
        np.testing.assert_allclose(z[:, d // 2:], h[:, d // 2:], **TOL)
        assert float(jnp.abs(z[:, : d // 2] - h[:, : d // 2]).max()) > 1e-3
