//! Synthetic task suites: the benchmark substitutions for GLUE (Table 2),
//! the eight commonsense tasks (Table 3), the four arithmetic tasks
//! (Table 4), instruction following (Table 5) and the multimodal suite
//! (Table 6).
//!
//! The paper's tables compare PEFT methods *against each other on shared
//! tasks*; these suites preserve that comparison structure with learnable-
//! but-nontrivial mappings over the byte vocabulary (DESIGN.md §4).  Every
//! task emits [`Example`]s; shared builders turn them into LM training
//! batches and the evaluation protocols used by the tables:
//!
//! * classification via `last_logits` argmax over label tokens (Table 2/6),
//! * multiple-choice via per-candidate NLL scoring (Table 3, the standard
//!   LM-harness protocol),
//! * generative exact match through the serving engine (Table 4),
//! * LL-judge win-rate: trained vs identity model NLL (Table 5).

pub mod arithmetic;
pub mod commonsense;
pub mod eval;
pub mod instruct;
pub mod multimodal;
pub mod nlu;
pub mod pretrain;

pub use eval::{
    eval_choice_accuracy, eval_classification, eval_exact_match, eval_win_rate, ClassEval,
};

use crate::trainer::TrainBatch;
use crate::util::rng::Rng;

/// The metric a task reports (mirroring the paper's per-task metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    /// Matthew's correlation (CoLA analogue).
    Matthews,
    /// Pearson correlation over graded labels (STS-B analogue).
    Pearson,
    /// Generative exact match (arithmetic suite).
    ExactMatch,
    /// LL-judge win rate vs the base model (AlpacaEval analogue).
    WinRate,
}

/// One synthetic example.
#[derive(Clone, Debug)]
pub struct Example {
    /// Prompt tokens (byte-level, never 0).
    pub prompt: Vec<i32>,
    /// Gold completion tokens.
    pub completion: Vec<i32>,
    /// Candidate completions for multiple-choice tasks (empty otherwise).
    pub choices: Vec<Vec<i32>>,
    /// Gold class index (classification / choice tasks) or graded label.
    pub answer: usize,
}

impl Example {
    pub fn gen(prompt: &str, completion: &str) -> Example {
        Example {
            prompt: crate::tokenizer::encode(prompt),
            completion: crate::tokenizer::encode(completion),
            choices: Vec::new(),
            answer: 0,
        }
    }

    /// Multiple-choice example; `answer` indexes `choices`, and the gold
    /// completion is set to the correct choice.
    pub fn choice(prompt: &str, choices: &[&str], answer: usize) -> Example {
        Example {
            prompt: crate::tokenizer::encode(prompt),
            completion: crate::tokenizer::encode(choices[answer]),
            choices: choices.iter().map(|c| crate::tokenizer::encode(c)).collect(),
            answer,
        }
    }
}

/// A synthetic task: a deterministic-under-seed generator of examples.
pub trait Task {
    fn name(&self) -> &'static str;
    fn metric(&self) -> Metric;
    /// Label tokens for classification tasks (argmax restricted to these);
    /// empty for generative/choice tasks.
    fn label_tokens(&self) -> Vec<i32> {
        Vec::new()
    }
    fn sample(&self, rng: &mut Rng) -> Example;
}

/// Build an LM training batch from `b` examples: tokens = prompt ++
/// completion (padded to `l`), next-token targets, mask = 1 only where the
/// position predicts a completion token (prompt tokens are context).
pub fn lm_batch(examples: &[Example], b: usize, l: usize) -> TrainBatch {
    assert!(examples.len() <= b, "{} examples > batch {b}", examples.len());
    let mut batch = TrainBatch::zeros(b, l);
    for (row, ex) in examples.iter().enumerate() {
        let seq: Vec<i32> =
            ex.prompt.iter().chain(ex.completion.iter()).copied().take(l).collect();
        let plen = ex.prompt.len().min(seq.len());
        let base = row * l;
        for (t, &tok) in seq.iter().enumerate() {
            batch.tokens[base + t] = tok;
        }
        // Position p predicts seq[p + 1]; completion tokens sit at indices
        // [plen, seq.len()).
        for p in 0..seq.len().saturating_sub(1) {
            batch.targets[base + p] = seq[p + 1];
            if p + 1 >= plen {
                batch.mask[base + p] = 1.0;
            }
        }
    }
    batch
}

/// A batch source drawing uniformly from a set of tasks (the paper's
/// unified multi-task finetuning protocol for Tables 3/4).
pub struct SuiteSampler<'a> {
    pub tasks: &'a [Box<dyn Task>],
    pub batch: usize,
    pub seq_len: usize,
}

impl<'a> SuiteSampler<'a> {
    pub fn new(tasks: &'a [Box<dyn Task>], batch: usize, seq_len: usize) -> SuiteSampler<'a> {
        SuiteSampler { tasks, batch, seq_len }
    }

    pub fn next_batch(&self, rng: &mut Rng) -> TrainBatch {
        let exs: Vec<Example> = (0..self.batch)
            .map(|_| self.tasks[rng.below(self.tasks.len())].sample(rng))
            .collect();
        lm_batch(&exs, self.batch, self.seq_len)
    }
}

impl crate::trainer::loop_::BatchSource for SuiteSampler<'_> {
    fn next_batch(&mut self, rng: &mut Rng) -> TrainBatch {
        SuiteSampler::next_batch(self, rng)
    }
}

/// Single-task batch source (Table 2: one model per GLUE task).
pub struct TaskSampler<'a> {
    pub task: &'a dyn Task,
    pub batch: usize,
    pub seq_len: usize,
}

impl crate::trainer::loop_::BatchSource for TaskSampler<'_> {
    fn next_batch(&mut self, rng: &mut Rng) -> TrainBatch {
        let exs: Vec<Example> = (0..self.batch).map(|_| self.task.sample(rng)).collect();
        lm_batch(&exs, self.batch, self.seq_len)
    }
}

/// Suite registries.
pub fn nlu_suite() -> Vec<Box<dyn Task>> {
    nlu::all()
}

pub fn commonsense_suite() -> Vec<Box<dyn Task>> {
    commonsense::all()
}

pub fn arithmetic_train_suite() -> Vec<Box<dyn Task>> {
    arithmetic::train_mix()
}

pub fn arithmetic_eval_suite() -> Vec<Box<dyn Task>> {
    arithmetic::eval_tasks()
}

pub fn instruct_suite() -> Vec<Box<dyn Task>> {
    instruct::all()
}

pub fn multimodal_suite() -> Vec<Box<dyn Task>> {
    multimodal::all()
}

pub fn pretrain_corpus() -> Vec<Box<dyn Task>> {
    pretrain::corpus()
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed;
    impl Task for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn metric(&self) -> Metric {
            Metric::Accuracy
        }
        fn sample(&self, _rng: &mut Rng) -> Example {
            Example::gen("ab", "cd")
        }
    }

    #[test]
    fn lm_batch_masks_completion_only() {
        let ex = Fixed.sample(&mut Rng::seed_from(0));
        let b = lm_batch(&[ex], 1, 8);
        // seq = [a, b, c, d]; targets at p: seq[p+1]; mask at p>=1 (plen-1)
        assert_eq!(&b.tokens[..4], &[97, 98, 99, 100]);
        assert_eq!(b.targets[0], 98);
        assert_eq!(b.mask[0], 0.0); // predicts prompt token
        assert_eq!(b.targets[1], 99);
        assert_eq!(b.mask[1], 1.0); // predicts first completion token
        assert_eq!(b.targets[2], 100);
        assert_eq!(b.mask[2], 1.0);
        assert_eq!(b.mask[3], 0.0); // past end
    }

    #[test]
    fn lm_batch_truncates_to_seq_len() {
        let ex = Example::gen("aaaaaaaaaa", "bbbbbbbbbb");
        let b = lm_batch(&[ex], 1, 12);
        assert_eq!(b.tokens.len(), 12);
        assert_eq!(b.tokens[11], 98);
    }

    #[test]
    fn choice_example_sets_gold_completion() {
        let ex = Example::choice("q", &["yes", "no"], 1);
        assert_eq!(ex.completion, crate::tokenizer::encode("no"));
        assert_eq!(ex.choices.len(), 2);
    }

    #[test]
    fn all_suites_nonempty_and_sampleable() {
        let mut rng = Rng::seed_from(7);
        for suite in [
            nlu_suite(),
            commonsense_suite(),
            arithmetic_train_suite(),
            arithmetic_eval_suite(),
            instruct_suite(),
            multimodal_suite(),
        ] {
            assert!(!suite.is_empty());
            for t in &suite {
                for _ in 0..20 {
                    let ex = t.sample(&mut rng);
                    assert!(!ex.prompt.is_empty(), "{} empty prompt", t.name());
                    assert!(!ex.completion.is_empty(), "{} empty completion", t.name());
                    // Tokens must avoid PAD/EOS = 0.
                    assert!(ex.prompt.iter().all(|&t| t > 0));
                    assert!(ex.completion.iter().all(|&t| t > 0));
                    if !ex.choices.is_empty() {
                        assert!(ex.answer < ex.choices.len());
                        assert_eq!(ex.choices[ex.answer], ex.completion);
                    }
                }
            }
        }
    }

    #[test]
    fn train_window_fits_suites() {
        // Train bucket is [16, 32]: prompt+completion must fit 32 tokens.
        let mut rng = Rng::seed_from(11);
        for suite in [
            nlu_suite(),
            commonsense_suite(),
            arithmetic_train_suite(),
            instruct_suite(),
            multimodal_suite(),
        ] {
            for t in &suite {
                for _ in 0..50 {
                    let ex = t.sample(&mut rng);
                    let n = ex.prompt.len() + ex.completion.len();
                    assert!(n <= 32, "{}: {} tokens > 32", t.name(), n);
                }
            }
        }
    }

    #[test]
    fn arithmetic_eval_prompts_fit_gen_bucket() {
        // Generative eval goes through prefill_<mode>_train_b8_l16.
        let mut rng = Rng::seed_from(13);
        for t in &arithmetic_eval_suite() {
            if t.metric() != Metric::ExactMatch {
                continue;
            }
            for _ in 0..100 {
                let ex = t.sample(&mut rng);
                assert!(ex.prompt.len() <= 16, "{}: prompt {} > 16", t.name(), ex.prompt.len());
            }
        }
    }
}
