"""Layer-1 Pallas kernel for the batched-LoRA baseline (paper §2.2).

This is the comparison path of Figure 4: serving heterogeneous requests with
per-request LoRA modules requires a batched matmul (bmm) chain

    delta_i = (h_i @ B_i) @ A_i            per request i in the batch,

which on a TPU forces [B] *separate* small MXU passes (the adapters differ,
so the batch cannot be collapsed into one systolic matmul), and on GPUs is
torch.bmm with its well-documented overhead [Abdelfattah et al.].  The
kernel grids over the batch; each program owns one request's [L, d1] tile
and its gathered [d1, r] / [r, d2] adapter matrices.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_bmm_kernel(h_ref, lb_ref, la_ref, o_ref):
    """One request: delta = (h @ lb) @ la."""
    h = h_ref[...][0]      # [L, d1]
    lb = lb_ref[...][0]    # [d1, r]
    la = la_ref[...][0]    # [r, d2]
    mid = jnp.dot(h, lb, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(mid, la,
                         preferred_element_type=jnp.float32)[None].astype(
                             o_ref.dtype)


def lora_batched_apply(h, lb_bank, la_bank, ids):
    """Heterogeneous-batch LoRA delta via per-request bmm.

    h [B, L, d1]; lb_bank [n, d1, r]; la_bank [n, r, d2]; ids [B].
    Returns the delta to be added to the frozen layer's output.
    """
    b, l, d1 = h.shape
    r = lb_bank.shape[-1]
    d2 = la_bank.shape[-1]
    lb = lb_bank[ids]  # [B, d1, r]
    la = la_bank[ids]  # [B, r, d2]
    return pl.pallas_call(
        _lora_bmm_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l, d1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d1, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, r, d2), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, d2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, d2), h.dtype),
        interpret=True,
    )(h, lb, la)
