#!/usr/bin/env python3
"""Smoke test for the NDJSON serving front door.

Runs up to three scenarios:

  * reference backend (`--backend ref`) — always: the pure-Rust reference
    model needs no artifacts, so the loopback round-trip runs
    unconditionally in CI.
  * reference backend, two replicas (`--replicas 2 --place affinity`) —
    always: the same round-trip through the fleet router, asserting the
    `replica` label on the admitted event stays in range.
  * pjrt backend (the default) — only when the AOT artifacts are present
    (`make artifacts`); otherwise that variant is skipped, mirroring the
    artifact-gated integration tests.

Each scenario starts `road serve --listen 127.0.0.1:0` (the engine picks a
free port and prints it), round-trips one NDJSON generate request over
loopback, and asserts the streamed event grammar ends in a `finished`
event.

Environment:
  ROAD_BIN          path to the road binary (default target/release/road)
  ROAD_ARTIFACTS    artifacts dir (default: walk up from cwd, like the
                    rust runtime)
  ROAD_SMOKE_MODEL  model config to serve (default "tiny", the test config)
"""

import json
import os
import pathlib
import socket
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent


def artifacts_dir():
    env = os.environ.get("ROAD_ARTIFACTS")
    if env:
        p = pathlib.Path(env)
        return p if (p / "manifest.json").exists() else None
    # Walk up from cwd, mirroring Manifest::default_dir.
    d = pathlib.Path.cwd()
    while True:
        cand = d / "artifacts"
        if (cand / "manifest.json").exists():
            return cand
        if d.parent == d:
            return None
        d = d.parent


def run_scenario(backend, replicas=1):
    binary = os.environ.get("ROAD_BIN", str(ROOT / "target" / "release" / "road"))
    model = os.environ.get("ROAD_SMOKE_MODEL", "tiny")
    cmd = [
        binary, "serve", "--listen", "127.0.0.1:0", "--backend", backend,
        "--model", model, "--mode", "base", "--slots", "2", "--distinct", "0",
    ]
    if replicas > 1:
        cmd += ["--replicas", str(replicas), "--place", "affinity"]
    label = backend if replicas == 1 else f"{backend} x{replicas}"
    print(f"serve smoke [{label}]:", " ".join(cmd))
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    try:
        # The server prints `listening on <addr>` once the engine thread is
        # up and the listener is bound.
        addr = None
        for line in proc.stdout:
            print("[road]", line.rstrip())
            if line.startswith("listening on "):
                addr = line.split()[-1]
                break
        if addr is None:
            print(f"serve smoke [{label}]: FAIL — server exited before listening")
            return 1

        host, port = addr.rsplit(":", 1)
        events = []
        with socket.create_connection((host, int(port)), timeout=60) as s:
            req = {"op": "generate", "prompt": [11, 12, 13],
                   "max_new_tokens": 4, "tag": "smoke"}
            s.sendall((json.dumps(req) + "\n").encode())
            reader = s.makefile("r")
            deadline = time.time() + 120
            while True:
                if time.time() > deadline:
                    print(f"serve smoke [{label}]: FAIL — timed out waiting for finished")
                    return 1
                line = reader.readline()
                if not line:
                    print(f"serve smoke [{label}]: FAIL — connection closed early")
                    return 1
                ev = json.loads(line)
                print("[event]", json.dumps(ev))
                events.append(ev["event"])
                if ev["event"] == "admitted":
                    assert 0 <= ev.get("replica", 0) < replicas, ev
                if ev["event"] == "error":
                    print(f"serve smoke [{label}]: FAIL — error event:", ev)
                    return 1
                if ev["event"] == "finished":
                    assert ev["finish"] == "max_tokens", ev
                    assert len(ev["tokens"]) == 4, ev
                    assert ev.get("tag") == "smoke", ev
                    break

        assert events[0] == "admitted", events
        assert events.count("token") == 4, events
        print(f"serve smoke [{label}]: OK —", " → ".join(events))
        return 0
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


def main():
    # The reference backend is artifact-free: these legs always run.
    rc = run_scenario("ref")
    if rc != 0:
        return rc
    rc = run_scenario("ref", replicas=2)
    if rc != 0:
        return rc

    if artifacts_dir() is None:
        print("serve smoke [pjrt]: AOT artifacts not found (run `make artifacts` first); skipping")
        return 0
    return run_scenario("pjrt")


if __name__ == "__main__":
    sys.exit(main())
