pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_clock_is_fine() {
        let _ = std::time::Instant::now();
    }
}
