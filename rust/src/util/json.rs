//! Minimal JSON parser/serializer (no serde in the offline image).
//!
//! Supports the full JSON grammar needed by artifacts/manifest.json and the
//! experiment result files: objects, arrays, strings (with escapes),
//! numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Single-line rendering — the NDJSON wire format requires exactly one
    /// `\n`-free line per value (string escapes keep embedded newlines out).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser will follow.  Parsing recurses
/// once per `[`/`{` level, and this parser also reads network input (the
/// NDJSON front door), so a hostile `[[[[…` line must come back as `Err`
/// instead of overflowing the stack and killing the process.
const MAX_DEPTH: u32 = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: u32,
}

impl<'a> Parser<'a> {
    /// Track entry into a nested container; errors past [`MAX_DEPTH`].
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} levels");
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        self.enter()?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                        let sl = &self.b[start..(start + len).min(self.b.len())];
                        out.push_str(std::str::from_utf8(sl)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"shape": [2, 3], "dtype": "f32"}"#).unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str().unwrap(), "f32");
        let shape: Vec<usize> =
            v.get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 3]);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \"quoted\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \"quoted\"");
    }

    #[test]
    fn compact_is_one_line_and_roundtrips() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert!(!compact.contains('\n'), "{compact}");
        assert!(!compact.contains("  "), "{compact}");
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn depth_limit_rejects_hostile_nesting() {
        // Deep enough to overflow the stack if recursion were unbounded.
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(Json::parse(&deep).is_err(), "hostile nesting must be a typed error");
        let mixed = "[{\"k\":".repeat(50_000) + "0" + &"}]".repeat(50_000);
        assert!(Json::parse(&mixed).is_err());
        // Deep-but-legal documents still parse.
        let ok = "[".repeat(64) + "1" + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
