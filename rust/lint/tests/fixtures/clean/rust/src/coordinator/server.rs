use std::sync::{mpsc, Mutex};
use std::time::Instant;

pub fn rendezvous() -> (mpsc::SyncSender<u32>, mpsc::Receiver<u32>) {
    mpsc::sync_channel(1)
}

pub fn guarded(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

pub fn profiled() -> Instant {
    Instant::now() // roadlint: allow(clock-discipline) -- fixture: profiling real hardware wall time
}

pub fn documented() -> Instant {
    // roadlint: allow(clock-discipline) -- fixture: the directive plus its
    // justification may sit on comment lines directly above the site.
    Instant::now()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let t = std::time::Instant::now();
        assert!(t.elapsed().as_secs() < 1, "unwrap() in a string is not a panic site");
        Some(1u32).unwrap();
    }
}
