//! Token-level Rust scanner: the shared substrate every rule matches
//! against.
//!
//! This is deliberately *not* a Rust parser.  Each source file is lexed
//! once into per-line views where rules can match patterns without being
//! fooled by the three classic grep failure modes:
//!
//! - **comments** — `// calls Instant::now()` in a doc comment is not a
//!   violation; comment text is split out of the code view (and kept,
//!   because the `// roadlint: allow(...)` escape hatch lives there),
//! - **string literals** — `"unwrap()"` inside a test-assertion message
//!   is not a panic site; literal *contents* are blanked from the code
//!   view but collected per line (the typed-error rule reads the
//!   `EngineError::kind()` wire strings out of them),
//! - **test code** — `#[cfg(test)]` items get their line spans marked so
//!   rules that only govern production paths can skip them.
//!
//! The lexer understands line/nested-block comments, plain and raw
//! string literals (`r"…"`, `r#"…"#`), byte strings, char literals vs
//! lifetimes, and escapes.  That is enough to make the rules exact on
//! this codebase while keeping the scanner a few hundred lines of std.

/// One source line, split into the views rules match against.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code text: comments removed, string/char literal contents blanked
    /// (delimiters kept, so `"x"` scans as `""`).
    pub code: String,
    /// Comment text on this line (line + block comments, concatenated).
    pub comment: String,
    /// String-literal contents that appear on this line, in order.
    pub strings: Vec<String>,
    /// True when the line sits inside a `#[cfg(test)]` item's braces
    /// (or is the attribute itself).
    pub in_test: bool,
}

/// A scanned source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the checked root, `/`-separated.
    pub rel: String,
    /// 0-indexed lines; rules report `index + 1`.
    pub lines: Vec<Line>,
}

impl SourceFile {
    pub fn scan(rel: &str, src: &str) -> SourceFile {
        let mut lines = lex(src);
        mark_test_spans(&mut lines);
        SourceFile { rel: rel.to_string(), lines }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    /// Nested depth.
    BlockComment(u32),
    Str,
    /// Number of `#` delimiters.
    RawStr(u32),
    Char,
}

fn lex(src: &str) -> Vec<Line> {
    let mut out: Vec<Line> = vec![Line::default()];
    let mut mode = Mode::Code;
    let mut cur_str = String::new();
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            match mode {
                Mode::LineComment => mode = Mode::Code,
                Mode::Str | Mode::RawStr(_) => cur_str.push('\n'),
                _ => {}
            }
            out.push(Line::default());
            i += 1;
            continue;
        }
        let line = out.last_mut().expect("lex starts with one line");
        match mode {
            Mode::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&line.code) {
                    // Raw/byte string starts: r", r#", br", b".
                    let (skip, hashes) = raw_string_start(&b[i..]);
                    if skip > 0 {
                        line.code.push('"');
                        mode = if hashes == u32::MAX { Mode::Str } else { Mode::RawStr(hashes) };
                        i += skip;
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: '\x' / 'x' followed by a
                    // closing quote is a literal; anything else ('a in
                    // generics, 'static) stays in the code view.
                    if next == Some('\\') {
                        line.code.push_str("''");
                        mode = Mode::Char;
                        i += 2; // consume the backslash with the quote
                        if i < b.len() {
                            i += 1; // and the escaped char
                        }
                    } else if b.get(i + 2).copied() == Some('\'') && next != Some('\'') {
                        line.code.push_str("''");
                        i += 3;
                    } else {
                        line.code.push('\'');
                        i += 1;
                    }
                } else {
                    line.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                line.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(d) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if d == 1 { Mode::Code } else { Mode::BlockComment(d - 1) };
                    i += 2;
                } else {
                    line.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    cur_str.push(c);
                    if let Some(&n) = b.get(i + 1) {
                        cur_str.push(n);
                        // A line-continuation escape (`\` at end of line)
                        // still ends a physical line — line numbers must
                        // track the file, not the string's logical value.
                        if n == '\n' {
                            out.push(Line::default());
                        }
                    }
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    line.strings.push(std::mem::take(&mut cur_str));
                    mode = Mode::Code;
                    i += 1;
                } else {
                    cur_str.push(c);
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && closes_raw(&b[i + 1..], hashes) {
                    line.code.push('"');
                    line.strings.push(std::mem::take(&mut cur_str));
                    mode = Mode::Code;
                    i += 1 + hashes as usize;
                } else {
                    cur_str.push(c);
                    i += 1;
                }
            }
            Mode::Char => {
                // Inside an escaped char literal, looking for the close.
                if c == '\'' {
                    mode = Mode::Code;
                }
                i += 1;
            }
        }
    }
    out
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().last().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Does `&chars[..]` start a raw/byte string (`r"`, `r#"`, `br"`, `b"`)?
/// Returns (chars consumed through the opening quote, hash count) — hash
/// count `u32::MAX` means "plain (escaped) string body", 0 means `r"`.
fn raw_string_start(chars: &[char]) -> (usize, u32) {
    let mut j = 0;
    if chars.first() == Some(&'b') {
        j += 1;
    }
    let raw = chars.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return (0, 0);
    }
    if !raw {
        if hashes > 0 {
            return (0, 0); // b#" is not a thing
        }
        return (j + 1, u32::MAX); // b"…": escaped body
    }
    (j + 1, hashes)
}

fn closes_raw(rest: &[char], hashes: u32) -> bool {
    (0..hashes as usize).all(|k| rest.get(k) == Some(&'#'))
}

/// Mark the line span of every `#[cfg(test)]` item (in this codebase,
/// `#[cfg(test)] mod tests { … }`): from the attribute through the
/// matching close brace of the next block.
fn mark_test_spans(lines: &mut [Line]) {
    let n = lines.len();
    let mut i = 0;
    while i < n {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Walk forward to the item's opening brace, then brace-match.
        let mut depth: i32 = 0;
        let mut opened = false;
        let mut j = i;
        'span: while j < n {
            lines[j].in_test = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'span;
                        }
                    }
                    // An un-braced item terminator before any brace
                    // (e.g. `#[cfg(test)] use foo;`) ends the span.
                    ';' if !opened && depth == 0 => break 'span,
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> SourceFile {
        SourceFile::scan("x.rs", src)
    }

    #[test]
    fn comments_leave_the_code_view() {
        let f = scan("let x = 1; // Instant::now() here is prose\n/* unwrap() */ let y = 2;\n");
        assert!(!f.lines[0].code.contains("Instant::now"));
        assert!(f.lines[0].comment.contains("Instant::now"));
        assert!(!f.lines[1].code.contains("unwrap"));
        assert!(f.lines[1].code.contains("let y"));
    }

    #[test]
    fn nested_block_comments() {
        let f = scan("/* a /* b */ still comment */ code();\n");
        assert_eq!(f.lines[0].code.trim(), "code();");
    }

    #[test]
    fn string_contents_are_blanked_but_collected() {
        let f = scan(r#"let s = "call unwrap() now"; f(s);"#);
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].code.contains(r#""""#));
        assert_eq!(f.lines[0].strings, vec!["call unwrap() now"]);
    }

    #[test]
    fn raw_strings_and_escapes() {
        let f = scan("let a = r#\"has \"quotes\" and unwrap()\"#; let b = \"esc\\\"aped\";\n");
        assert!(!f.lines[0].code.contains("unwrap"));
        assert_eq!(f.lines[0].strings.len(), 2);
        assert!(f.lines[0].strings[0].contains("unwrap()"));
        assert!(f.lines[0].strings[1].contains("esc"));
    }

    #[test]
    fn multiline_strings_span_lines() {
        let f = scan("let a = \"line one\nthread::sleep inside\"; done();\n");
        assert!(!f.lines[1].code.contains("thread::sleep"));
        assert!(f.lines[1].code.contains("done()"));
        assert_eq!(f.lines[1].strings[0], "line one\nthread::sleep inside");
    }

    #[test]
    fn lifetimes_stay_char_literals_go() {
        let f = scan("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }\n");
        assert!(f.lines[0].code.contains("<'a>"));
        assert!(f.lines[0].code.contains("&'a str"));
        assert!(!f.lines[0].code.contains("'x'"));
    }

    #[test]
    fn line_continuation_strings_keep_physical_line_numbers() {
        // `\` at end of line inside a string continues the literal but
        // still ends a physical line; losing it would shift every line
        // number (and allow-directive lookup) after it.
        let src = "let a = \"one \\\n    two\";\nlet b = 1;\n";
        let f = scan(src);
        assert_eq!(f.lines.len(), src.lines().count() + 1, "trailing newline adds a line");
        assert!(f.lines[2].code.contains("let b"));
    }

    #[test]
    fn cfg_test_span_is_marked() {
        let src = "fn prod() { now(); }\n#[cfg(test)]\nmod tests {\n    fn t() { now(); }\n}\nfn after() {}\n";
        let f = scan(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[3].in_test);
        assert!(f.lines[4].in_test, "closing brace line");
        assert!(!f.lines[5].in_test, "code after the test mod");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_span() {
        let f = scan("#[cfg(not(test))]\nfn prod() { x(); }\n");
        // The attribute line itself contains `#[cfg(not(test))]`, not
        // `#[cfg(test)]` — no span starts.
        assert!(!f.lines[1].in_test);
    }
}
