//! Engine-level integration tests on the tiny config: continuous batching,
//! adapter isolation, merged-vs-unmerged equivalence, and backpressure.
//!
//! All tests share one PJRT process; the tiny artifacts keep compiles fast.

use std::rc::Rc;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::{Engine, EngineConfig};
use road::coordinator::request::{FinishReason, Request, SamplingParams};
use road::model::ParamStore;
use road::runtime::Runtime;
use road::util::rng::Rng;

fn rt() -> Rc<Runtime> {
    Rc::new(Runtime::from_default_artifacts().expect("run `make artifacts` first"))
}

fn tiny_engine(rt: &Rc<Runtime>, mode: &str) -> Engine {
    Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: mode.into(),
            decode_slots: 2,
            queue_capacity: 64,
        },
    )
    .unwrap()
}

fn greedy(prompt: &[i32], max_new: usize) -> Request {
    Request::new(0, prompt.to_vec(), max_new).with_sampling(SamplingParams {
        temperature: 0.0,
        top_k: 0,
        seed: 0,
        stop_token: None,
    })
}

#[test]
fn greedy_serving_is_deterministic() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(3);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("a", &a).unwrap();

    let mk = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("a"),
            greedy(&[10, 20, 30], 8),
        ]
    };
    let mut out1 = eng.run_all(mk()).unwrap();
    let mut out2 = eng.run_all(mk()).unwrap();
    out1.sort_by_key(|o| o.adapter.clone());
    out2.sort_by_key(|o| o.adapter.clone());
    for (x, y) in out1.iter().zip(&out2) {
        assert_eq!(x.tokens, y.tokens);
    }
    // The adapter actually changes the output distribution.
    assert_ne!(out1[0].tokens, out1[1].tokens, "adapter had no effect");
}

#[test]
fn adapter_state_does_not_leak_across_lanes() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(4);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    let b = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("a", &a).unwrap();
    eng.register_adapter("b", &b).unwrap();

    // Solo run with adapter a.
    let solo = eng.run_all(vec![greedy(&[5, 6, 7], 6).with_adapter("a")]).unwrap();
    // Mixed batch: a alongside b.
    let mixed = eng
        .run_all(vec![
            greedy(&[5, 6, 7], 6).with_adapter("a"),
            greedy(&[5, 6, 7], 6).with_adapter("b"),
        ])
        .unwrap();
    let mixed_a = mixed.iter().find(|o| o.adapter.as_deref() == Some("a")).unwrap();
    assert_eq!(solo[0].tokens, mixed_a.tokens, "lane isolation violated");
}

#[test]
fn merged_road_equals_unmerged_road() {
    let rt = rt();
    // Unmerged: adapter in the bank, road decode path (Eq. 4).
    let mut unmerged = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(5);
    let adapter = RoadAdapter::random(&unmerged.cfg, &mut rng, 0.2);
    unmerged.register_adapter("x", &Adapter::Road(adapter.clone())).unwrap();
    let out_u = unmerged.run_all(vec![greedy(&[9, 8, 7, 6], 8).with_adapter("x")]).unwrap();

    // Merged: W <- W R^T folded host-side, base decode path (paper §3.2).
    let mut params = ParamStore::load_pretrained(&rt.manifest, "tiny").unwrap();
    params.merge_road(&adapter).unwrap();
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "base".into(),
        decode_slots: 2,
        queue_capacity: 64,
    };
    let mut merged = Engine::with_params(rt.clone(), econf, params).unwrap();
    let out_m = merged.run_all(vec![greedy(&[9, 8, 7, 6], 8)]).unwrap();

    assert_eq!(out_u[0].tokens, out_m[0].tokens, "merge changed the model");
}

#[test]
fn more_requests_than_slots_all_complete() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    let reqs: Vec<Request> =
        (0..7).map(|i| greedy(&[1 + i as i32, 2, 3], 4)).collect();
    let outs = eng.run_all(reqs).unwrap();
    assert_eq!(outs.len(), 7);
    assert!(outs.iter().all(|o| o.tokens.len() == 4));
    assert!(outs.iter().all(|o| o.finish == FinishReason::MaxTokens));
}

#[test]
fn stop_token_finishes_early_and_is_stripped() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    // Find what the model greedily emits, then use it as the stop token.
    let probe = eng.run_all(vec![greedy(&[42, 43], 3)]).unwrap();
    let first = probe[0].tokens[0];
    let mut req = greedy(&[42, 43], 8);
    req.sampling.stop_token = Some(first);
    let outs = eng.run_all(vec![req]).unwrap();
    assert_eq!(outs[0].finish, FinishReason::StopToken);
    assert!(!outs[0].tokens.contains(&first));
}

#[test]
fn submit_validates_prompts_and_adapters() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    // Empty prompt.
    assert!(eng.submit(greedy(&[], 4)).is_err());
    // Prompt longer than the largest prefill bucket.
    let long = vec![1i32; eng.max_prompt_len() + 1];
    assert!(eng.submit(greedy(&long, 4)).is_err());
    // Unknown adapter.
    assert!(eng.submit(greedy(&[1, 2], 4).with_adapter("nope")).is_err());
    // prompt + max_new beyond max_seq.
    assert!(eng.submit(greedy(&[1, 2], eng.cfg.max_seq)).is_err());
}

#[test]
fn queue_backpressure_rejects_when_full() {
    let rt = rt();
    let mut eng = Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: "base".into(),
            decode_slots: 2,
            queue_capacity: 2,
        },
    )
    .unwrap();
    eng.submit(greedy(&[1, 2], 2)).unwrap();
    eng.submit(greedy(&[1, 2], 2)).unwrap();
    let err = eng.submit(greedy(&[1, 2], 2)).unwrap_err();
    assert!(err.to_string().contains("backpressure"), "{err}");
}

#[test]
fn metrics_account_for_all_tokens() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    let outs = eng.run_all(vec![greedy(&[3, 4, 5], 6), greedy(&[6, 7], 6)]).unwrap();
    let gen: usize = outs.iter().map(|o| o.tokens.len()).sum();
    assert_eq!(eng.metrics.tokens_generated, gen);
    assert_eq!(eng.metrics.requests_completed, 2);
    assert_eq!(eng.metrics.prompt_tokens, 5);
    assert!(eng.metrics.decode_steps > 0);
}

#[test]
fn engine_server_thread_roundtrip() {
    use road::coordinator::server::EngineServer;
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "road".into(),
        decode_slots: 2,
        queue_capacity: 64,
    };
    let dir = road::Manifest::default_dir();
    let (server, client) = EngineServer::start(econf, dir, |eng| {
        let mut rng = Rng::seed_from(6);
        let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.2));
        eng.register_adapter("srv", &a)?;
        Ok(())
    })
    .unwrap();
    let out = client.generate(greedy(&[11, 12, 13], 5).with_adapter("srv")).unwrap();
    assert_eq!(out.tokens.len(), 5);
    let stats = client.stats().unwrap();
    assert!(stats.contains("requests=1"), "{stats}");
    server.shutdown().unwrap();
}
