//! Threaded front-end for the engine: clients talk to a dedicated engine
//! thread over mpsc channels (the PJRT client is not Send; and the image
//! carries no tokio — std::thread + channels is the documented
//! substitution, docs/DESIGN.md §Substitutions).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::adapters::Adapter;

use super::engine::{Engine, EngineConfig};
use super::request::{Request, RequestOutput};

enum Cmd {
    Submit(Request, Sender<Result<RequestOutput, String>>),
    Register(String, Box<Adapter>, Sender<Result<(), String>>),
    Unregister(String, Sender<Result<(), String>>),
    Stats(Sender<String>),
    Shutdown,
}

/// Handle for submitting work to a running engine thread.
#[derive(Clone)]
pub struct EngineClient {
    tx: Sender<Cmd>,
}

impl EngineClient {
    /// Submit and wait for the full response.
    pub fn generate(&self, req: Request) -> Result<RequestOutput> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Submit(req, tx)).map_err(|_| anyhow!("engine stopped"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?.map_err(|e| anyhow!(e))
    }

    /// Submit without waiting; the receiver yields the output when done.
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<RequestOutput, String>>> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Submit(req, tx)).map_err(|_| anyhow!("engine stopped"))?;
        Ok(rx)
    }

    /// Register a named adapter into the engine's host store (device
    /// residency is paged in on demand at admission).
    pub fn register_adapter(&self, name: &str, adapter: Adapter) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Register(name.to_string(), Box::new(adapter), tx))
            .map_err(|_| anyhow!("engine stopped"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?.map_err(|e| anyhow!(e))
    }

    /// Remove a named adapter (rejected while it has queued or in-flight
    /// requests).
    pub fn unregister_adapter(&self, name: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Unregister(name.to_string(), tx))
            .map_err(|_| anyhow!("engine stopped"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?.map_err(|e| anyhow!(e))
    }

    pub fn stats(&self) -> Result<String> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Stats(tx)).map_err(|_| anyhow!("engine stopped"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }
}

pub struct EngineServer {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl EngineServer {
    /// Start an engine on its own thread.  `setup` runs on the engine
    /// thread after construction (e.g. to register adapters that are not
    /// Send-friendly to build elsewhere).
    pub fn start(
        econf: EngineConfig,
        artifacts_dir: std::path::PathBuf,
        setup: impl FnOnce(&mut Engine) -> Result<()> + Send + 'static,
    ) -> Result<(EngineServer, EngineClient)> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("road-engine".into())
            .spawn(move || engine_thread(econf, artifacts_dir, rx, ready_tx, setup))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow!("engine init failed: {e}")),
            Err(_) => return Err(anyhow!("engine thread died during init")),
        }
        Ok((EngineServer { tx: tx.clone(), handle: Some(handle) }, EngineClient { tx }))
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_thread(
    econf: EngineConfig,
    artifacts_dir: std::path::PathBuf,
    rx: Receiver<Cmd>,
    ready: Sender<Result<(), String>>,
    setup: impl FnOnce(&mut Engine) -> Result<()>,
) -> Result<()> {
    let init = (|| -> Result<Engine> {
        let manifest = crate::manifest::Manifest::load(&artifacts_dir)?;
        let rt = std::rc::Rc::new(crate::runtime::Runtime::new(manifest)?);
        let mut engine = Engine::new(rt, econf)?;
        setup(&mut engine)?;
        Ok(engine)
    })();
    let mut engine = match init {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };

    // id -> response channel
    let mut waiters: std::collections::HashMap<u64, Sender<Result<RequestOutput, String>>> =
        Default::default();
    let mut shutting_down = false;

    loop {
        // Drain commands: block when idle, poll when there is work.
        loop {
            let cmd = if engine.has_work() || shutting_down {
                match rx.try_recv() {
                    Ok(c) => Some(c),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        None
                    }
                }
            } else {
                match rx.recv() {
                    Ok(c) => Some(c),
                    Err(_) => return Ok(()), // all clients gone, idle
                }
            };
            let Some(cmd) = cmd else { break };
            match cmd {
                Cmd::Submit(req, resp) => match engine.submit(req) {
                    Ok(id) => {
                        waiters.insert(id, resp);
                    }
                    Err(e) => {
                        let _ = resp.send(Err(format!("{e:#}")));
                    }
                },
                Cmd::Register(name, adapter, resp) => {
                    let _ = resp.send(
                        engine.register_adapter(&name, &adapter).map_err(|e| format!("{e:#}")),
                    );
                }
                Cmd::Unregister(name, resp) => {
                    let _ = resp
                        .send(engine.unregister_adapter(&name).map_err(|e| format!("{e:#}")));
                }
                Cmd::Stats(resp) => {
                    let _ = resp.send(engine.metrics.report());
                }
                Cmd::Shutdown => shutting_down = true,
            }
        }

        if engine.has_work() {
            for out in engine.step()? {
                if let Some(w) = waiters.remove(&out.id) {
                    let _ = w.send(Ok(out));
                }
            }
        } else if shutting_down {
            return Ok(());
        }
    }
}
