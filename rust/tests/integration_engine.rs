//! Engine-level integration tests on the tiny config: continuous batching,
//! adapter isolation, merged-vs-unmerged equivalence, backpressure, and
//! device-resident vs host-round-trip KV parity.
//!
//! Every test here runs unconditionally: on the pure-Rust **reference
//! backend** when no artifacts are built (no native XLA needed — the full
//! engine path executes end to end on every `cargo test`), and on the
//! PJRT backend when artifacts exist, preserving the pre-backend
//! coverage.  `ROAD_TEST_BACKEND=ref|pjrt` overrides the choice.  The
//! cross-backend oracle is [`reference_matches_pjrt_token_identity`],
//! which stays artifact-gated.

use std::rc::Rc;
use std::time::Duration;

use road::adapters::{Adapter, Ia3Adapter, LoraAdapter, RoadAdapter};
use road::coordinator::engine::{Engine, EngineConfig};
use road::coordinator::queue::EngineError;
use road::coordinator::request::{FinishReason, Request, SamplingParams, StreamEvent};
use road::model::ParamStore;
use road::require_artifacts;
use road::runtime::{BackendKind, Runtime};
use road::util::clock::Clock;
use road::util::rng::Rng;

/// Suite backend ([`BackendKind::auto`]): `ROAD_TEST_BACKEND` (ref|pjrt)
/// wins; otherwise PJRT when artifacts are built (the pre-backend
/// behavior), reference when they are not (so the suite executes instead
/// of skipping).
fn test_backend() -> BackendKind {
    BackendKind::auto()
}

fn rt() -> Rc<Runtime> {
    let rt = Runtime::for_backend(test_backend(), road::Manifest::default_dir())
        .expect("run `make artifacts` first");
    Rc::new(rt)
}

fn tiny_engine(rt: &Rc<Runtime>, mode: &str) -> Engine {
    Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: mode.into(),
            decode_slots: 2,
            queue_capacity: 64,
            ..Default::default()
        },
    )
    .unwrap()
}

fn greedy(prompt: &[i32], max_new: usize) -> Request {
    Request::new(prompt.to_vec(), max_new).with_sampling(SamplingParams {
        temperature: 0.0,
        top_k: 0,
        seed: 0,
        stop_token: None,
    })
}

#[test]
fn greedy_serving_is_deterministic() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(3);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("a", &a).unwrap();

    let mk = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("a"),
            greedy(&[10, 20, 30], 8),
        ]
    };
    let mut out1 = eng.run_all(mk()).unwrap();
    let mut out2 = eng.run_all(mk()).unwrap();
    out1.sort_by_key(|o| o.adapter.clone());
    out2.sort_by_key(|o| o.adapter.clone());
    for (x, y) in out1.iter().zip(&out2) {
        assert_eq!(x.tokens, y.tokens);
    }
    // The adapter actually changes the output distribution.
    assert_ne!(out1[0].tokens, out1[1].tokens, "adapter had no effect");
}

/// The device-resident decode loop must be a pure transfer optimization:
/// greedy outputs are token-identical to the host-round-trip baseline.
#[test]
fn device_resident_kv_matches_host_roundtrip() {
    let rt = rt();
    let mut rng = Rng::seed_from(12);
    let adapter = Adapter::Road(RoadAdapter::random(
        &rt.manifest.config("tiny").unwrap().clone(),
        &mut rng,
        0.3,
    ));
    let mk_reqs = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("x"),
            greedy(&[5, 6], 6),
            greedy(&[9, 8, 7, 6], 7).with_adapter("x"),
        ]
    };
    let run = |kv_host_roundtrip: bool| {
        let mut eng = Engine::new(
            rt.clone(),
            EngineConfig {
                model: "tiny".into(),
                mode: "road".into(),
                decode_slots: 2,
                queue_capacity: 64,
                kv_host_roundtrip,
                ..Default::default()
            },
        )
        .unwrap();
        eng.register_adapter("x", &adapter).unwrap();
        let mut outs = eng.run_all(mk_reqs()).unwrap();
        outs.sort_by_key(|o| o.id);
        (outs, eng.metrics.kv_host_syncs, eng.metrics.decode_steps)
    };
    let (device, device_syncs, device_steps) = run(false);
    let (host, _, host_steps) = run(true);
    assert_eq!(device.len(), host.len());
    for (d, h) in device.iter().zip(&host) {
        assert_eq!(d.tokens, h.tokens, "device-resident decode changed outputs");
    }
    assert_eq!(device_steps, host_steps);
    // Device path materializes at admissions only — strictly fewer full
    // cache downloads than decode steps.
    assert!(
        device_syncs < device_steps,
        "kv syncs {device_syncs} should be < decode steps {device_steps}"
    );
}

/// The paper's hetero-batching claim, end to end: a request's tokens are
/// identical whether it runs alone or batched beside a different adapter.
#[test]
fn adapter_state_does_not_leak_across_lanes() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(4);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    let b = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("a", &a).unwrap();
    eng.register_adapter("b", &b).unwrap();

    // Solo run with adapter a.
    let solo = eng.run_all(vec![greedy(&[5, 6, 7], 6).with_adapter("a")]).unwrap();
    // Mixed batch: a alongside b.
    let mixed = eng
        .run_all(vec![
            greedy(&[5, 6, 7], 6).with_adapter("a"),
            greedy(&[5, 6, 7], 6).with_adapter("b"),
        ])
        .unwrap();
    let mixed_a = mixed.iter().find(|o| o.adapter.as_deref() == Some("a")).unwrap();
    assert_eq!(solo[0].tokens, mixed_a.tokens, "lane isolation violated");
}

#[test]
fn merged_road_equals_unmerged_road() {
    let rt = rt();
    // Unmerged: adapter in the bank, road decode path (Eq. 4).
    let mut unmerged = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(5);
    let adapter = RoadAdapter::random(&unmerged.cfg, &mut rng, 0.2);
    unmerged.register_adapter("x", &Adapter::Road(adapter.clone())).unwrap();
    let out_u = unmerged.run_all(vec![greedy(&[9, 8, 7, 6], 8).with_adapter("x")]).unwrap();

    // Merged: W <- W R^T folded host-side, base decode path (paper §3.2).
    let mut params = ParamStore::load_pretrained(&rt.manifest, "tiny").unwrap();
    params.merge_road(&adapter).unwrap();
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "base".into(),
        decode_slots: 2,
        queue_capacity: 64,
        ..Default::default()
    };
    let mut merged = Engine::with_params(rt.clone(), econf, params).unwrap();
    let out_m = merged.run_all(vec![greedy(&[9, 8, 7, 6], 8)]).unwrap();

    assert_eq!(out_u[0].tokens, out_m[0].tokens, "merge changed the model");
}

#[test]
fn more_requests_than_slots_all_complete() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    let reqs: Vec<Request> =
        (0..7).map(|i| greedy(&[1 + i as i32, 2, 3], 4)).collect();
    let outs = eng.run_all(reqs).unwrap();
    assert_eq!(outs.len(), 7);
    assert!(outs.iter().all(|o| o.tokens.len() == 4));
    assert!(outs.iter().all(|o| o.finish == FinishReason::MaxTokens));
}

#[test]
fn stop_token_finishes_early_and_is_stripped() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    // Find what the model greedily emits, then use it as the stop token.
    let probe = eng.run_all(vec![greedy(&[42, 43], 3)]).unwrap();
    let first = probe[0].tokens[0];
    let mut req = greedy(&[42, 43], 8);
    req.sampling.stop_token = Some(first);
    let outs = eng.run_all(vec![req]).unwrap();
    assert_eq!(outs[0].finish, FinishReason::StopToken);
    assert!(!outs[0].tokens.contains(&first));
}

#[test]
fn submit_validates_prompts_and_adapters() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    // Empty prompt.
    assert!(matches!(
        eng.submit(greedy(&[], 4)),
        Err(EngineError::Invalid { .. })
    ));
    // Prompt longer than the largest prefill bucket.
    let long = vec![1i32; eng.max_prompt_len() + 1];
    assert!(matches!(
        eng.submit(greedy(&long, 4)),
        Err(EngineError::Invalid { .. })
    ));
    // Unknown adapter is its own typed variant.
    assert!(matches!(
        eng.submit(greedy(&[1, 2], 4).with_adapter("nope")),
        Err(EngineError::AdapterNotFound { name }) if name == "nope"
    ));
    // prompt + max_new beyond max_seq.
    assert!(matches!(
        eng.submit(greedy(&[1, 2], eng.cfg.max_seq)),
        Err(EngineError::Invalid { .. })
    ));
}

#[test]
fn queue_backpressure_rejects_when_full() {
    let rt = rt();
    let mut eng = Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: "base".into(),
            decode_slots: 2,
            queue_capacity: 2,
            ..Default::default()
        },
    )
    .unwrap();
    eng.submit(greedy(&[1, 2], 2)).unwrap();
    eng.submit(greedy(&[1, 2], 2)).unwrap();
    // Typed backpressure straight off the submit path.
    let err = eng.submit(greedy(&[1, 2], 2)).unwrap_err();
    assert_eq!(err, EngineError::QueueFull { waiting: 2 });
    assert!(err.to_string().contains("backpressure"), "{err}");
}

#[test]
fn metrics_account_for_all_tokens() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    let outs = eng.run_all(vec![greedy(&[3, 4, 5], 6), greedy(&[6, 7], 6)]).unwrap();
    let gen: usize = outs.iter().map(|o| o.tokens.len()).sum();
    assert_eq!(eng.metrics.tokens_generated, gen);
    assert_eq!(eng.metrics.requests_completed, 2);
    assert_eq!(eng.metrics.prompt_tokens, 5);
    assert!(eng.metrics.decode_steps > 0);
}

/// TTFT/e2e clocks start at submit: a request that waits behind a full set
/// of slots reports e2e ≥ its queue wait, and the queue-wait histogram
/// records one sample per admitted request.
#[test]
fn latency_metrics_include_queue_wait() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "base");
    // 5 requests through 2 slots: at least 3 must wait for a free slot.
    let reqs: Vec<Request> = (0..5).map(|i| greedy(&[1 + i as i32, 2], 4)).collect();
    let outs = eng.run_all(reqs).unwrap();
    assert_eq!(outs.len(), 5);
    assert_eq!(eng.metrics.queue_wait.count(), 5, "one wait sample per admission");
    for o in &outs {
        assert!(o.e2e >= o.ttft, "e2e {} < ttft {}", o.e2e, o.ttft);
        assert!(o.ttft >= 0.0);
    }
    // Depth was sampled every scheduler step and saw the initial backlog.
    let depth = eng.metrics.queue_depth_summary();
    assert!(depth.n >= eng.metrics.decode_steps);
    assert!(depth.max >= 3.0, "max depth {}", depth.max);
}

/// Store-capacity churn: far more registered adapters than pageable bank
/// slots, round-robin traffic.  The paged engine must (a) accept every
/// registration, (b) serve every request to completion with token output
/// identical to a large-bank run, and (c) on the paged-upload path move
/// strictly fewer bank bytes than the whole-bank-upload baseline.
#[test]
fn bank_churn_token_identical_to_large_bank() {
    let rt = rt();
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    assert!(cfg.n_adapters >= 4, "tiny config has {} bank slots", cfg.n_adapters);
    // Fits entirely in the large bank, overflows the 2 pageable slots of
    // the small one.
    let distinct = cfg.n_adapters - 1;
    let mut rng = Rng::seed_from(21);
    let adapters: Vec<Adapter> = (0..distinct)
        .map(|_| Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.25)))
        .collect();
    // Round-robin over the adapters: every adapter recurs with others in
    // between, so a 2-slot pager is guaranteed to miss and evict (the
    // Zipf-skewed variant of this workload is the bench study's job).
    let mk_reqs = || {
        let mut wrng = Rng::seed_from(33);
        road::bench::hetero_workload(&mut wrng, 3 * distinct, distinct, 4, 5)
    };
    let run = |bank_slots: Option<usize>, paged: bool| {
        let mut eng = Engine::new(
            rt.clone(),
            EngineConfig {
                model: "tiny".into(),
                mode: "road".into(),
                decode_slots: 2,
                queue_capacity: 256,
                bank_slots,
                paged_bank_uploads: paged,
                ..Default::default()
            },
        )
        .unwrap();
        for (i, a) in adapters.iter().enumerate() {
            eng.register_adapter(&format!("adapter-{i}"), a).unwrap();
        }
        let mut outs = eng.run_all(mk_reqs()).unwrap();
        outs.sort_by_key(|o| o.id);
        (
            outs,
            eng.metrics.bank_misses,
            eng.metrics.bank_evictions,
            eng.metrics.bank_upload_bytes,
        )
    };
    let (big, _, big_evict, _) = run(None, true);
    let (paged, misses, evictions, paged_bytes) = run(Some(3), true);
    let (whole, _, _, whole_bytes) = run(Some(3), false);

    assert_eq!(big.len(), 3 * distinct, "every request completes");
    assert_eq!(paged.len(), big.len());
    for (p, b) in paged.iter().zip(&big) {
        assert_eq!(p.tokens, b.tokens, "paging changed request {} output", p.id);
    }
    for (p, w) in paged.iter().zip(&whole) {
        assert_eq!(p.tokens, w.tokens, "upload policy changed request {} output", p.id);
    }
    assert_eq!(big_evict, 0, "large bank never evicts when all adapters fit");
    assert!(misses > 0, "small bank must page");
    assert!(evictions > 0, "adapters beyond slots must evict");
    assert!(
        paged_bytes < whole_bytes,
        "per-slot uploads ({paged_bytes}B) must move less than whole-bank ({whole_bytes}B)"
    );
}

/// Unregister is rejected while the adapter still has queued work, and
/// succeeds once its requests have drained.
#[test]
fn unregister_waits_for_queued_requests() {
    let rt = rt();
    let mut eng = tiny_engine(&rt, "road");
    let mut rng = Rng::seed_from(8);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("tmp", &a).unwrap();
    eng.submit(greedy(&[4, 5], 3).with_adapter("tmp")).unwrap();
    assert!(eng.unregister_adapter("tmp").is_err(), "queued request blocks unregister");
    while eng.has_work() {
        eng.step().unwrap();
    }
    eng.unregister_adapter("tmp").unwrap();
    // Gone: new submissions referencing it are rejected.
    assert!(eng.submit(greedy(&[4, 5], 3).with_adapter("tmp")).is_err());
}

#[test]
fn engine_server_thread_roundtrip() {
    use road::coordinator::server::EngineServer;
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "road".into(),
        decode_slots: 2,
        queue_capacity: 64,
        backend: test_backend(),
        ..Default::default()
    };
    // The reference backend ignores the artifacts dir (nothing on disk).
    let dir = road::Manifest::default_dir();
    let (server, client) = EngineServer::start(econf, dir, |eng| {
        let mut rng = Rng::seed_from(6);
        let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.2));
        eng.register_adapter("srv", &a)?;
        Ok(())
    })
    .unwrap();
    let out = client.generate(greedy(&[11, 12, 13], 5).with_adapter("srv")).unwrap();
    assert_eq!(out.tokens.len(), 5);
    // Stats cross the channel as a typed snapshot, rendered client-side.
    let stats = client.stats().unwrap();
    assert_eq!(stats.requests_completed, 1);
    assert_eq!(stats.tokens_generated, 5);
    assert!(stats.report().contains("requests=1"), "{}", stats.report());
    server.shutdown().unwrap();
}

/// Every adapter mode serves end to end on the active backend, and
/// identity-initialized banks reproduce the base model's tokens through
/// the whole engine (admission → prefill → banked decode).  The ia3 leg
/// is reference-only: aot.py lowers tiny artifacts for base/road/lora,
/// while the synthetic manifest carries all four modes.
#[test]
fn every_adapter_mode_serves_and_identity_matches_base() {
    let rt = rt();
    let reqs = || vec![greedy(&[12, 34, 56], 6), greedy(&[7, 8], 5)];
    let base = tiny_engine(&rt, "base").run_all(reqs()).unwrap();
    assert_eq!(base.len(), 2);
    let mut modes = vec!["road", "lora"];
    if test_backend() == BackendKind::Reference {
        modes.push("ia3");
    }
    for mode in modes {
        // No adapter registered: every lane uses the identity slot 0.
        let outs = tiny_engine(&rt, mode).run_all(reqs()).unwrap();
        assert_eq!(outs.len(), base.len(), "mode {mode}");
        for (o, b) in outs.iter().zip(&base) {
            assert_eq!(o.tokens, b.tokens, "identity {mode} diverged from base");
        }
    }
}

// ---------------------------------------------------------------------------
// Paged KV: shared-prefix reuse, eviction safety, exactly-once release
// ---------------------------------------------------------------------------

/// A 12-token prefix (3 cacheable blocks at block size 4) plus a 4-token
/// request-specific suffix — the tiny model's 16-token prefill bucket.
fn prefixed(prefix_tag: i32, suffix_tag: i32) -> Vec<i32> {
    let mut p: Vec<i32> = (0..12).map(|i| 1 + (prefix_tag * 13 + i) % 200).collect();
    p.extend((0..4).map(|i| 1 + (suffix_tag * 31 + i) % 200));
    p
}

/// An engine on the tiny model with 4-token KV blocks, paged or flat, on
/// the given clock, optionally with a squeezed pool budget.
fn paged_engine(rt: &Rc<Runtime>, paged: bool, pool: Option<usize>, clock: Clock) -> Engine {
    Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: "road".into(),
            decode_slots: 2,
            queue_capacity: 64,
            clock,
            paged_kv: paged,
            kv_block_size: 4,
            kv_pool_blocks: pool,
            ..Default::default()
        },
    )
    .unwrap()
}

fn two_adapters(eng: &mut Engine, seed: u64) {
    let mut rng = Rng::seed_from(seed);
    let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    let b = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.3));
    eng.register_adapter("a", &a).unwrap();
    eng.register_adapter("b", &b).unwrap();
}

/// The tentpole identity claim: a request admitted over a cached shared
/// prefix produces exactly the tokens it produces cold, across a
/// heterogeneous-adapter batch, and the flat (pre-paging) layout agrees.
#[test]
fn shared_prefix_reuse_is_token_identical_to_flat() {
    let rt = rt();
    // Two waves per engine: the first warms the prefix cache per adapter,
    // the second re-uses it (same prefixes, fresh suffixes).
    let wave1 = || {
        vec![
            greedy(&prefixed(1, 10), 8).with_adapter("a"),
            greedy(&prefixed(2, 20), 8).with_adapter("b"),
        ]
    };
    let wave2 = || {
        vec![
            greedy(&prefixed(1, 11), 8).with_adapter("a"),
            greedy(&prefixed(2, 21), 8).with_adapter("b"),
            greedy(&prefixed(1, 12), 8), // same tokens, no adapter: must NOT share
        ]
    };
    let run = |paged: bool| {
        let mut eng = paged_engine(&rt, paged, None, Clock::wall());
        two_adapters(&mut eng, 40);
        let mut outs = eng.run_all(wave1()).unwrap();
        outs.extend(eng.run_all(wave2()).unwrap());
        outs.sort_by_key(|o| o.id);
        let hits = eng.metrics.kv_prefix_hits;
        let saved = eng.metrics.kv_prefill_tokens_saved;
        let prefill = eng.metrics.prefill_lane_tokens;
        (outs, hits, saved, prefill)
    };
    let (paged, hits, saved, paged_prefill) = run(true);
    let (flat, flat_hits, _, flat_prefill) = run(false);
    assert_eq!(paged.len(), flat.len());
    for (p, f) in paged.iter().zip(&flat) {
        assert_eq!(p.tokens, f.tokens, "shared-prefix reuse changed request {}", p.id);
        assert_eq!(p.finish, FinishReason::MaxTokens);
    }
    // Both warm adapter-tagged requests hit their 3-block prefix; the
    // adapter-less lookalike must miss (prefix keys are adapter-salted).
    assert_eq!(hits, 2, "expected exactly the two warm adapter requests to hit");
    assert_eq!(saved, 2 * 12, "each hit skips its 12 cached prefix tokens");
    assert_eq!(flat_hits, 0, "flat accounting has no prefix cache");
    // A hit lane skips prefill entirely: its 12 cached tokens come from
    // the pool and the 4 suffix tokens are fed through the decode path.
    assert_eq!(
        flat_prefill - paged_prefill,
        2 * 16,
        "each of the two hit lanes should skip one full 16-token prefill"
    );
}

/// Prefix-hit admission on the virtual clock: the warm request goes
/// through zero prefill tokens and reaches its first token in a handful of
/// virtual milliseconds, with the hit recorded in the TTFT histogram.
#[test]
fn prefix_hit_skips_prefill_with_near_zero_ttft() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = paged_engine(&rt, true, None, clock.clone());
    let drain = |eng: &mut Engine, clock: &Clock| {
        let mut outs = Vec::new();
        while eng.has_work() {
            for ev in eng.step().unwrap() {
                if let StreamEvent::Finished(o) = ev {
                    outs.push(o);
                }
            }
            clock.advance(Duration::from_millis(1));
        }
        outs
    };
    eng.submit(greedy(&prefixed(3, 1), 8)).unwrap();
    let cold = drain(&mut eng, &clock);
    assert_eq!(eng.metrics.prefill_lane_tokens, 16);
    assert_eq!(eng.metrics.kv_prefix_hits, 0);

    eng.submit(greedy(&prefixed(3, 2), 8)).unwrap();
    let warm = drain(&mut eng, &clock);
    assert_eq!(eng.metrics.kv_prefix_hits, 1);
    assert_eq!(eng.metrics.kv_block_hits, 3);
    assert_eq!(eng.metrics.kv_prefill_tokens_saved, 12);
    // No new prefill-lane tokens: the warm request never entered a
    // prefill batch — strictly fewer prefill tokens than a cold run.
    assert_eq!(eng.metrics.prefill_lane_tokens, 16);
    // First token after feeding the 4 uncached prompt tokens through the
    // decode path: single-digit virtual milliseconds.
    assert_eq!(warm.len(), 1);
    assert!(warm[0].ttft < 0.010, "hit-lane ttft {}s", warm[0].ttft);
    assert_eq!(eng.metrics.prefix_hit_ttft.count(), 1);

    // And the reuse is invisible in the tokens: a flat engine serving the
    // same two requests agrees with both.
    let mut flat = paged_engine(&rt, false, None, Clock::manual());
    let mut f = flat
        .run_all(vec![greedy(&prefixed(3, 1), 8), greedy(&prefixed(3, 2), 8)])
        .unwrap();
    f.sort_by_key(|o| o.id);
    assert_eq!(cold[0].tokens, f[0].tokens);
    assert_eq!(warm[0].tokens, f[1].tokens);
}

/// Eviction under pressure: a pool too small to cache every prefix must
/// evict — and eviction may only ever take unreferenced cached blocks, so
/// every output is identical to a pressure-free run.
#[test]
fn eviction_under_pressure_never_touches_inflight_blocks() {
    let rt = rt();
    // 6 distinct prefix groups x 3 cached blocks each overflow the tight
    // pool once two 8-block lanes are also in flight.
    let reqs = || {
        let mut v = Vec::new();
        for g in 0..6 {
            v.push(greedy(&prefixed(g, 2 * g), 8));
            v.push(greedy(&prefixed(g, 2 * g + 1), 8));
        }
        v
    };
    let run = |pool: Option<usize>| {
        let mut eng = paged_engine(&rt, true, pool, Clock::wall());
        let mut outs = eng.run_all(reqs()).unwrap();
        outs.sort_by_key(|o| o.id);
        let pressure = (
            eng.metrics.kv_block_evictions,
            eng.metrics.kv_admission_stalls,
            eng.metrics.kv_blocks_free_min,
        );
        // Drained: no lane holds anything, no reference outstanding.
        let pool = eng.paged_kv().pool();
        assert_eq!(pool.n_private(), 0);
        assert_eq!(pool.total_refs(), 0);
        pool.check_conservation().unwrap();
        (outs, pressure)
    };
    let (tight, (evictions, _stalls, free_min)) = run(Some(20));
    let (roomy, (roomy_evictions, _, _)) = run(Some(256));
    assert!(evictions > 0, "tight pool should evict cached prefixes");
    assert_eq!(roomy_evictions, 0, "roomy pool should never evict");
    assert!(free_min <= 4, "tight pool should run near empty, min {free_min}");
    assert_eq!(tight.len(), roomy.len());
    for (t, r) in tight.iter().zip(&roomy) {
        assert_eq!(t.tokens, r.tokens, "eviction corrupted request {}", t.id);
    }
}

/// Regression (exactly-once release): a lane reaped by the deadline
/// enforcer returns its private blocks and shared references exactly once
/// — no leak, no double free — and the prefix it published survives for
/// later requests.
#[test]
fn reaped_lane_returns_blocks_exactly_once() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = paged_engine(&rt, true, Some(32), clock.clone());
    let doomed = greedy(&prefixed(5, 1), 64).with_deadline(Duration::from_millis(5));
    let id = eng.submit(doomed).unwrap();
    // Admit and decode a little, then blow the deadline.
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 1);
    assert!(eng.paged_kv().pool().n_private() > 0, "in-flight lane holds blocks");
    clock.advance(Duration::from_millis(10));
    let events = eng.step().unwrap();
    assert!(
        events.iter().any(|e| matches!(
            e,
            StreamEvent::Error { id: eid, error: EngineError::DeadlineExceeded } if *eid == id
        )),
        "expected a deadline error event"
    );
    assert_eq!(eng.metrics.deadline_shed, 1);
    let pool = eng.paged_kv().pool();
    assert_eq!(pool.n_private(), 0, "reaped lane leaked private blocks");
    assert_eq!(pool.total_refs(), 0, "reaped lane leaked shared references");
    // The cold lane published all 4 full prompt blocks before the reap.
    assert_eq!(pool.n_cached(), 4, "published prefix should survive the reap");
    pool.check_conservation().unwrap();

    // The reaped lane's published prefix is still serviceable.
    eng.submit(greedy(&prefixed(5, 2), 4)).unwrap();
    while eng.has_work() {
        eng.step().unwrap();
        clock.advance(Duration::from_millis(1));
    }
    assert_eq!(eng.metrics.kv_prefix_hits, 1, "survivor prefix should hit");
    let pool = eng.paged_kv().pool();
    assert_eq!(pool.n_private(), 0);
    assert_eq!(pool.total_refs(), 0);
    pool.check_conservation().unwrap();
}

/// Regression (COW release on cancel): cancelling a lane admitted over a
/// shared prefix drops its references without freeing the cached
/// originals, which keep serving later requests token-identically.
#[test]
fn cancel_releases_cow_refs_but_keeps_shared_originals() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = paged_engine(&rt, true, None, clock.clone());
    // Warm the cache.
    eng.submit(greedy(&prefixed(6, 1), 6)).unwrap();
    while eng.has_work() {
        eng.step().unwrap();
        clock.advance(Duration::from_millis(1));
    }
    // All 4 full prompt blocks of the warming request are published.
    let cached = eng.paged_kv().pool().n_cached();
    assert_eq!(cached, 4);

    // A hit lane in flight holds references onto the cached blocks.
    let id = eng.submit(greedy(&prefixed(6, 2), 32)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.metrics.kv_prefix_hits, 1);
    assert_eq!(eng.paged_kv().pool().total_refs(), 3);
    let out = eng.cancel(id).expect("in-flight lane cancels");
    assert_eq!(out.finish, FinishReason::Cancelled);
    let pool = eng.paged_kv().pool();
    assert_eq!(pool.total_refs(), 0, "cancel must drop the COW references");
    assert_eq!(pool.n_private(), 0, "cancel must free the private blocks");
    assert_eq!(pool.n_cached(), cached, "cancel must NOT free shared originals");
    pool.check_conservation().unwrap();

    // The originals still serve: a fresh same-prefix request hits and
    // matches a cold run of the same prompt on a fresh engine.
    eng.submit(greedy(&prefixed(6, 3), 6)).unwrap();
    let mut warm_tokens = Vec::new();
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Finished(o) = ev {
                warm_tokens = o.tokens;
            }
        }
        clock.advance(Duration::from_millis(1));
    }
    assert_eq!(eng.metrics.kv_prefix_hits, 2);
    let mut cold = paged_engine(&rt, true, None, Clock::manual());
    let cold_out = cold.run_all(vec![greedy(&prefixed(6, 3), 6)]).unwrap();
    assert_eq!(warm_tokens, cold_out[0].tokens, "post-cancel hit diverged");
}

// ---------------------------------------------------------------------------
// Chunked prefill (mixed steps) + admission-path stall accounting
// ---------------------------------------------------------------------------

/// The chunk-prefill entry ships in the synthetic (reference) manifest
/// only — aot.py lowers no chunk_prefill artifacts — so the chunked-mode
/// tests pin the reference backend instead of [`test_backend`].
fn ref_rt() -> Rc<Runtime> {
    Rc::new(Runtime::for_backend(BackendKind::Reference, road::Manifest::default_dir()).unwrap())
}

/// One scheduler step on the virtual clock, charged at 1ms per iteration
/// plus 1ms per prompt token prefilled (bucketed or chunked) — the
/// constant-rate cost model the ITL assertions below are phrased in: an
/// atomic 32-token prefill costs a 33ms step, a chunked step never
/// exceeds 1ms + its token budget.
fn step_charged(eng: &mut Engine, clock: &Clock, fed_seen: &mut usize) -> Vec<StreamEvent> {
    let evs = eng.step().unwrap();
    let fed = eng.metrics.prefill_lane_tokens + eng.metrics.chunk_prefill_tokens;
    let delta = fed - *fed_seen;
    *fed_seen = fed;
    clock.advance(Duration::from_millis(1) * (1 + delta) as u32);
    evs
}

/// The tentpole identity claim for mixed steps: streaming prompts through
/// the chunk-prefill entry under a per-iteration token budget produces
/// exactly the tokens the atomic bucketed prefill produces, across a
/// heterogeneous-adapter batch — chunking is a scheduling change, not a
/// model change.
#[test]
fn chunked_prefill_token_identical_to_atomic_prefill() {
    let rt = ref_rt();
    let mk = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("a"),
            greedy(&(1..=20).collect::<Vec<i32>>(), 6).with_adapter("b"),
            greedy(&[5, 6], 6),
            greedy(&prefixed(9, 1), 5).with_adapter("a"),
            greedy(&[42, 43, 44], 4).with_adapter("b"),
        ]
    };
    let run = |chunk: usize| {
        let mut eng = Engine::new(
            rt.clone(),
            EngineConfig {
                model: "tiny".into(),
                mode: "road".into(),
                decode_slots: 2,
                queue_capacity: 64,
                prefill_chunk_tokens: chunk,
                ..Default::default()
            },
        )
        .unwrap();
        two_adapters(&mut eng, 77);
        let mut outs = eng.run_all(mk()).unwrap();
        outs.sort_by_key(|o| o.id);
        (outs, eng.metrics.prefill_batches, eng.metrics.chunk_prefill_tokens)
    };
    let (atomic, atomic_batches, atomic_chunk_tokens) = run(0);
    let (chunked, chunked_batches, chunked_chunk_tokens) = run(6);
    assert!(atomic_batches > 0, "baseline must run bucketed prefills");
    assert_eq!(atomic_chunk_tokens, 0, "baseline must never touch the chunk entry");
    assert_eq!(chunked_batches, 0, "chunked admission must never run a bucketed prefill");
    assert!(chunked_chunk_tokens > 0, "prompts must stream through the chunk entry");
    assert_eq!(atomic.len(), chunked.len());
    for (a, c) in atomic.iter().zip(&chunked) {
        assert_eq!(a.tokens, c.tokens, "chunked prefill changed request {} output", a.id);
        assert_eq!(a.finish, c.finish);
    }
}

/// The ITL regression the tentpole fixes, on the virtual clock: admit a
/// max-length prompt into an actively decoding batch.  Under the atomic
/// baseline the short request's inter-token gap absorbs the entire
/// 32-token prefill (33 virtual ms); under `--prefill-chunk 6` no step —
/// and therefore no gap — can exceed the 6-token budget (5ms when one
/// lane decodes beside the feeding lane).
#[test]
fn chunked_prefill_bounds_decode_stall_from_long_prompt_admission() {
    let rt = ref_rt();
    let run = |chunk: usize| {
        let clock = Clock::manual();
        let mut eng = Engine::new(
            rt.clone(),
            EngineConfig {
                model: "tiny".into(),
                mode: "road".into(),
                decode_slots: 2,
                queue_capacity: 64,
                clock: clock.clone(),
                prefill_chunk_tokens: chunk,
                ..Default::default()
            },
        )
        .unwrap();
        let mut fed = 0usize;
        let mut outs = Vec::new();
        // The short request is admitted and decoding...
        eng.submit(greedy(&[3, 4, 5, 6], 16)).unwrap();
        for ev in step_charged(&mut eng, &clock, &mut fed) {
            if let StreamEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
        // ...when a max-length prompt arrives mid-stream.
        let long: Vec<i32> = (1..=32).collect();
        assert_eq!(long.len(), eng.max_prompt_len());
        eng.submit(greedy(&long, 2)).unwrap();
        let mut steps = 0;
        while eng.has_work() {
            for ev in step_charged(&mut eng, &clock, &mut fed) {
                if let StreamEvent::Finished(o) = ev {
                    outs.push(o);
                }
            }
            steps += 1;
            assert!(steps < 300, "engine wedged");
        }
        outs.sort_by_key(|o| o.id);
        (outs, eng.metrics.itl.summary().max)
    };
    let (atomic, atomic_max_us) = run(0);
    let (chunked, chunked_max_us) = run(6);
    // Chunking changes when prompt tokens are computed, never what any
    // request generates.
    assert_eq!(atomic.len(), 2);
    assert_eq!(chunked.len(), 2);
    for (a, c) in atomic.iter().zip(&chunked) {
        assert_eq!(a.tokens, c.tokens, "chunking changed request {}", a.id);
    }
    // Red under --prefill-chunk=0: the short lane's worst gap spans the
    // whole 32-token prefill step (1ms + 32ms under the cost model).
    assert!(atomic_max_us >= 33_000.0 - 1.0, "atomic max itl {atomic_max_us}us");
    // Green chunked: no step exceeds 1ms + (budget - active) tokens = 5ms.
    assert!(chunked_max_us <= 5_000.0 + 1.0, "chunked max itl {chunked_max_us}us");
    assert!(chunked_max_us < atomic_max_us);
}

/// Regression (counter inflation): a request parked at the KV-block gate
/// for many scheduler iterations is ONE stall, not one per retry.  A
/// 6-block pool fits request A (5 blocks) but strands B behind it until A
/// drains and its published prefix becomes evictable.
#[test]
fn kv_admission_stall_counts_one_transition_not_retries() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = paged_engine(&rt, true, Some(6), clock.clone());
    let a: Vec<i32> = (1..=12).collect();
    let b: Vec<i32> = (101..=112).collect();
    eng.submit(greedy(&a, 8)).unwrap();
    eng.submit(greedy(&b, 8)).unwrap();
    let mut outs = Vec::new();
    let mut steps = 0usize;
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
        clock.advance(Duration::from_millis(1));
        steps += 1;
        assert!(steps < 200, "engine wedged");
    }
    assert_eq!(outs.len(), 2, "the stalled request must eventually admit and finish");
    assert!(outs.iter().all(|o| o.tokens.len() == 8));
    // B retried the block gate on every iteration of A's 8-token decode.
    assert!(steps > 8, "B must have waited across iterations, saw {steps}");
    assert_eq!(eng.metrics.kv_admission_stalls, 1, "stall counter inflated by retries");
    assert!(eng.metrics.kv_block_evictions > 0, "B's admission evicts A's cached prefix");
}

/// Same transition accounting for the adapter-bank gate: with a single
/// pageable bank slot pinned by an in-flight request, the request waiting
/// on the other adapter is ONE bank stall across its whole wait.
#[test]
fn bank_admission_stall_counts_one_transition_not_retries() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = Engine::new(
        rt.clone(),
        EngineConfig {
            model: "tiny".into(),
            mode: "road".into(),
            decode_slots: 2,
            queue_capacity: 64,
            bank_slots: Some(2), // identity slot 0 + one pageable slot
            clock: clock.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    two_adapters(&mut eng, 55);
    eng.submit(greedy(&[10, 20, 30], 8).with_adapter("a")).unwrap();
    eng.submit(greedy(&[40, 50], 4).with_adapter("b")).unwrap();
    let mut outs = Vec::new();
    let mut steps = 0usize;
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Finished(o) = ev {
                outs.push(o);
            }
        }
        clock.advance(Duration::from_millis(1));
        steps += 1;
        assert!(steps < 200, "engine wedged");
    }
    assert_eq!(outs.len(), 2, "the bank-stalled request must eventually serve");
    assert!(steps > 6, "b must have waited across iterations, saw {steps}");
    assert_eq!(eng.metrics.bank_admission_stalls, 1, "bank stall counter inflated by retries");
    assert_eq!(eng.metrics.kv_admission_stalls, 0, "the block gate never bound here");
    assert_eq!(eng.metrics.bank_evictions, 1, "b pages in over a's slot once it drains");
}

/// The fused epilogue is a pure iteration-shape change: serving a
/// heterogeneous-adapter batch (two distinct adapters plus an identity
/// lane) with `fused_epilogue: false` (the scalar oracle) must produce
/// token-identical greedy streams to the fused default, end to end
/// through admission, prefill, and banked decode — for every adapter
/// mode.  Reference backend: the flag only steers the reference kernels.
#[test]
fn fused_epilogue_token_identical_to_scalar_oracle() {
    let rt = ref_rt();
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let mk_reqs = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("a"),
            greedy(&[10, 20, 30], 8).with_adapter("b"),
            greedy(&[5, 6, 7], 6),
        ]
    };
    for mode in ["road", "lora", "ia3"] {
        let mut rng = Rng::seed_from(77);
        let (a, b) = match mode {
            "road" => (
                Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3)),
                Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3)),
            ),
            "lora" => (
                Adapter::Lora(LoraAdapter::random(&cfg, &mut rng, 0.3)),
                Adapter::Lora(LoraAdapter::random(&cfg, &mut rng, 0.3)),
            ),
            _ => (
                Adapter::Ia3(Ia3Adapter::random(&cfg, &mut rng, 0.3)),
                Adapter::Ia3(Ia3Adapter::random(&cfg, &mut rng, 0.3)),
            ),
        };
        let run = |fused: bool| {
            let mut eng = Engine::new(
                rt.clone(),
                EngineConfig {
                    model: "tiny".into(),
                    mode: mode.into(),
                    decode_slots: 3,
                    queue_capacity: 64,
                    fused_epilogue: fused,
                    ..Default::default()
                },
            )
            .unwrap();
            eng.register_adapter("a", &a).unwrap();
            eng.register_adapter("b", &b).unwrap();
            let mut outs = eng.run_all(mk_reqs()).unwrap();
            outs.sort_by_key(|o| o.id);
            outs
        };
        let (fused, scalar) = (run(true), run(false));
        assert_eq!(fused.len(), 3, "mode {mode}");
        for (f, s) in fused.iter().zip(&scalar) {
            assert_eq!(f.tokens, s.tokens, "mode {mode}: fused epilogue changed tokens");
            assert_eq!(f.finish, FinishReason::MaxTokens, "mode {mode}");
        }
        // Distinct adapters in the same batch actually diverge, so the
        // identity above is not vacuous.
        assert_ne!(fused[0].tokens, fused[2].tokens, "mode {mode}: adapter a had no effect");
    }
}

/// Cross-backend oracle (artifact-gated): the pure-Rust reference model
/// and the compiled PJRT artifacts, serving the *same weights* from the
/// same manifest, must produce token-identical greedy outputs.  This is
/// the test that pins the artifact path's numerics to the reference
/// implementation; it requires `make artifacts` plus the native xla
/// runtime (the vendored host-memory stub cannot execute HLO).
#[test]
fn reference_matches_pjrt_token_identity() {
    require_artifacts!();
    let dir = road::Manifest::default_dir();
    let pjrt = Rc::new(Runtime::new(road::Manifest::load(&dir).unwrap()).unwrap());
    let reference =
        Rc::new(Runtime::reference_with(road::Manifest::load(&dir).unwrap()).unwrap());
    assert_eq!(reference.backend, BackendKind::Reference);

    let cfg = pjrt.manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::seed_from(99);
    let adapter = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.25));
    let mk_reqs = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("x"),
            greedy(&[5, 6], 6),
        ]
    };
    let run = |rt: &Rc<Runtime>| {
        let mut eng = tiny_engine(rt, "road");
        eng.register_adapter("x", &adapter).unwrap();
        let mut outs = eng.run_all(mk_reqs()).unwrap();
        outs.sort_by_key(|o| o.id);
        outs
    };
    let (ref_outs, pjrt_outs) = (run(&reference), run(&pjrt));
    assert_eq!(ref_outs.len(), pjrt_outs.len());
    for (r, p) in ref_outs.iter().zip(&pjrt_outs) {
        assert_eq!(r.tokens, p.tokens, "backends diverged on request {}", r.id);
    }
}
