"""Layer-2 model correctness: entry-point consistency across adapter modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref

CFG = configs.TINY
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def batch():
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, CFG.vocab)
    lens = jnp.array([12, 7], dtype=jnp.int32)
    ids = jnp.array([0, 1], dtype=jnp.int32)
    return toks, lens, ids


def random_road_banks(cfg, n, seed=5):
    banks = {}
    k = jax.random.PRNGKey(seed)
    for i in range(cfg.n_layers):
        for proj in configs.PROJS:
            _, d_out = configs.proj_dims(cfg, proj)
            k, k1, k2 = jax.random.split(k, 3)
            theta = 0.3 * jax.random.normal(k1, (n, d_out // 2))
            alpha = 1.0 + 0.1 * jax.random.normal(k2, (n, d_out // 2))
            r1, r2 = jax.vmap(ref.road_vectors_1)(theta, alpha)
            banks[f"blocks.{i}.{proj}.r1"] = r1
            banks[f"blocks.{i}.{proj}.r2"] = r2
    return banks


class TestIdentityAdapters:
    """theta=0, alpha=1 must reproduce the base model exactly — the paper's
    'preserve the starting point' initialization property."""

    @pytest.mark.parametrize("mode", ["road", "lora", "ia3", "oft"])
    def test_prefill_matches_base(self, params, batch, mode):
        toks, lens, ids = batch
        ad = model.init_adapters(CFG, mode)
        base, _, _ = model.prefill(CFG, "base", params, {}, ids, toks, lens)
        got, _, _ = model.prefill(CFG, mode, params, ad, ids, toks, lens)
        np.testing.assert_allclose(got, base, rtol=2e-4, atol=2e-4)


class TestPrefillDecodeConsistency:
    def test_prefill_logits_match_full_forward(self, params, batch):
        toks, lens, ids = batch
        lg, _, _ = model.prefill(CFG, "base", params, {}, ids, toks, lens)
        full = model.full_forward(CFG, "base", params, {}, ids, toks)
        np.testing.assert_allclose(lg[0], full[0, 11], rtol=2e-3, atol=1e-3)
        np.testing.assert_allclose(lg[1], full[1, 6], rtol=2e-3, atol=1e-3)

    def test_decode_step_matches_full_forward(self, params, batch):
        toks, lens, ids = batch
        _, kc, vc = model.prefill(CFG, "base", params, {}, ids, toks, lens)
        nxt = jnp.array([42, 99], dtype=jnp.int32)
        lg2, _, _ = model.decode(CFG, "base", params, {}, ids, nxt, lens,
                                 kc, vc)
        ext = jnp.concatenate([toks[0], jnp.array([42])])[None]
        full = model.full_forward(CFG, "base", params, {}, ids[:1], ext)
        np.testing.assert_allclose(lg2[0], full[0, 12], rtol=5e-3, atol=2e-3)

    def test_two_decode_steps_chain(self, params, batch):
        toks, lens, ids = batch
        _, kc, vc = model.prefill(CFG, "base", params, {}, ids, toks, lens)
        t1 = jnp.array([10, 11], dtype=jnp.int32)
        _, kc, vc = model.decode(CFG, "base", params, {}, ids, t1, lens, kc, vc)
        t2 = jnp.array([20, 21], dtype=jnp.int32)
        lg, _, _ = model.decode(CFG, "base", params, {}, ids, t2, lens + 1,
                                kc, vc)
        ext = jnp.concatenate([toks[0], jnp.array([10, 20])])[None]
        full = model.full_forward(CFG, "base", params, {}, ids[:1], ext)
        np.testing.assert_allclose(lg[0], full[0, 13], rtol=5e-3, atol=2e-3)

    def test_road_decode_matches_merged_weights(self, params, batch):
        """Serving equivalence: unmerged RoAd banks == weights merged with
        W R^T (paper §3.2 zero-overhead-merge claim)."""
        toks, lens, ids = batch
        banks = random_road_banks(CFG, CFG.n_adapters)
        # Build a merged-params model for adapter id 1.
        merged = dict(params)
        for i in range(CFG.n_layers):
            for proj in configs.PROJS:
                nm = f"blocks.{i}.{proj}"
                r1 = banks[f"{nm}.r1"][1]
                r2 = banks[f"{nm}.r2"][1]
                merged[nm] = ref.road_merge(params[nm], r1, r2)
                rmat = ref.road_dense_matrix(r1, r2)
                merged[f"{nm}.bias"] = rmat @ params[f"{nm}.bias"]
        ids1 = jnp.array([1, 1], dtype=jnp.int32)
        lg_road, _, _ = model.prefill(CFG, "road", params, banks, ids1,
                                      toks, lens)
        lg_merged, _, _ = model.prefill(CFG, "base", merged, {}, ids1,
                                        toks, lens)
        np.testing.assert_allclose(lg_road, lg_merged, rtol=5e-3, atol=2e-3)


class TestHeterogeneousBatch:
    def test_each_slot_uses_its_own_adapter(self, params, batch):
        """Slot isolation: batched heterogeneous == per-request homogeneous."""
        toks, lens, _ = batch
        banks = random_road_banks(CFG, CFG.n_adapters)
        ids = jnp.array([3, 1], dtype=jnp.int32)
        lg, _, _ = model.prefill(CFG, "road", params, banks, ids, toks, lens)
        for slot in range(2):
            solo_ids = jnp.full((2,), ids[slot], dtype=jnp.int32)
            solo, _, _ = model.prefill(CFG, "road", params, banks, solo_ids,
                                       toks, lens)
            np.testing.assert_allclose(lg[slot], solo[slot], rtol=2e-4,
                                       atol=2e-4)


class TestHiddenStates:
    def test_shapes_and_embedding_row(self, params, batch):
        toks, lens, ids = batch
        hs = model.hidden_states(CFG, "base", params, {}, ids, toks, lens)
        assert hs.shape == (2, CFG.n_layers + 1, CFG.d_model)
        np.testing.assert_allclose(hs[0, 0], params["tok_emb"][toks[0, 11]],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(hs[1, 0], params["tok_emb"][toks[1, 6]],
                                   rtol=1e-5, atol=1e-6)


class TestRope:
    def test_rope_preserves_norm(self):
        pos = jnp.arange(6)[None]
        cos, sin = model.rope_tables(CFG, pos)
        x = jax.random.normal(jax.random.PRNGKey(3),
                              (1, CFG.n_heads, 6, CFG.head_dim))
        y = model.apply_rope(x, cos, sin)
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1), rtol=1e-5,
                                   atol=1e-5)

    def test_rope_position_zero_is_identity(self):
        pos = jnp.zeros((1, 1), dtype=jnp.int32)
        cos, sin = model.rope_tables(CFG, pos)
        x = jax.random.normal(jax.random.PRNGKey(4),
                              (1, CFG.n_heads, 1, CFG.head_dim))
        np.testing.assert_allclose(model.apply_rope(x, cos, sin), x,
                                   rtol=1e-6, atol=1e-6)

    def test_rope_relative_property(self):
        """<RoPE(q,m), RoPE(k,n)> depends only on m-n."""
        hd = CFG.head_dim
        q = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 1, hd))

        def dot_at(m, n):
            cm, sm = model.rope_tables(CFG, jnp.array([[m]]))
            cn, sn = model.rope_tables(CFG, jnp.array([[n]]))
            qr = model.apply_rope(q, cm, sm)
            kr = model.apply_rope(k, cn, sn)
            return float((qr * kr).sum())

        assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


class TestParamSpecs:
    def test_specs_match_init(self, params):
        specs = model.param_specs(CFG)
        assert [k for k, _ in specs] == sorted(params)
        for k, s in specs:
            assert tuple(params[k].shape) == s

    def test_adapter_specs_match_init(self):
        for mode in ("road", "lora", "ia3", "oft"):
            banks = model.init_adapters(CFG, mode)
            specs = model.adapter_specs(CFG, mode)
            assert [k for k, _ in specs] == sorted(banks)
            for k, s in specs:
                assert tuple(banks[k].shape) == s
