//! The commonsense suite: eight multiple-choice tasks standing in for
//! BoolQ / PIQA / SIQA / HellaSwag / WinoGrande / ARC-e / ARC-c / OBQA
//! (Table 3).  Like the paper, a *single* model is finetuned on the union
//! of all eight (templated generatively); evaluation scores each candidate
//! completion by NLL and picks the argmin — the standard LM-harness
//! protocol for these datasets.

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

fn chars(s: &[u8]) -> String {
    s.iter().map(|&c| c as char).collect()
}

/// BoolQ analogue: yes/no — does the context contain the query letter?
pub struct BoolqX;

impl Task for BoolqX {
    fn name(&self) -> &'static str {
        "boolq-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let ctx: Vec<u8> = (0..8).map(|_| b'a' + rng.below(10) as u8).collect();
        let (q, yes) = if rng.chance(0.5) {
            (ctx[rng.below(8)], true)
        } else {
            loop {
                let c = b'a' + rng.below(10) as u8;
                if !ctx.contains(&c) {
                    break (c, false);
                }
            }
        };
        Example::choice(
            &format!("B:{}?{}>", chars(&ctx), q as char),
            &["yes", "no"],
            usize::from(!yes),
        )
    }
}

/// PIQA analogue: "physical" procedure = continue the periodic pattern;
/// pick the continuation that matches the established period.
pub struct PiqaX;

impl Task for PiqaX {
    fn name(&self) -> &'static str {
        "piqa-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = b'a' + rng.below(8) as u8;
        let b = loop {
            let c = b'a' + rng.below(8) as u8;
            if c != a {
                break c;
            }
        };
        // Pattern "ababab" -> correct next two chars "ab".
        let ctx = [a, b, a, b, a, b];
        let good = chars(&[a, b]);
        let bad = chars(&[b, a]);
        let (c0, c1, ans) =
            if rng.chance(0.5) { (good.clone(), bad, 0) } else { (bad, good.clone(), 1) };
        Example::choice(&format!("I:{}+>", chars(&ctx)), &[&c0, &c1], ans)
    }
}

/// SIQA analogue: 3-choice relational judgement — is x before (<), after
/// (>) or equal (=) to y in the alphabet?
pub struct SiqaX;

impl Task for SiqaX {
    fn name(&self) -> &'static str {
        "siqa-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let x = b'a' + rng.below(10) as u8;
        let y = if rng.chance(0.3) { x } else { b'a' + rng.below(10) as u8 };
        let ans = match x.cmp(&y) {
            std::cmp::Ordering::Less => 0,
            std::cmp::Ordering::Greater => 1,
            std::cmp::Ordering::Equal => 2,
        };
        Example::choice(&format!("S:{}{}?>", x as char, y as char), &["lt", "gt", "eq"], ans)
    }
}

/// HellaSwag analogue: 4-choice ending — the correct continuation of a
/// mod-10 arithmetic digit progression; distractors perturb the step.
pub struct HellaswagX;

impl Task for HellaswagX {
    fn name(&self) -> &'static str {
        "hellaswag-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let start = rng.below(10) as u8;
        let step = 1 + rng.below(4) as u8;
        let digit = |i: u8| ((start + step * i) % 10 + b'0') as char;
        let ctx: String = (0..5).map(digit).collect();
        let good: String = (5..8).map(digit).collect();
        let mut cands = vec![good.clone()];
        while cands.len() < 4 {
            let d = 1 + rng.below(9) as u8;
            let alt: String = (5..8).map(|i| ((start + step * i + d) % 10 + b'0') as char).collect();
            if !cands.contains(&alt) {
                cands.push(alt);
            }
        }
        rng.shuffle(&mut cands[..]);
        let ans = cands.iter().position(|c| *c == good).unwrap();
        let refs: Vec<&str> = cands.iter().map(|s| s.as_str()).collect();
        Example::choice(&format!("H:{ctx}+>"), &refs, ans)
    }
}

/// WinoGrande analogue: coreference — context binds two letters to two
/// digits; the question asks which digit a letter was bound to.
pub struct WinograndeX;

impl Task for WinograndeX {
    fn name(&self) -> &'static str {
        "winogrande-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let x = b'a' + rng.below(6) as u8;
        let y = loop {
            let c = b'a' + rng.below(6) as u8;
            if c != x {
                break c;
            }
        };
        let dx = (b'1' + rng.below(9) as u8) as char;
        let dy = loop {
            let c = (b'1' + rng.below(9) as u8) as char;
            if c != dx {
                break c;
            }
        };
        let ask_x = rng.chance(0.5);
        let q = if ask_x { x } else { y };
        let sx = dx.to_string();
        let sy = dy.to_string();
        let ans = usize::from(!ask_x);
        Example::choice(
            &format!("W:{}{dx}{}{dy}|{}?>", x as char, y as char, q as char),
            &[&sx, &sy],
            ans,
        )
    }
}

/// ARC-easy analogue: the maximum of four digits (4-choice over the
/// digits themselves).
pub struct ArcEasyX;

impl Task for ArcEasyX {
    fn name(&self) -> &'static str {
        "arc-e-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let ds = distinct_digits(rng, 4);
        let max = *ds.iter().max().unwrap();
        let cands: Vec<String> = ds.iter().map(|d| d.to_string()).collect();
        let ans = ds.iter().position(|&d| d == max).unwrap();
        let refs: Vec<&str> = cands.iter().map(|s| s.as_str()).collect();
        let ctx: String = ds.iter().map(|d| std::char::from_digit(*d, 10).unwrap()).collect();
        Example::choice(&format!("E:{ctx}max>"), &refs, ans)
    }
}

/// ARC-challenge analogue: the *second*-largest of four digits — same
/// surface form as ARC-e but a harder induced rule.
pub struct ArcChallengeX;

impl Task for ArcChallengeX {
    fn name(&self) -> &'static str {
        "arc-c-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let ds = distinct_digits(rng, 4);
        let mut sorted = ds.clone();
        sorted.sort_unstable();
        let second = sorted[2];
        let cands: Vec<String> = ds.iter().map(|d| d.to_string()).collect();
        let ans = ds.iter().position(|&d| d == second).unwrap();
        let refs: Vec<&str> = cands.iter().map(|s| s.as_str()).collect();
        let ctx: String = ds.iter().map(|d| std::char::from_digit(*d, 10).unwrap()).collect();
        Example::choice(&format!("A:{ctx}2nd>"), &refs, ans)
    }
}

/// OBQA analogue: "open-book knowledge" — a fixed random fact table from
/// two-letter keys to a letter, baked at a constant seed ("the book").
/// Answering requires memorizing the table during finetuning, which is
/// what makes the task knowledge-intensive.
pub struct ObqaX;

impl ObqaX {
    /// The book: key (i, j) in 12x12 -> letter 'a'..'h', fixed forever.
    fn fact(i: usize, j: usize) -> u8 {
        let mut r = Rng::seed_from(0x0b9a + (i * 12 + j) as u64);
        b'a' + r.below(8) as u8
    }
}

impl Task for ObqaX {
    fn name(&self) -> &'static str {
        "obqa-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let i = rng.below(12);
        let j = rng.below(12);
        let gold = Self::fact(i, j);
        let mut cands = vec![gold];
        while cands.len() < 4 {
            let c = b'a' + rng.below(8) as u8;
            if !cands.contains(&c) {
                cands.push(c);
            }
        }
        rng.shuffle(&mut cands[..]);
        let ans = cands.iter().position(|&c| c == gold).unwrap();
        let strs: Vec<String> = cands.iter().map(|&c| (c as char).to_string()).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        let key = format!("{}{}", (b'k' + i as u8) as char, (b'k' + j as u8) as char);
        Example::choice(&format!("O:{key}?>"), &refs, ans)
    }
}

fn distinct_digits(rng: &mut Rng, n: usize) -> Vec<u32> {
    let mut pool: Vec<u32> = (0..10).collect();
    rng.shuffle(&mut pool);
    pool.truncate(n);
    pool
}

/// The eight tasks in Table-3 column order.
pub fn all() -> Vec<Box<dyn Task>> {
    vec![
        Box::new(BoolqX),
        Box::new(PiqaX),
        Box::new(SiqaX),
        Box::new(HellaswagX),
        Box::new(WinograndeX),
        Box::new(ArcEasyX),
        Box::new(ArcChallengeX),
        Box::new(ObqaX),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choices_contain_gold_and_are_distinct() {
        let mut rng = Rng::seed_from(21);
        for t in all() {
            for _ in 0..100 {
                let ex = t.sample(&mut rng);
                assert!(ex.choices.len() >= 2, "{}", t.name());
                assert_eq!(ex.choices[ex.answer], ex.completion, "{}", t.name());
                for i in 0..ex.choices.len() {
                    for j in i + 1..ex.choices.len() {
                        assert_ne!(ex.choices[i], ex.choices[j], "{} dup choice", t.name());
                    }
                }
            }
        }
    }

    #[test]
    fn obqa_facts_are_stable() {
        assert_eq!(ObqaX::fact(3, 7), ObqaX::fact(3, 7));
        // At least two different letters exist in the book.
        let letters: std::collections::BTreeSet<u8> =
            (0..12).flat_map(|i| (0..12).map(move |j| ObqaX::fact(i, j))).collect();
        assert!(letters.len() > 2);
    }

    #[test]
    fn hellaswag_gold_continues_progression() {
        let mut rng = Rng::seed_from(33);
        for _ in 0..100 {
            let ex = HellaswagX.sample(&mut rng);
            let ctx = crate::tokenizer::decode(&ex.prompt);
            let digits: Vec<u8> = ctx
                .trim_start_matches("H:")
                .trim_end_matches("+>")
                .bytes()
                .map(|b| b - b'0')
                .collect();
            let step = (10 + digits[1] - digits[0]) % 10;
            let next = (digits[4] + step) % 10;
            assert_eq!(ex.completion[0], (next + b'0') as i32);
        }
    }

    #[test]
    fn arc_answers_follow_rules() {
        let mut rng = Rng::seed_from(34);
        for _ in 0..100 {
            let e = ArcEasyX.sample(&mut rng);
            let ctx = crate::tokenizer::decode(&e.prompt);
            let ds: Vec<u32> = ctx
                .trim_start_matches("E:")
                .trim_end_matches("max>")
                .chars()
                .map(|c| c.to_digit(10).unwrap())
                .collect();
            let gold: u32 =
                crate::tokenizer::decode(&e.completion).parse().unwrap();
            assert_eq!(gold, *ds.iter().max().unwrap());
        }
    }
}
