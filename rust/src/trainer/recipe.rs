//! Training recipes: hyperparameters + the linear warmup/decay schedule
//! (paper Table C.2/C.5: AdamW, weight decay 0, warmup ratio 0.1, linear
//! scheduler).

/// Hyperparameters for one training run.
#[derive(Clone, Debug)]
pub struct Recipe {
    /// Peak learning rate (paper: RoAd prefers ~10x larger LRs, e.g. 3e-3).
    pub lr: f32,
    /// Total optimizer steps.
    pub steps: usize,
    /// Fraction of steps spent warming up linearly from 0 (paper: 0.1).
    pub warmup_ratio: f32,
    /// Workload RNG seed (three random runs in the paper's tables).
    pub seed: u64,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: usize,
    /// Print a log line every N steps (0 = silent).
    pub log_every: usize,
}

impl Default for Recipe {
    fn default() -> Self {
        Recipe {
            lr: 3e-3,
            steps: 200,
            warmup_ratio: 0.1,
            seed: 0,
            eval_every: 0,
            log_every: 0,
        }
    }
}

impl Recipe {
    pub fn with_lr(mut self, lr: f32) -> Recipe {
        self.lr = lr;
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Recipe {
        self.steps = steps;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Recipe {
        self.seed = seed;
        self
    }

    /// Learning rate for 0-indexed step `i`.
    pub fn lr_at(&self, i: usize) -> f32 {
        linear_lr(i, self.steps, self.warmup_ratio, self.lr)
    }

    /// Default per-method peak LRs (paper Table C.3: RoAd and (IA)³ prefer
    /// ~10x the LoRA LR because their adapters multiply instead of add).
    pub fn default_lr(method: &str) -> f32 {
        match method {
            m if m.starts_with("road") => 3e-3,
            "ia3" => 3e-3,
            "oft2" | "oft16" => 1e-3,
            "bitfit" => 1e-3,
            "lora" => 1e-3,
            "full" => 3e-4,
            _ => 1e-3,
        }
    }
}

/// Linear warmup to `peak` over `warmup_ratio * total` steps, then linear
/// decay to 0 at `total`.
pub fn linear_lr(step: usize, total: usize, warmup_ratio: f32, peak: f32) -> f32 {
    if total == 0 {
        return peak;
    }
    let warm = ((total as f32) * warmup_ratio).max(1.0);
    let s = step as f32;
    if s < warm {
        // Clamp: with fractional warm the last warmup step would overshoot.
        peak * ((s + 1.0) / warm).min(1.0)
    } else {
        let rest = (total as f32 - warm).max(1.0);
        peak * (1.0 - (s - warm) / rest).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let peak = 1.0;
        let total = 100;
        // warmup phase increases
        assert!(linear_lr(0, total, 0.1, peak) < linear_lr(5, total, 0.1, peak));
        // peak at end of warmup
        assert!((linear_lr(9, total, 0.1, peak) - peak).abs() < 1e-6);
        // decay phase decreases
        assert!(linear_lr(50, total, 0.1, peak) > linear_lr(90, total, 0.1, peak));
        // never negative
        assert!(linear_lr(99, total, 0.1, peak) >= 0.0);
    }

    #[test]
    fn zero_total_is_peak() {
        assert_eq!(linear_lr(0, 0, 0.1, 0.5), 0.5);
    }

    #[test]
    fn recipe_builders() {
        let r = Recipe::default().with_lr(0.01).with_steps(10).with_seed(3);
        assert_eq!(r.lr, 0.01);
        assert_eq!(r.steps, 10);
        assert_eq!(r.seed, 3);
        assert!(r.lr_at(0) > 0.0);
    }

    #[test]
    fn method_lrs_follow_paper_pattern() {
        // multiplicative adapters get the larger LR
        assert!(Recipe::default_lr("road1") > Recipe::default_lr("lora"));
        assert!(Recipe::default_lr("ia3") > Recipe::default_lr("full"));
    }
}
