//! Token sampling over a logits row (greedy / temperature / top-k).

use crate::util::rng::Rng;

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    // Collect candidate (index, logit) pairs, optionally top-k-truncated.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        // total_cmp: a NaN logit (bad adapter numerics) must not panic
        // the engine thread mid-sample — it takes a deterministic place
        // in the total order instead.
        idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        idx.truncate(top_k);
    }
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - max) / temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= total;
    }
    idx[rng.weighted(&probs)] as i32
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Log-softmax probability of `token` under `logits` (LL-judge, Table 5).
pub fn token_logprob(logits: &[f32], token: usize) -> f64 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f64 = logits.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln()
        + max as f64;
    logits[token] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::seed_from(0);
        assert_eq!(sample(&[0.1, 2.0, -1.0], 0.0, 0, &mut rng), 1);
    }

    #[test]
    fn temperature_respects_distribution() {
        let mut rng = Rng::seed_from(1);
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample(&logits, 1.0, 0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 150, "{hits}");
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::seed_from(2);
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        for _ in 0..100 {
            let t = sample(&logits, 2.0, 2, &mut rng);
            assert!(t == 2 || t == 3, "{t}");
        }
    }

    #[test]
    fn logprob_normalizes() {
        let logits = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| token_logprob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let logits = [1.0f32, 1.1, 0.9, 1.05];
        let a: Vec<i32> =
            (0..20).map(|_| sample(&logits, 0.8, 0, &mut Rng::seed_from(9))).collect();
        let b: Vec<i32> =
            (0..20).map(|_| sample(&logits, 0.8, 0, &mut Rng::seed_from(9))).collect();
        assert_eq!(a, b);
    }
}
