//! Markdown table rendering for experiment reports (Tables 2-6, D.1).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }
}

/// Render `(metric, value)` pairs as a two-column markdown table — the
/// serving stats presentation (`Metrics::report_table`).
pub fn kv_table(pairs: &[(&str, String)]) -> String {
    let mut t = Table::new(&["metric", "value"]);
    for (k, v) in pairs {
        t.row(vec![k.to_string(), v.clone()]);
    }
    t.render()
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Method", "Acc"]);
        t.row(vec!["road1".into(), "85.6".into()]);
        t.row(vec!["lora".into(), "84.7".into()]);
        let s = t.render();
        assert!(s.contains("| Method |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn kv_table_two_columns() {
        let s = kv_table(&[("bank hits", "12".to_string()), ("bank misses", "3".to_string())]);
        assert!(s.contains("| metric"), "{s}");
        assert!(s.contains("| bank hits"), "{s}");
        assert!(s.contains("| 12"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
