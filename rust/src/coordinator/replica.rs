//! Replica lifecycle for the multi-replica data plane: one [`Replica`]
//! wraps one engine's [`super::server::EngineClient`] with a typed
//! lifecycle state and a live load gauge.
//!
//! States advance monotonically — `Starting → Ready → Draining → Stopped`
//! — via a lock-free `fetch_max`, so a racing drain and shutdown can never
//! resurrect a replica.  A `Draining` replica keeps serving its in-flight
//! lanes (its engine thread and event streams stay live) but the router's
//! placement layer stops sending it new admissions; `Stopped` means the
//! engine thread is gone and every client call answers
//! `EngineError::EngineStopped`.
//!
//! Load is the number of outstanding routed requests, tracked by RAII
//! [`LoadGuard`]s: the router takes a guard per submission and parks it in
//! the returned generation handle, so both normal completion and the
//! drop-cancel path release the gauge without bookkeeping.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

use super::server::EngineClient;

/// Lifecycle state of one engine replica.  Ordered: transitions only move
/// rightward ([`Replica::advance_to`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ReplicaState {
    /// Engine thread is being constructed; not yet routable.
    Starting,
    /// Serving: the placement layer may route new admissions here.
    Ready,
    /// Finishing in-flight work; receives no new admissions.
    Draining,
    /// Engine thread is gone; every client call answers `EngineStopped`.
    Stopped,
}

impl ReplicaState {
    /// Stable wire / report name (the `state` field of fleet stats).
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaState::Starting => "starting",
            ReplicaState::Ready => "ready",
            ReplicaState::Draining => "draining",
            ReplicaState::Stopped => "stopped",
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            ReplicaState::Starting => 0,
            ReplicaState::Ready => 1,
            ReplicaState::Draining => 2,
            ReplicaState::Stopped => 3,
        }
    }

    fn from_u8(v: u8) -> ReplicaState {
        match v {
            0 => ReplicaState::Starting,
            1 => ReplicaState::Ready,
            2 => ReplicaState::Draining,
            _ => ReplicaState::Stopped,
        }
    }
}

/// One engine replica as the router sees it: the client handle plus the
/// shared lifecycle/load cells every router clone reads.
pub struct Replica {
    id: usize,
    client: EngineClient,
    state: Arc<AtomicU8>,
    load: Arc<AtomicUsize>,
}

impl Replica {
    /// Wrap a started engine's client; the replica begins `Starting` and
    /// the fleet advances it to `Ready` once construction succeeded.
    pub fn new(id: usize, client: EngineClient) -> Replica {
        Replica {
            id,
            client,
            state: Arc::new(AtomicU8::new(ReplicaState::Starting.as_u8())),
            load: Arc::new(AtomicUsize::new(0)),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn client(&self) -> &EngineClient {
        &self.client
    }

    pub fn state(&self) -> ReplicaState {
        ReplicaState::from_u8(self.state.load(Ordering::Acquire))
    }

    /// Routable right now (exactly `Ready`; `Draining` and `Stopped`
    /// replicas receive no new admissions).
    pub fn is_ready(&self) -> bool {
        self.state() == ReplicaState::Ready
    }

    /// Advance the lifecycle — monotone: a `fetch_max` on the state cell,
    /// so moving "backward" (e.g. `Ready` after `Draining`) is a no-op and
    /// concurrent transitions settle at the furthest state.
    pub fn advance_to(&self, s: ReplicaState) {
        self.state.fetch_max(s.as_u8(), Ordering::AcqRel);
    }

    /// Outstanding routed requests (admitted or queued on this replica's
    /// engine; live [`LoadGuard`] count).
    pub fn load(&self) -> usize {
        self.load.load(Ordering::Acquire)
    }

    /// Count one outstanding request until the guard drops.
    pub fn load_guard(&self) -> LoadGuard {
        self.load.fetch_add(1, Ordering::AcqRel);
        LoadGuard { load: Arc::clone(&self.load) }
    }

    /// Point-in-time health view (the `replicas[]` rows of fleet stats).
    pub fn health(&self) -> ReplicaHealth {
        ReplicaHealth { id: self.id, state: self.state(), load: self.load() }
    }
}

/// One replica's health row: id, lifecycle state, outstanding load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaHealth {
    pub id: usize,
    pub state: ReplicaState,
    pub load: usize,
}

/// RAII load token: one outstanding request on one replica.  Created by
/// [`Replica::load_guard`] at submission; the router parks it inside the
/// returned generation handle so every terminal path — finished stream,
/// explicit cancel, or a dropped handle — releases the gauge.
pub struct LoadGuard {
    load: Arc<AtomicUsize>,
}

impl Drop for LoadGuard {
    fn drop(&mut self) {
        // Saturating: a release can never underflow the gauge even if a
        // guard outlives a reset elsewhere.
        let _ = self
            .load
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| Some(v.saturating_sub(1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bare_replica(id: usize) -> Replica {
        // A client whose engine thread never existed: good enough for
        // lifecycle/load tests (no command is sent).
        Replica::new(id, EngineClient::disconnected())
    }

    #[test]
    fn lifecycle_is_monotone() {
        let r = bare_replica(0);
        assert_eq!(r.state(), ReplicaState::Starting);
        assert!(!r.is_ready());
        r.advance_to(ReplicaState::Ready);
        assert!(r.is_ready());
        r.advance_to(ReplicaState::Draining);
        assert_eq!(r.state(), ReplicaState::Draining);
        // Backward transitions are no-ops.
        r.advance_to(ReplicaState::Ready);
        assert_eq!(r.state(), ReplicaState::Draining, "drain cannot be undone by ready");
        r.advance_to(ReplicaState::Stopped);
        r.advance_to(ReplicaState::Draining);
        assert_eq!(r.state(), ReplicaState::Stopped, "stopped is terminal");
    }

    #[test]
    fn load_guards_count_and_release_on_drop() {
        let r = bare_replica(1);
        assert_eq!(r.load(), 0);
        let g1 = r.load_guard();
        let g2 = r.load_guard();
        assert_eq!(r.load(), 2);
        drop(g1);
        assert_eq!(r.load(), 1);
        assert_eq!(r.health(), ReplicaHealth { id: 1, state: ReplicaState::Starting, load: 1 });
        drop(g2);
        assert_eq!(r.load(), 0);
    }

    #[test]
    fn state_names_are_stable_wire_strings() {
        for (s, name) in [
            (ReplicaState::Starting, "starting"),
            (ReplicaState::Ready, "ready"),
            (ReplicaState::Draining, "draining"),
            (ReplicaState::Stopped, "stopped"),
        ] {
            assert_eq!(s.as_str(), name);
            assert_eq!(ReplicaState::from_u8(s.as_u8()), s);
        }
    }
}
