"""Layer-2 training graphs: fwd + bwd + AdamW step per PEFT method.

Each method is lowered to a single HLO `train_step` that the rust trainer
drives in a loop (python never runs at training time either — training is
part of the reproduced system, Tables 2-6 / Fig 2 / Fig 5 / Tab D.1).

Methods
-------
  full      — full finetuning (all parameters trainable)
  road1/2/4 — the paper's contribution (Table 1 variants); trainables are
              theta/alpha per adapted projection, mapped to effective
              (R1, R2) vectors by kernels.ref.road_vectors_* and applied
              through the Layer-1 element-wise kernel
  road1_fc1 — RoAd_1 on the first feed-forward layer only (Table 2 row)
  lora      — LoRA rank cfg.lora_rank on every linear
  ia3       — (IA)^3 scaling vectors
  bitfit    — biases (+ norm scales) only
  oft2/oft16— OFT with Cayley parameterization, block size w (Tab D.1
              baseline: matrix solves in the step graph)
  road1_masked — RoAd_1 with a per-block gradient mask, used by the
              composability experiment (Fig 5) to train disjoint subspaces
              of R on different tasks simultaneously.

The optimizer is AdamW (paper Tab C.2: weight decay 0), with bias
correction; `lr` is a runtime input so the rust side owns the schedule.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PROJS, proj_dims
from . import model
from .kernels import ref as kref

METHODS = ("full", "road1", "road2", "road4", "road1_fc1", "lora", "ia3",
           "bitfit", "oft2", "oft16", "road1_masked")

FC1_PROJS = ("wgate", "wup")  # "first feed-forward layer" analogue


def method_projs(method: str):
    return FC1_PROJS if method == "road1_fc1" else PROJS


def oft_block_w(method: str) -> int:
    return {"oft2": 2, "oft16": 16}[method]


# ---------------------------------------------------------------------------
# Trainable parameter initialization per method
# ---------------------------------------------------------------------------

def init_trainable(cfg: ModelConfig, method: str, key, params=None) -> dict:
    """Identity-preserving init (theta=0, alpha=1, la=0, q=0, s=1)."""
    t = {}
    if method == "full":
        assert params is not None
        return dict(params)
    if method == "bitfit":
        assert params is not None
        for k in params:
            if k.endswith(".bias") or k.endswith("norm"):
                t[k] = params[k]
        return t
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        for proj in method_projs(method):
            d_in, d_out = proj_dims(cfg, proj)
            nm = f"{pre}.{proj}"
            if method in ("road1", "road1_fc1", "road1_masked"):
                t[f"{nm}.theta"] = jnp.zeros((d_out // 2,))
                t[f"{nm}.alpha"] = jnp.ones((d_out // 2,))
            elif method == "road2":
                t[f"{nm}.theta"] = jnp.zeros((d_out // 2, 2))
                t[f"{nm}.alpha"] = jnp.ones((d_out // 2, 2))
            elif method == "road4":
                t[f"{nm}.theta"] = jnp.zeros((d_out // 2, 4))
                t[f"{nm}.alpha"] = jnp.ones((d_out // 2, 4))
            elif method == "lora":
                key, sub = jax.random.split(key)
                t[f"{nm}.lb"] = jax.random.normal(sub, (d_in, cfg.lora_rank)) * (d_in ** -0.5)
                t[f"{nm}.la"] = jnp.zeros((cfg.lora_rank, d_out))
            elif method == "ia3":
                t[f"{nm}.s"] = jnp.ones((d_out,))
            elif method in ("oft2", "oft16"):
                w = oft_block_w(method)
                t[f"{nm}.q"] = jnp.zeros((d_out // w, w, w))
            else:
                raise ValueError(method)
    return t


def trainable_specs(cfg: ModelConfig, method: str):
    p = model.init_params(cfg, jax.random.PRNGKey(0)) \
        if method in ("full", "bitfit") else None
    t = init_trainable(cfg, method, jax.random.PRNGKey(0), p)
    return [(k, tuple(t[k].shape)) for k in sorted(t)]


def n_trainable(cfg: ModelConfig, method: str) -> int:
    return sum(
        int(jnp.prod(jnp.array(s))) for _, s in trainable_specs(cfg, method))


# ---------------------------------------------------------------------------
# Method -> forward mapping
# ---------------------------------------------------------------------------

def road_variant(method: str) -> int:
    return {"road1": 1, "road1_fc1": 1, "road1_masked": 1,
            "road2": 2, "road4": 4}[method]


def build_forward_inputs(cfg: ModelConfig, method: str, params: dict,
                         trainable: dict):
    """Map (frozen params, trainable) -> (eff_params, mode, adapters, oft_w).

    Adapter banks get n=1 rows; ids are all-zero at train time.
    """
    if method == "full":
        return trainable, "base", {}, 2
    if method == "bitfit":
        eff = dict(params)
        eff.update(trainable)
        return eff, "base", {}, 2
    adapters = {}
    if method.startswith("road"):
        var = road_variant(method)
        vec = kref.ROAD_VECTOR_FNS[var]
        # Projections NOT adapted by this method keep identity banks.
        for i in range(cfg.n_layers):
            pre = f"blocks.{i}"
            for proj in PROJS:
                _, d_out = proj_dims(cfg, proj)
                nm = f"{pre}.{proj}"
                if f"{nm}.theta" in trainable:
                    r1, r2 = vec(trainable[f"{nm}.theta"], trainable[f"{nm}.alpha"])
                else:
                    r1 = jnp.ones((d_out,))
                    r2 = jnp.zeros((d_out,))
                adapters[f"{nm}.r1"] = r1[None]
                adapters[f"{nm}.r2"] = r2[None]
        return params, "road", adapters, 2
    if method == "lora":
        for k, a in trainable.items():
            adapters[k] = a[None]
        return params, "lora", adapters, 2
    if method == "ia3":
        for k, a in trainable.items():
            adapters[k] = a[None]
        return params, "ia3", adapters, 2
    if method in ("oft2", "oft16"):
        for k, a in trainable.items():
            adapters[k] = a[None]
        return params, "oft", adapters, oft_block_w(method)
    raise ValueError(method)


# ---------------------------------------------------------------------------
# Loss / AdamW
# ---------------------------------------------------------------------------

def masked_nll(logits, targets, mask):
    """Per-example mean negative log-likelihood of `targets` under `logits`.

    logits [B, L, V]; targets [B, L] int32; mask [B, L] float (1 = counted).
    Returns ([B] per-example nll, scalar mean over counted tokens).
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    per_ex = -(tgt * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    total = -(tgt * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return per_ex, total


def adamw_update(g, p, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v


# ---------------------------------------------------------------------------
# Entry points lowered by aot.py
# ---------------------------------------------------------------------------

def train_step(cfg: ModelConfig, method: str, frozen: dict, trainable: dict,
               m: dict, v: dict, step, lr, tokens, targets, mask,
               grad_mask: dict | None = None):
    """One AdamW step.  Returns (trainable', m', v', loss).

    grad_mask (road1_masked only): dict with the same keys as trainable,
    multiplying gradients element-wise — this is how the composability
    experiment trains disjoint halves of R on different tasks.
    """

    def loss_fn(tr):
        eff, mode, adapters, oft_w = build_forward_inputs(cfg, method, frozen, tr)
        ids = jnp.zeros((tokens.shape[0],), dtype=jnp.int32)
        logits = model.full_forward(cfg, mode, eff, adapters, ids, tokens,
                                    oft_w=oft_w, use_kernels=False)
        _, total = masked_nll(logits, targets, mask)
        return total

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    if grad_mask is not None:
        grads = {k: g * grad_mask[k] for k, g in grads.items()}
    new_t, new_m, new_v = {}, {}, {}
    for k in trainable:
        new_t[k], new_m[k], new_v[k] = adamw_update(
            grads[k], trainable[k], m[k], v[k], step, lr)
    return new_t, new_m, new_v, loss


def eval_loss(cfg: ModelConfig, method: str, frozen: dict, trainable: dict,
              tokens, targets, mask):
    """Per-example + mean NLL with the method's trainables applied."""
    eff, mode, adapters, oft_w = build_forward_inputs(cfg, method, frozen,
                                                      trainable)
    ids = jnp.zeros((tokens.shape[0],), dtype=jnp.int32)
    logits = model.full_forward(cfg, mode, eff, adapters, ids, tokens,
                                oft_w=oft_w)
    per_ex, total = masked_nll(logits, targets, mask)
    return per_ex, total


def last_logits(cfg: ModelConfig, method: str, frozen: dict, trainable: dict,
                tokens, lengths):
    """Logits at the last valid position (classification eval path)."""
    eff, mode, adapters, oft_w = build_forward_inputs(cfg, method, frozen,
                                                      trainable)
    b, l = tokens.shape
    ids = jnp.zeros((b,), dtype=jnp.int32)
    logits = model.full_forward(cfg, mode, eff, adapters, ids, tokens,
                                oft_w=oft_w)
    last = jnp.clip(lengths - 1, 0, l - 1).astype(jnp.int32)
    return jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Disentanglement head (pilot study 2, Fig 2 Right)
# ---------------------------------------------------------------------------

HEAD_MODES = ("normal", "mag", "angle")


def head_init(d: int, n_classes: int, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, d)) * (d ** -0.5),
        "b1": jnp.zeros((d,)),
        "w2": jax.random.normal(k2, (d, n_classes)) * (d ** -0.5),
        "b2": jnp.zeros((n_classes,)),
    }


def head_forward(head: dict, reps, head_mode: str):
    """Two-layer classifier over frozen-backbone representations.

    First layer per the paper's disentanglement protocol:
      normal: z = x @ W1
      mag:    z_i = ||W1[:, i]|| * ||x||         (magnitude only)
      angle:  z_i = cos(W1[:, i], x)             (angle only)
    """
    x = reps  # [B, D]
    w1 = head["w1"]
    if head_mode == "normal":
        z = x @ w1 + head["b1"]
    elif head_mode == "mag":
        wn = jnp.linalg.norm(w1, axis=0)          # [D]
        xn = jnp.linalg.norm(x, axis=-1, keepdims=True)
        z = wn[None, :] * xn + head["b1"]
    elif head_mode == "angle":
        wn = jnp.maximum(jnp.linalg.norm(w1, axis=0), 1e-6)
        xn = jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
        z = (x @ w1) / (wn[None, :] * xn) + head["b1"]
    else:
        raise ValueError(head_mode)
    h = jax.nn.relu(z)
    return h @ head["w2"] + head["b2"]


def head_train_step(head: dict, m: dict, v: dict, step, lr, reps, labels,
                    head_mode: str):
    def loss_fn(hd):
        logits = head_forward(hd, reps, head_mode)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                                   axis=-1)[:, 0]
        return nll.mean()

    loss, grads = jax.value_and_grad(loss_fn)(head)
    nh, nm, nv = {}, {}, {}
    for k in head:
        nh[k], nm[k], nv[k] = adamw_update(grads[k], head[k], m[k], v[k],
                                           step, lr)
    return nh, nm, nv, loss


def head_logits(head: dict, reps, head_mode: str):
    return head_forward(head, reps, head_mode)
