//! Bounded admission queue with backpressure (the front door of the
//! coordinator).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;
use std::time::Instant;

use super::request::Request;

/// Typed engine-level errors: the only error type that crosses the
/// client↔engine channel boundary, and the payload of
/// [`super::request::StreamEvent::Error`].
///
/// Callers match on variants (or `e.downcast_ref::<EngineError>()` when the
/// error rides inside an `anyhow::Error`), never on rendered strings.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The admission queue is at capacity; retry later or shed load.
    QueueFull { waiting: usize },
    /// The request names an adapter the host store has never seen (or no
    /// longer holds).  Register it first.
    AdapterNotFound { name: String },
    /// The request's deadline passed before it finished; it was shed from
    /// the queue or reaped from its decode slot.
    DeadlineExceeded,
    /// The request was cancelled (explicitly or by a dropped
    /// [`super::server::Generation`] handle).
    Cancelled,
    /// The engine thread is shutting down or gone; no further requests are
    /// accepted and in-flight streams end with this error.
    EngineStopped,
    /// The request (or adapter operation) failed validation; `reason` is
    /// human-readable context, not a matching surface.
    Invalid { reason: String },
    /// The engine broke one of its own invariants while handling the
    /// request (e.g. admission popped a request whose KV reservation went
    /// missing).  Surfaced as a terminal stream event instead of silently
    /// dropping the request; `reason` is diagnostic context, not a
    /// matching surface.
    Internal { reason: String },
}

impl EngineError {
    /// Stable wire name for the NDJSON protocol (docs/DESIGN.md
    /// §Streaming protocol).
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::QueueFull { .. } => "queue_full",
            EngineError::AdapterNotFound { .. } => "adapter_not_found",
            EngineError::DeadlineExceeded => "deadline_exceeded",
            EngineError::Cancelled => "cancelled",
            EngineError::EngineStopped => "engine_stopped",
            EngineError::Invalid { .. } => "invalid",
            EngineError::Internal { .. } => "internal",
        }
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::QueueFull { waiting } => {
                write!(f, "admission queue full ({waiting} waiting); backpressure")
            }
            EngineError::AdapterNotFound { name } => {
                write!(f, "unknown adapter {name:?} (register it first)")
            }
            EngineError::DeadlineExceeded => write!(f, "deadline exceeded"),
            EngineError::Cancelled => write!(f, "request cancelled"),
            EngineError::EngineStopped => write!(f, "engine stopped"),
            EngineError::Invalid { reason } => write!(f, "invalid request: {reason}"),
            EngineError::Internal { reason } => {
                write!(f, "internal engine error: {reason}")
            }
        }
    }
}

impl std::error::Error for EngineError {}

pub struct AdmissionQueue {
    q: VecDeque<Request>,
    capacity: usize,
    pub admitted: usize,
    pub rejected: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue { q: VecDeque::new(), capacity, admitted: 0, rejected: 0 }
    }

    /// Admit a request; returns the typed [`EngineError::QueueFull`] when
    /// the queue is at capacity (the caller is expected to retry or shed
    /// load).
    pub fn push(&mut self, r: Request) -> Result<(), EngineError> {
        if self.q.len() >= self.capacity {
            self.rejected += 1;
            return Err(EngineError::QueueFull { waiting: self.q.len() });
        }
        self.admitted += 1;
        self.q.push_back(r);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }

    /// Pop up to n requests whose prompt length fits `max_len`.
    /// FIFO order is preserved among the selected; skipped requests keep
    /// their place (no starvation: longer prompts are handled by the bigger
    /// prefill bucket on a later iteration).
    pub fn pop_fitting(&mut self, n: usize, max_len: usize) -> Vec<Request> {
        self.pop_admissible(n, max_len, |_| true)
    }

    /// Like [`AdmissionQueue::pop_fitting`], but a request is only taken
    /// when `admit` also accepts it — the engine's hook for gating
    /// admission on adapter residency (paging the adapter in is a side
    /// effect of the predicate).  `admit` is called once per candidate
    /// that already fits the length/count limits, in FIFO order; rejected
    /// requests keep their queue position for a later scheduler step.
    pub fn pop_admissible(
        &mut self,
        n: usize,
        max_len: usize,
        admit: impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        let order: Vec<usize> = (0..self.q.len()).collect();
        self.pop_scheduled(&order, n, max_len, admit)
    }

    /// The scheduler-policy hook: like [`AdmissionQueue::pop_admissible`],
    /// but candidates are considered in the order given by `order` (queue
    /// indices, best first — a [`super::sched::SchedPolicy`] ranking)
    /// instead of FIFO.  `admit` is called once per in-bounds candidate
    /// that fits the length/count limits, in ranking order; requests not
    /// taken keep their original FIFO positions.  Out-of-range or
    /// duplicate indices are skipped, so a stale ranking degrades to
    /// admitting less, never to corruption.  The identity ranking makes
    /// this exactly `pop_admissible` — FCFS is the degenerate policy.
    pub fn pop_scheduled(
        &mut self,
        order: &[usize],
        n: usize,
        max_len: usize,
        mut admit: impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        let mut taken_idx: Vec<usize> = Vec::new();
        for &i in order {
            if taken_idx.len() >= n {
                break;
            }
            let Some(r) = self.q.get(i) else { continue };
            if taken_idx.contains(&i) {
                continue;
            }
            if r.prompt.len() <= max_len && admit(r) {
                taken_idx.push(i);
            }
        }
        if taken_idx.is_empty() {
            return Vec::new();
        }
        let marked: BTreeSet<usize> = taken_idx.iter().copied().collect();
        let mut by_idx: BTreeMap<usize, Request> = BTreeMap::new();
        let mut keep = VecDeque::with_capacity(self.q.len() - marked.len());
        for (i, r) in self.q.drain(..).enumerate() {
            if marked.contains(&i) {
                by_idx.insert(i, r);
            } else {
                keep.push_back(r);
            }
        }
        self.q = keep;
        // Every marked index was drained into `by_idx` above, so each
        // remove hits; filter_map keeps a lost invariant from panicking
        // the serving thread, and the conservation debug_assert below
        // keeps it loud where tests run.
        let taken: Vec<Request> =
            taken_idx.into_iter().filter_map(|i| by_idx.remove(&i)).collect();
        debug_assert!(by_idx.is_empty(), "pop_scheduled dropped a drained request");
        taken
    }

    /// Iterate the waiting requests in FIFO order (index 0 = queue front).
    /// Scheduler policies rank the queue through this view.
    pub fn iter(&self) -> std::collections::vec_deque::Iter<'_, Request> {
        self.q.iter()
    }

    /// Remove a waiting request by id (cancellation before admission).
    /// Returns the request so the caller can synthesize its terminal event.
    pub fn cancel(&mut self, id: u64) -> Option<Request> {
        let idx = self.q.iter().position(|r| r.id == id)?;
        self.q.remove(idx)
    }

    /// Remove every waiting request whose deadline has passed — the
    /// admission-time shed that keeps expired work from ever occupying a
    /// decode slot.  FIFO order among survivors is preserved.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<Request> {
        let mut shed = Vec::new();
        self.q.retain(|r| {
            if r.expired(now) {
                shed.push(r.clone());
                false
            } else {
                true
            }
        });
        shed
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Any waiting request referencing this adapter?  (Unregistering an
    /// adapter with queued work is rejected to keep admission live.)
    pub fn contains_adapter(&self, name: &str) -> bool {
        self.q.iter().any(|r| r.adapter.as_deref() == Some(name))
    }

    pub fn max_prompt_len(&self) -> usize {
        self.q.iter().map(|r| r.prompt.len()).max().unwrap_or(0)
    }

    pub fn min_prompt_len(&self) -> usize {
        self.q.iter().map(|r| r.prompt.len()).min().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize) -> Request {
        // Ids are engine-issued in production; unit tests stamp them
        // directly to exercise the queue in isolation.
        let mut r = Request::new(vec![1; plen], 4);
        r.id = id;
        r
    }

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::new(10);
        q.push(req(1, 3)).unwrap();
        q.push(req(2, 3)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn backpressure_at_capacity_is_typed() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(1, 1)).unwrap();
        q.push(req(2, 1)).unwrap();
        let err = q.push(req(3, 1)).unwrap_err();
        assert_eq!(err, EngineError::QueueFull { waiting: 2 });
        assert_eq!(q.rejected, 1);
        q.pop();
        q.push(req(3, 1)).unwrap();
    }

    #[test]
    fn queue_full_downcasts_through_anyhow() {
        let mut q = AdmissionQueue::new(1);
        q.push(req(1, 1)).unwrap();
        let any: anyhow::Error = q.push(req(2, 1)).unwrap_err().into();
        match any.downcast_ref::<EngineError>() {
            Some(EngineError::QueueFull { waiting }) => assert_eq!(*waiting, 1),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // The rendered message still names backpressure for humans.
        assert!(any.to_string().contains("backpressure"), "{any}");
    }

    #[test]
    fn pop_fitting_preserves_skipped() {
        let mut q = AdmissionQueue::new(10);
        q.push(req(1, 20)).unwrap(); // too long for bucket
        q.push(req(2, 4)).unwrap();
        q.push(req(3, 4)).unwrap();
        let taken = q.pop_fitting(2, 16);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn pop_admissible_skips_rejected_but_keeps_them_queued() {
        let mut q = AdmissionQueue::new(10);
        for i in 1..=5 {
            q.push(req(i, 4)).unwrap();
        }
        // Reject odd ids (e.g. "adapter not pageable right now").
        let taken = q.pop_admissible(10, 16, |r| r.id % 2 == 0);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert_eq!(q.len(), 3, "rejected requests stay queued");
        assert_eq!(q.pop().unwrap().id, 1, "FIFO order preserved among kept");
    }

    #[test]
    fn pop_admissible_stops_calling_predicate_at_n() {
        let mut q = AdmissionQueue::new(10);
        for i in 1..=4 {
            q.push(req(i, 2)).unwrap();
        }
        let mut calls = 0;
        let taken = q.pop_admissible(2, 16, |_| {
            calls += 1;
            true
        });
        assert_eq!(taken.len(), 2);
        assert_eq!(calls, 2, "predicate (and its paging side effects) not run past n");
    }

    #[test]
    fn cancel_removes_by_id_and_preserves_order() {
        let mut q = AdmissionQueue::new(10);
        for i in 1..=4 {
            q.push(req(i, 2)).unwrap();
        }
        let cancelled = q.cancel(2).expect("queued request is cancellable");
        assert_eq!(cancelled.id, 2);
        assert!(q.cancel(2).is_none(), "second cancel is a no-op");
        assert!(q.cancel(99).is_none(), "unknown id is a no-op");
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 3, 4]);
    }

    #[test]
    fn shed_expired_drops_only_past_deadline() {
        use std::time::{Duration, Instant};
        let now = Instant::now();
        let stamp = |mut r: Request, deadline: Option<Duration>| {
            r.submitted_at = Some(now - Duration::from_millis(10));
            r.deadline = deadline;
            r
        };
        let mut q = AdmissionQueue::new(10);
        q.push(stamp(req(1, 2), Some(Duration::from_millis(1)))).unwrap();
        q.push(stamp(req(2, 2), None)).unwrap();
        q.push(stamp(req(3, 2), Some(Duration::from_secs(60)))).unwrap();
        q.push(stamp(req(4, 2), Some(Duration::ZERO))).unwrap();
        let shed: Vec<u64> = q.shed_expired(now).iter().map(|r| r.id).collect();
        assert_eq!(shed, vec![1, 4]);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![2, 3], "unexpired requests keep FIFO order");
    }

    #[test]
    fn error_kinds_are_stable_wire_names() {
        assert_eq!(EngineError::QueueFull { waiting: 1 }.kind(), "queue_full");
        assert_eq!(
            EngineError::AdapterNotFound { name: "x".into() }.kind(),
            "adapter_not_found"
        );
        assert_eq!(EngineError::DeadlineExceeded.kind(), "deadline_exceeded");
        assert_eq!(EngineError::Cancelled.kind(), "cancelled");
        assert_eq!(EngineError::EngineStopped.kind(), "engine_stopped");
        assert_eq!(EngineError::Invalid { reason: "r".into() }.kind(), "invalid");
        assert_eq!(EngineError::Internal { reason: "r".into() }.kind(), "internal");
    }

    #[test]
    fn pop_scheduled_takes_in_ranking_order_and_keeps_fifo_among_rest() {
        let mut q = AdmissionQueue::new(10);
        for i in 1..=5 {
            q.push(req(i, 4)).unwrap();
        }
        // Ranking prefers the back of the queue (indices 4, 2, 0 first).
        let taken = q.pop_scheduled(&[4, 2, 0, 1, 3], 2, 16, |_| true);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![5, 3]);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 2, 4], "untaken requests keep FIFO order");
    }

    #[test]
    fn pop_scheduled_identity_ranking_equals_pop_admissible() {
        let mk = || {
            let mut q = AdmissionQueue::new(20);
            for i in 1..=8 {
                q.push(req(i, (i as usize % 3) * 8 + 2)).unwrap();
            }
            q
        };
        let mut a = mk();
        let mut b = mk();
        let order: Vec<usize> = (0..b.len()).collect();
        let pred = |r: &Request| r.id % 3 != 0;
        let via_admissible: Vec<u64> =
            a.pop_admissible(3, 16, pred).iter().map(|r| r.id).collect();
        let via_scheduled: Vec<u64> =
            b.pop_scheduled(&order, 3, 16, pred).iter().map(|r| r.id).collect();
        assert_eq!(via_admissible, via_scheduled);
        let rest_a: Vec<u64> = std::iter::from_fn(|| a.pop()).map(|r| r.id).collect();
        let rest_b: Vec<u64> = std::iter::from_fn(|| b.pop()).map(|r| r.id).collect();
        assert_eq!(rest_a, rest_b, "residual queues identical too");
    }

    #[test]
    fn pop_scheduled_tolerates_stale_or_duplicate_indices() {
        let mut q = AdmissionQueue::new(10);
        for i in 1..=3 {
            q.push(req(i, 2)).unwrap();
        }
        // Out-of-range and duplicate entries are skipped, not a panic.
        let taken = q.pop_scheduled(&[7, 1, 1, 99, 0], 5, 16, |_| true);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 1]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }

    #[test]
    fn pop_fitting_respects_n() {
        let mut q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.push(req(i, 2)).unwrap();
        }
        let taken = q.pop_fitting(3, 16);
        assert_eq!(taken.len(), 3);
        assert_eq!(q.len(), 2);
    }
}
