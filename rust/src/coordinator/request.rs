//! Request/response types for the multi-adapter serving engine.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// 0.0 => greedy decoding.
    pub temperature: f32,
    /// 0 => no top-k truncation.
    pub top_k: usize,
    pub seed: u64,
    /// Stop early when this token is produced (it is not emitted).
    pub stop_token: Option<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_token: None }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Registered adapter name; None = base model (identity slot 0).
    pub adapter: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// Stamped by `Engine::submit` at enqueue time and carried through the
    /// admission queue so TTFT/e2e include queueing delay.  `None` until
    /// submitted.
    pub submitted_at: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            adapter: None,
            prompt,
            max_new_tokens,
            sampling: Default::default(),
            submitted_at: None,
        }
    }

    pub fn with_adapter(mut self, name: &str) -> Request {
        self.adapter = Some(name.to_string());
        self
    }

    pub fn with_sampling(mut self, s: SamplingParams) -> Request {
        self.sampling = s;
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    Cancelled,
}

#[derive(Clone, Debug)]
pub struct RequestOutput {
    pub id: u64,
    pub adapter: Option<String>,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token (seconds).
    pub ttft: f64,
    /// End-to-end latency (seconds).
    pub e2e: f64,
}

/// In-flight request state pinned to a decode slot.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    pub slot_adapter: usize,
    pub generated: Vec<i32>,
    pub pos: usize,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    pub rng_state: crate::util::rng::Rng,
}

impl ActiveRequest {
    /// `admitted` is when the scheduler pulled the request into a prefill
    /// batch; `submitted` is taken from the request's submit stamp when
    /// present, so latency metrics start the clock at the front door
    /// (queue wait included), not at admission.
    pub fn new(req: Request, slot_adapter: usize, admitted: Instant) -> ActiveRequest {
        let seed = req.sampling.seed ^ req.id.wrapping_mul(0x9e3779b97f4a7c15);
        ActiveRequest {
            slot_adapter,
            pos: req.prompt.len(),
            generated: Vec::with_capacity(req.max_new_tokens),
            submitted: req.submitted_at.unwrap_or(admitted),
            first_token_at: None,
            rng_state: crate::util::rng::Rng::seed_from(seed),
            req,
        }
    }

    pub fn done(&self) -> Option<FinishReason> {
        if let (Some(stop), Some(&last)) = (self.req.sampling.stop_token, self.generated.last()) {
            if last == stop {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.req.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }
}
