pub fn plan_chunk(budget: Option<usize>) -> usize {
    budget.unwrap()
}

pub fn grant(remaining: &[usize], lane: usize) -> usize {
    *remaining.get(lane).expect("lane has a feeding prompt")
}

pub fn assemble(tokens: &[i32], start: usize, n: usize) {
    if start + n > tokens.len() {
        panic!("chunk {start}+{n} overruns the prompt");
    }
}

pub fn spend(budget: usize, granted: usize) {
    if granted > budget {
        unreachable!("plan granted more than the step budget");
    }
}

pub fn shared_plan(m: &std::sync::Mutex<usize>) -> usize {
    *m.lock().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        Some(1usize).unwrap();
    }
}
