"""Layer-1 Pallas kernels for RoAd (Eq. 4 of the paper).

The compute hot-spot of the serving path is the per-request adapter
application inside every linear layer:

    z = R1_i (*) h  +  R2_i (*) pairswap(h)        (request i's adapter)

TPU mapping (DESIGN.md §Hardware-Adaptation): this is a pure VPU
(vector-unit) op — no MXU involvement — which is the TPU restatement of the
paper's "element-wise instead of bmm" claim.  The grid tiles [batch x
sequence] and BlockSpec streams [TL, d] tiles of h through VMEM together
with the request's two [d] adapter vectors; the pair-swap is a lane-local
even/odd de-interleave (reshape to [TL, d/2, 2]), so the whole kernel is one
fused multiply-add pass over the tile.

Pallas runs with interpret=True on this CPU image: real-TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute.  Correctness is
validated against kernels/ref.py under pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _road_tile_kernel(h_ref, r1_ref, r2_ref, o_ref):
    """One [1, TL, d] tile: z = r1*h + r2*pairswap(h).

    r1_ref / r2_ref are the [1, d] adapter vectors already gathered for this
    batch row (gather hoisted out of the inner loop — see road_batched_apply).
    """
    h = h_ref[...]                       # [1, TL, d]
    r1 = r1_ref[...][:, None, :]         # [1, 1, d]
    r2 = r2_ref[...][:, None, :]
    one, tl, d = h.shape
    hp = h.reshape(one, tl, d // 2, 2)
    hhat = jnp.stack([-hp[..., 1], hp[..., 0]], axis=-1).reshape(one, tl, d)
    o_ref[...] = r1 * h + r2 * hhat


def _pick_tile(l: int) -> int:
    """Sequence tile length: small enough for VMEM, divides the bucket."""
    for t in (32, 16, 8, 4, 2, 1):
        if l % t == 0:
            return t
    return 1


@functools.partial(jax.named_call, name="road_batched_apply")
def road_batched_apply(h, r1_bank, r2_bank, ids):
    """Heterogeneous-batch RoAd apply (Eq. 4), Pallas hot path.

    h        [B, L, d]   activations out of the frozen linear layer
    r1_bank  [n, d]      cos-side effective vectors, one row per adapter
    r2_bank  [n, d]      sin-side effective vectors
    ids      [B] int32   adapter id per request

    The adapter gather is O(B*d) and hoisted out of the kernel; the kernel
    body is a single element-wise pass (the paper's claim: overhead
    comparable to element-wise multiplication, not bmm).
    """
    b, l, d = h.shape
    r1 = r1_bank[ids]  # [B, d]
    r2 = r2_bank[ids]
    tl = _pick_tile(l)
    grid = (b, l // tl)
    return pl.pallas_call(
        _road_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tl, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tl, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), h.dtype),
        interpret=True,
    )(h, r1, r2)


def _road_apply_kernel(h_ref, r1_ref, r2_ref, o_ref):
    """Single-adapter tile kernel: shared (r1, r2) for the whole batch."""
    h = h_ref[...]                       # [TL, d]
    r1 = r1_ref[...]                     # [d]
    r2 = r2_ref[...]
    tl, d = h.shape
    hp = h.reshape(tl, d // 2, 2)
    hhat = jnp.stack([-hp[..., 1], hp[..., 0]], axis=-1).reshape(tl, d)
    o_ref[...] = r1[None, :] * h + r2[None, :] * hhat


def road_apply(h, r1, r2):
    """Single-adapter RoAd apply; h [..., d], r1/r2 [d] (training path)."""
    *lead, d = h.shape
    rows = 1
    for s in lead:
        rows *= s
    h2 = h.reshape(rows, d)
    tl = _pick_tile(rows)
    out = pl.pallas_call(
        _road_apply_kernel,
        grid=(rows // tl,),
        in_specs=[
            pl.BlockSpec((tl, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tl, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), h.dtype),
        interpret=True,
    )(h2, r1, r2)
    return out.reshape(*lead, d)
