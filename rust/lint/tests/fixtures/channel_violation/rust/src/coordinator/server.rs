use std::sync::mpsc;

pub fn open() -> (mpsc::Sender<u32>, mpsc::Receiver<u32>) {
    mpsc::channel()
}

pub fn typed() {
    let (_tx, _rx) = mpsc::channel::<u32>();
}
