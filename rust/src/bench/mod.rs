//! Serving workload generation + the Figure-4 / Table-D.1 sweep harness.
//!
//! Figure 4's three panels are throughput studies of the multi-adapter
//! serving engine:
//!   * Left   — merged vs unmerged LoRA vs rank (batch 1, long generation),
//!   * Middle — RoAd vs unmerged LoRA vs #generated tokens (batch 8,
//!              heterogeneous adapters),
//!   * Right  — RoAd vs unmerged LoRA vs #distinct adapters in the batch.
//!
//! The bank-churn study ([`bank_churn_study`]) goes past the paper's
//! figure: many more registered adapters than device bank slots, a
//! Zipf-distributed request-to-adapter assignment, and paged vs
//! whole-bank-upload engines compared on hit/miss/eviction counts and
//! host-to-device upload bytes.
//!
//! Table D.1 times the per-step cost of each finetuning method (RoAd's
//! inherent orthogonality vs OFT's Cayley solves) and reports the
//! optimizer-state footprint.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::adapters::{Adapter, LoraAdapter, RoadAdapter};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{Request, SamplingParams, StreamEvent};
use crate::coordinator::sched::{PolicyKind, SchedSim, SimOutcome, SimRecord};
use crate::runtime::Runtime;
use crate::trainer::{Recipe, TrainBatch, Trainer};
use crate::util::clock::Clock;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// One serving measurement.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    pub label: String,
    pub batch: usize,
    pub distinct_adapters: usize,
    pub new_tokens: usize,
    pub requests: usize,
    pub wall_secs: f64,
    /// Generated tokens per second (the paper's throughput axis).
    pub tokens_per_sec: f64,
    pub decode_steps: usize,
    /// Time spent inside decode executions (see
    /// [`ServingPoint::ms_per_step`]; the KV residency comparison's axis).
    pub decode_secs: f64,
    /// Adapter-bank paging counters (the bank study's axes).
    pub bank_hits: usize,
    pub bank_misses: usize,
    pub bank_evictions: usize,
    pub bank_upload_bytes: usize,
}

impl ServingPoint {
    /// Mean decode-step cost in milliseconds; `None` when the run never
    /// decoded (e.g. every request finished at prefill).
    pub fn ms_per_step(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| self.decode_secs * 1e3 / self.decode_steps as f64)
    }
}

/// Build a heterogeneous workload: `n_requests` requests over
/// `distinct` registered adapters (round-robin), each generating
/// `new_tokens` tokens from a short prompt.
pub fn hetero_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if distinct > 0 {
                r = r.with_adapter(&format!("adapter-{}", i % distinct));
            }
            r
        })
        .collect()
}

/// Sample from a Zipf(s) distribution over ranks `0..n` (rank 0 most
/// popular): the canonical popularity skew for per-user adapter traffic —
/// a few hot adapters dominate while a long tail stays cold, which is the
/// regime an LRU-paged bank exploits.
pub fn zipf_sample(rng: &mut Rng, n: usize, s: f64) -> usize {
    rng.weighted(&zipf_weights(n, s))
}

/// Unnormalized Zipf(s) weights over ranks `0..n` (precompute once when
/// sampling repeatedly — [`zipf_workload`] does).
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    assert!(n > 0, "zipf distribution needs at least one rank");
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
}

/// Build an adapter-churn workload: `n_requests` requests over `distinct`
/// registered adapters with a Zipf(s)-distributed request→adapter
/// assignment (instead of [`hetero_workload`]'s uniform round-robin).
pub fn zipf_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    zipf_s: f64,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    let weights = (distinct > 0).then(|| zipf_weights(distinct, zipf_s));
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> = (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if let Some(w) = &weights {
                let k = rng.weighted(w);
                r = r.with_adapter(&format!("adapter-{k}"));
            }
            r
        })
        .collect()
}

/// Register `distinct` random adapters of the engine's mode.
pub fn register_adapters(engine: &mut Engine, distinct: usize, seed: u64) -> Result<()> {
    let mut rng = Rng::seed_from(seed);
    for i in 0..distinct {
        let adapter = match engine.econf.mode.as_str() {
            "road" => Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.2)),
            "lora" => Adapter::Lora(LoraAdapter::random(&engine.cfg, &mut rng, 0.05)),
            m => anyhow::bail!("no random adapter generator for mode {m}"),
        };
        engine.register_adapter(&format!("adapter-{i}"), &adapter)?;
    }
    Ok(())
}

/// Run one serving measurement: fresh engine in `mode`, `distinct`
/// adapters, `n_requests` requests × `new_tokens` tokens.
pub fn measure_serving(
    rt: &Rc<Runtime>,
    model: &str,
    mode: &str,
    slots: usize,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let econf = EngineConfig {
        model: model.into(),
        mode: mode.into(),
        decode_slots: slots,
        queue_capacity: 4096,
        ..Default::default()
    };
    measure_serving_cfg(rt, econf, distinct, n_requests, new_tokens, seed)
}

/// Like [`measure_serving`], but over an explicit engine config — the KV
/// residency comparison uses this to flip `kv_host_roundtrip` with
/// everything else held fixed.
pub fn measure_serving_cfg(
    rt: &Rc<Runtime>,
    econf: EngineConfig,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let mode = econf.mode.clone();
    let mut engine = Engine::new(rt.clone(), econf)?;
    if distinct > 0 {
        register_adapters(&mut engine, distinct, seed)?;
    }
    let mut rng = Rng::seed_from(seed ^ 0xbe7c);
    let prompt_len = 8;
    let reqs = hetero_workload(&mut rng, n_requests, distinct, prompt_len, new_tokens);
    run_workload(&mut engine, &format!("{mode}/d{distinct}"), distinct, new_tokens, reqs)
}

/// Drive `reqs` to completion on `engine` and package the measurement.
fn run_workload(
    engine: &mut Engine,
    label: &str,
    distinct: usize,
    new_tokens: usize,
    reqs: Vec<Request>,
) -> Result<ServingPoint> {
    let n_requests = reqs.len();
    // roadlint: allow(clock-discipline) -- closed-loop throughput point:
    // wall_secs divides into tokens/sec, so it must be real hardware time
    // even when the engine itself runs on a manual clock.
    let t0 = std::time::Instant::now();
    let outs = engine.run_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let gen_tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    Ok(ServingPoint {
        label: label.to_string(),
        batch: engine.econf.decode_slots,
        distinct_adapters: distinct,
        new_tokens,
        requests: n_requests,
        wall_secs: wall,
        tokens_per_sec: gen_tokens as f64 / wall,
        decode_steps: engine.metrics.decode_steps,
        decode_secs: engine.metrics.decode_time.as_secs_f64(),
        bank_hits: engine.metrics.bank_hits,
        bank_misses: engine.metrics.bank_misses,
        bank_evictions: engine.metrics.bank_evictions,
        bank_upload_bytes: engine.metrics.bank_upload_bytes,
    })
}

/// The adapter-churn study: `n_adapters` registered adapters paged through
/// a `bank_slots`-slot device bank (adapters ≫ slots) under a Zipf(1.1)
/// request mix, measured with paged per-slot uploads vs the whole-bank
/// re-upload baseline.  Every request must complete — registration can no
/// longer fail on capacity, and eviction never touches a pinned slot.
pub fn bank_churn_study(
    rt: &Rc<Runtime>,
    n_adapters: usize,
    bank_slots: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, paged) in [("road/paged-bank", true), ("road/whole-bank-upload", false)] {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            bank_slots: Some(bank_slots),
            paged_bank_uploads: paged,
            ..Default::default()
        };
        let mut engine = Engine::new(rt.clone(), econf)?;
        register_adapters(&mut engine, n_adapters, seed)?;
        let mut rng = Rng::seed_from(seed ^ 0x21f7);
        let reqs = zipf_workload(&mut rng, n_requests, n_adapters, 1.1, 8, new_tokens);
        out.push(run_workload(&mut engine, label, n_adapters, new_tokens, reqs)?);
    }
    Ok(out)
}

/// Device-resident vs host-round-trip decode on an otherwise identical
/// heterogeneous workload (batch 8, road mode).  The second point is the
/// pre-refactor baseline that moved the full K/V cache host↔device every
/// step; `decode_secs / decode_steps` is the per-step cost to compare.
pub fn kv_residency_comparison(
    rt: &Rc<Runtime>,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, kv_host_roundtrip) in
        [("road/device-resident", false), ("road/host-roundtrip", true)]
    {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            kv_host_roundtrip,
            ..Default::default()
        };
        let mut p = measure_serving_cfg(rt, econf, 8, 16, new_tokens, seed)?;
        p.label = label.into();
        out.push(p);
    }
    Ok(out)
}

/// One streaming-serving measurement (the open-loop study's row).
#[derive(Clone, Debug)]
pub struct StreamingPoint {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub cancelled: usize,
    /// Requests that never reached a `Finished` event (submit rejected or
    /// stream ended in `Error`) — kept out of `completed` so the
    /// run-to-completion vs cancel comparison stays honest.
    pub errored: usize,
    /// Token events observed client-side across all requests.
    pub tokens_streamed: usize,
    pub wall_secs: f64,
    /// Client-observed TTFT (submit call → first `Token` event received),
    /// in milliseconds — the latency a real caller sees through the
    /// channel, not the engine's internal stamp.
    pub observed_ttft_p50_ms: f64,
    pub observed_ttft_p90_ms: f64,
}

/// Open-loop streaming study over the threaded server: clients submit on
/// an arrival clock (independent of completions), consume `StreamEvent`s,
/// and measure *observed* TTFT.  The second scenario cancels every other
/// request after `cancel_after` observed tokens — the cancellation-reclaim
/// comparison: reclaimed decode lanes shrink wall time and streamed-token
/// volume versus running every request to completion.
///
/// Arrivals are driven by `clock`, which the engine shares, and paced by
/// the submitting thread itself so the arrival *order* is deterministic
/// on either clock: request `i` enters at `i*2ms` of clock time (a real
/// sleep on the wall clock, a virtual jump on a manual one — no sleeps
/// anywhere in the bench itself).  Consumer threads only drain events,
/// so their scheduling cannot reorder submissions.  Client-observed
/// latencies still carry thread-timing noise; the byte-reproducible
/// study is `sched_study_sim`.
#[allow(clippy::too_many_arguments)]
pub fn streaming_study(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    n_requests: usize,
    new_tokens: usize,
    cancel_after: usize,
    seed: u64,
    clock: Clock,
    backend: crate::runtime::BackendKind,
) -> Result<Vec<StreamingPoint>> {
    use crate::coordinator::server::EngineServer;

    let distinct = 8usize;
    let mut out = Vec::new();
    for (label, cancel_half) in [
        ("stream/run-to-completion", false),
        ("stream/cancel-half", true),
    ] {
        let econf = EngineConfig {
            model: model.into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            clock: clock.clone(),
            backend,
            ..Default::default()
        };
        let (server, client) = EngineServer::start(econf, artifacts_dir.clone(), move |eng| {
            register_adapters(eng, distinct, seed)
        })?;
        let mut rng = Rng::seed_from(seed ^ 0x57e4);
        let reqs = hetero_workload(&mut rng, n_requests, distinct, 8, new_tokens);

        let start = clock.now();
        let mut handles = Vec::new();
        for (i, req) in reqs.into_iter().enumerate() {
            let cancel_at = (cancel_half && i % 2 == 1).then_some(cancel_after);
            // Open-loop arrival clock, paced here on the submitting
            // thread: request i enters at i*2ms of clock time whether or
            // not earlier requests have finished, and submissions happen
            // in arrival order on both clock kinds.
            clock.sleep_until(start + Duration::from_millis(2 * i as u64));
            let submitted = clock.now();
            let generation = match client.submit(req) {
                Ok(g) => g,
                Err(_) => {
                    // Terminal outcome None = submit rejected (counted as
                    // errored below, like a stream that dies in Error).
                    handles.push(std::thread::spawn(move || (None, 0, None)));
                    continue;
                }
            };
            // Per-request terminal outcome: Some(true) = cancelled,
            // Some(false) = completed, None = the stream ended in an
            // Error event.
            let tclock = clock.clone();
            handles.push(std::thread::spawn(move || -> (Option<f64>, usize, Option<bool>) {
                let mut generation = generation;
                let mut ttft = None;
                let mut seen = 0usize;
                let mut cancel_sent = false;
                let mut outcome = None;
                while let Some(ev) = generation.recv() {
                    match ev {
                        StreamEvent::Token { .. } => {
                            ttft.get_or_insert_with(|| {
                                tclock.now().saturating_duration_since(submitted).as_secs_f64()
                            });
                            seen += 1;
                            if !cancel_sent && cancel_at.is_some_and(|k| seen >= k) {
                                generation.cancel();
                                cancel_sent = true;
                            }
                        }
                        StreamEvent::Finished(o) => {
                            let c = crate::coordinator::request::FinishReason::Cancelled;
                            outcome = Some(o.finish == c);
                            break;
                        }
                        StreamEvent::Error { .. } => break,
                        StreamEvent::Admitted { .. } => {}
                    }
                }
                (ttft, seen, outcome)
            }));
        }
        let mut ttfts_ms = Vec::new();
        let (mut completed, mut cancelled, mut errored) = (0usize, 0usize, 0usize);
        let mut tokens_streamed = 0usize;
        for h in handles {
            let (ttft, seen, outcome) = h.join().expect("client thread panicked");
            if let Some(t) = ttft {
                ttfts_ms.push(t * 1e3);
            }
            tokens_streamed += seen;
            match outcome {
                Some(true) => cancelled += 1,
                Some(false) => completed += 1,
                None => errored += 1,
            }
        }
        let wall = clock.now().saturating_duration_since(start).as_secs_f64();
        server.shutdown()?;
        let s = crate::util::stats::summarize(&ttfts_ms);
        out.push(StreamingPoint {
            label: label.into(),
            requests: n_requests,
            completed,
            cancelled,
            errored,
            tokens_streamed,
            wall_secs: wall,
            observed_ttft_p50_ms: s.p50,
            observed_ttft_p90_ms: s.p90,
        });
    }
    Ok(out)
}

/// Render the streaming study; the cancel row's smaller streamed-token
/// volume and wall time are the reclaim the study exists to show.
pub fn render_streaming_points(title: &str, points: &[StreamingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "reqs", "completed", "cancelled", "errored", "tok-streamed", "wall(s)",
        "obs-ttft p50(ms)", "obs-ttft p90(ms)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.requests.to_string(),
            p.completed.to_string(),
            p.cancelled.to_string(),
            p.errored.to_string(),
            p.tokens_streamed.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.observed_ttft_p50_ms, 1),
            fmt_f(p.observed_ttft_p90_ms, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nobs-ttft is measured at the client (submit call → first Token \
         event through the channel); cancelled lanes are reclaimed for waiting work, \
         which is the wall/token delta between the rows.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Admission-scheduling study (`road bench-serving --study sched`)
// ---------------------------------------------------------------------------

/// Per-adapter queue-wait aggregate in one sched-study row — the
/// fairness axis (one hot adapter must not starve the rest).
#[derive(Clone, Debug)]
pub struct AdapterWait {
    pub adapter: String,
    pub requests: usize,
    pub wait_p50_ms: f64,
    pub wait_p99_ms: f64,
    pub wait_max_ms: f64,
}

/// One policy's row in the admission-scheduling study.
#[derive(Clone, Debug)]
pub struct SchedPoint {
    pub policy: String,
    pub requests: usize,
    pub finished: usize,
    pub shed: usize,
    /// Sheds over deadline-bearing requests (0 when none carry deadlines).
    pub deadline_miss_rate: f64,
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p99_ms: f64,
    /// Worst time any single request spent waiting in the queue (time to
    /// admission, or to its terminal event if it never got a lane) — the
    /// starvation axis.
    pub starvation_ms: f64,
    pub per_adapter: Vec<AdapterWait>,
}

/// Decorate a Zipf workload for the sched study: every 3rd request
/// carries a deadline and every 4th a priority tier, both derived from
/// the request index so the workload is a pure function of `seed`.
fn sched_workload(
    n_requests: usize,
    distinct: usize,
    zipf_s: f64,
    new_tokens: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::seed_from(seed ^ 0x5c4ed);
    let mut reqs = zipf_workload(&mut rng, n_requests, distinct, zipf_s, 8, new_tokens);
    for (i, r) in reqs.iter_mut().enumerate() {
        if i % 3 == 0 {
            r.deadline = Some(Duration::from_millis(200 + (i as u64 % 5) * 50));
        }
        if i % 4 == 0 {
            r.priority = (i % 3) as u8 + 1;
        }
    }
    reqs
}

/// Fold terminal records into one study row.  Works over [`SimRecord`]s
/// whether they came from the [`SchedSim`] harness or from replaying a
/// real engine's event stream.
fn aggregate_sched(policy: &str, requests: usize, records: &[SimRecord]) -> SchedPoint {
    // Queue wait = submit → admission; a request that never reached a
    // lane (shed/cancelled while queued) waited until its terminal event.
    let wait_ms = |r: &SimRecord| {
        (r.admitted_at.unwrap_or(r.finished_at) - r.submitted_at).as_secs_f64() * 1e3
    };
    let mut waits: Vec<f64> = Vec::new();
    let mut per: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let (mut finished, mut shed, mut with_deadline) = (0usize, 0usize, 0usize);
    for r in records {
        match r.outcome {
            SimOutcome::Finished => finished += 1,
            SimOutcome::DeadlineShed => shed += 1,
            SimOutcome::Cancelled => {}
        }
        if r.deadline.is_some() {
            with_deadline += 1;
        }
        let w = wait_ms(r);
        waits.push(w);
        per.entry(r.adapter.clone().unwrap_or_else(|| "base".into())).or_default().push(w);
    }
    let s = crate::util::stats::summarize(&waits);
    let per_adapter = per
        .into_iter()
        .map(|(adapter, ws)| {
            let a = crate::util::stats::summarize(&ws);
            AdapterWait {
                adapter,
                requests: ws.len(),
                wait_p50_ms: a.p50,
                wait_p99_ms: a.p99,
                wait_max_ms: a.max,
            }
        })
        .collect();
    SchedPoint {
        policy: policy.to_string(),
        requests,
        finished,
        shed,
        deadline_miss_rate: if with_deadline > 0 {
            shed as f64 / with_deadline as f64
        } else {
            0.0
        },
        queue_wait_p50_ms: s.p50,
        queue_wait_p99_ms: s.p99,
        starvation_ms: s.max,
        per_adapter,
    }
}

/// The admission-scheduling study on the deterministic harness
/// (`--sim-clock`): all four policies over the same Zipf-skewed,
/// deadline/priority-decorated workload, arrivals every 10 ms of
/// *virtual* time, decode steps costing a fixed 5 ms of virtual time.
/// No artifacts, no sleeps, no wall-clock reads — two runs produce
/// byte-identical output.
pub fn sched_study_sim(
    n_requests: usize,
    distinct: usize,
    new_tokens: usize,
    seed: u64,
) -> Vec<SchedPoint> {
    let arrival_gap = Duration::from_millis(10);
    let step_cost = Duration::from_millis(5);
    let mut out = Vec::new();
    for kind in PolicyKind::ALL {
        let mut sim = SchedSim::new(kind, 8, 4096, step_cost);
        let reqs = sched_workload(n_requests, distinct, 1.2, new_tokens, seed);
        let start = sim.clock.now();
        let mut pending: VecDeque<(usize, Request)> = reqs.into_iter().enumerate().collect();
        loop {
            let due = |pending: &VecDeque<(usize, Request)>| {
                pending.front().map(|(i, _)| start + arrival_gap * (*i as u32))
            };
            while due(&pending).is_some_and(|d| d <= sim.clock.now()) {
                let (_, req) = pending.pop_front().expect("due arrival checked");
                sim.submit(req).expect("study queue capacity exceeds the workload");
            }
            if pending.is_empty() && !sim.has_work() {
                break;
            }
            if !sim.has_work() {
                // Idle until the next arrival (a virtual jump).
                if let Some(d) = due(&pending) {
                    sim.clock.sleep_until(d);
                    continue;
                }
            }
            sim.step();
        }
        out.push(aggregate_sched(kind.name(), n_requests, sim.records()));
    }
    out
}

/// The same study over the real engine (artifacts required): one engine
/// per policy with `EngineConfig::policy` set, the identical decorated
/// workload, arrivals open-loop on the engine's clock.  Queue waits are
/// observed from the `Admitted`/terminal events the step loop emits.
pub fn sched_study_engine(
    rt: &Rc<Runtime>,
    n_requests: usize,
    distinct: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<SchedPoint>> {
    struct OpenLoop {
        adapter: Option<String>,
        priority: u8,
        deadline: Option<Duration>,
        submitted_at: Instant,
        admitted_at: Option<Instant>,
        admitted_seq: Option<usize>,
    }
    let arrival_gap = Duration::from_millis(10);
    let mut out = Vec::new();
    for kind in PolicyKind::ALL {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            policy: kind,
            ..Default::default()
        };
        let mut engine = Engine::new(rt.clone(), econf)?;
        register_adapters(&mut engine, distinct, seed)?;
        let clock = engine.clock().clone();
        let reqs = sched_workload(n_requests, distinct, 1.2, new_tokens, seed);
        let start = clock.now();
        let mut pending: VecDeque<(usize, Request)> = reqs.into_iter().enumerate().collect();
        let mut live: BTreeMap<u64, OpenLoop> = BTreeMap::new();
        let mut records: Vec<SimRecord> = Vec::new();
        let mut admissions = 0usize;
        loop {
            let due = |pending: &VecDeque<(usize, Request)>| {
                pending.front().map(|(i, _)| start + arrival_gap * (*i as u32))
            };
            while due(&pending).is_some_and(|d| d <= clock.now()) {
                let (_, req) = pending.pop_front().expect("due arrival checked");
                let info = OpenLoop {
                    adapter: req.adapter.clone(),
                    priority: req.priority,
                    deadline: req.deadline,
                    submitted_at: clock.now(),
                    admitted_at: None,
                    admitted_seq: None,
                };
                let id = engine.submit(req)?;
                live.insert(id, info);
            }
            if pending.is_empty() && !engine.has_work() {
                break;
            }
            if !engine.has_work() {
                if let Some(d) = due(&pending) {
                    clock.sleep_until(d);
                    continue;
                }
            }
            for ev in engine.step()? {
                let id = ev.id();
                match &ev {
                    StreamEvent::Admitted { .. } => {
                        if let Some(info) = live.get_mut(&id) {
                            info.admitted_at = Some(clock.now());
                            info.admitted_seq = Some(admissions);
                            admissions += 1;
                        }
                    }
                    StreamEvent::Token { .. } => {}
                    StreamEvent::Finished(o) => {
                        if let Some(info) = live.remove(&id) {
                            let cancelled =
                                o.finish == crate::coordinator::request::FinishReason::Cancelled;
                            records.push(SimRecord {
                                id,
                                adapter: info.adapter,
                                priority: info.priority,
                                deadline: info.deadline,
                                submitted_at: info.submitted_at,
                                admitted_at: info.admitted_at,
                                admitted_seq: info.admitted_seq,
                                finished_at: clock.now(),
                                outcome: if cancelled {
                                    SimOutcome::Cancelled
                                } else {
                                    SimOutcome::Finished
                                },
                            });
                        }
                    }
                    StreamEvent::Error { error, .. } => {
                        if let Some(info) = live.remove(&id) {
                            let shed = matches!(
                                error,
                                crate::coordinator::queue::EngineError::DeadlineExceeded
                            );
                            records.push(SimRecord {
                                id,
                                adapter: info.adapter,
                                priority: info.priority,
                                deadline: info.deadline,
                                submitted_at: info.submitted_at,
                                admitted_at: info.admitted_at,
                                admitted_seq: info.admitted_seq,
                                finished_at: clock.now(),
                                // Only deadline sheds occur on this driver;
                                // anything else counts as a cancellation so
                                // the conservation totals still close.
                                outcome: if shed {
                                    SimOutcome::DeadlineShed
                                } else {
                                    SimOutcome::Cancelled
                                },
                            });
                        }
                    }
                }
            }
        }
        out.push(aggregate_sched(kind.name(), n_requests, &records));
    }
    Ok(out)
}

/// JSON form of the sched study — what the `--sim-clock` acceptance check
/// compares byte-for-byte across runs.
pub fn sched_points_json(points: &[SchedPoint]) -> Json {
    json::arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("policy", json::s(&p.policy)),
                    ("requests", json::num(p.requests as f64)),
                    ("finished", json::num(p.finished as f64)),
                    ("deadline_shed", json::num(p.shed as f64)),
                    ("deadline_miss_rate", json::num(p.deadline_miss_rate)),
                    ("queue_wait_p50_ms", json::num(p.queue_wait_p50_ms)),
                    ("queue_wait_p99_ms", json::num(p.queue_wait_p99_ms)),
                    ("starvation_ms", json::num(p.starvation_ms)),
                    (
                        "per_adapter",
                        json::arr(
                            p.per_adapter
                                .iter()
                                .map(|a| {
                                    json::obj(vec![
                                        ("adapter", json::s(&a.adapter)),
                                        ("requests", json::num(a.requests as f64)),
                                        ("wait_p50_ms", json::num(a.wait_p50_ms)),
                                        ("wait_p99_ms", json::num(a.wait_p99_ms)),
                                        ("wait_max_ms", json::num(a.wait_max_ms)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// Render the sched study: one row per policy, plus the hottest/coldest
/// adapter waits so the fairness story is visible without the JSON.
pub fn render_sched_points(title: &str, points: &[SchedPoint]) -> String {
    let mut t = Table::new(&[
        "policy",
        "reqs",
        "finished",
        "shed",
        "miss-rate",
        "wait p50(ms)",
        "wait p99(ms)",
        "starvation(ms)",
        "hot p99(ms)",
        "cold p99(ms)",
    ]);
    for p in points {
        // "Hot" = adapter with the most requests; "cold" = the fewest.
        let hot = p.per_adapter.iter().max_by_key(|a| a.requests);
        let cold = p.per_adapter.iter().min_by_key(|a| a.requests);
        t.row(vec![
            p.policy.clone(),
            p.requests.to_string(),
            p.finished.to_string(),
            p.shed.to_string(),
            fmt_f(p.deadline_miss_rate, 3),
            fmt_f(p.queue_wait_p50_ms, 1),
            fmt_f(p.queue_wait_p99_ms, 1),
            fmt_f(p.starvation_ms, 1),
            fmt_f(hot.map_or(0.0, |a| a.wait_p99_ms), 1),
            fmt_f(cold.map_or(0.0, |a| a.wait_p99_ms), 1),
        ]);
    }
    format!(
        "## {title}\n{}\nedf should minimize miss-rate, priority should favor high tiers, \
         fair should pull cold-adapter waits toward hot-adapter waits, and fcfs is the \
         pre-policy baseline.  Full per-adapter percentiles ride in the JSON block below.\n",
        t.render()
    )
}

/// Figure 4 (Left): merged vs unmerged LoRA.  The merged path is the base
/// model (adapter folded into W, paper §4.2); the unmerged path pays the
/// per-layer bmm epilogue.  Rank is compile-time-fixed in the artifacts,
/// so the sweep axis here is the serving mode; the rank effect is covered
/// by the adapter_ops microbench.
pub fn fig4_left(rt: &Rc<Runtime>, new_tokens: usize, seed: u64) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    // batch 1, single adapter — the paper's configuration.
    let mut merged = measure_serving(rt, "serve", "base", 1, 0, 4, new_tokens, seed)?;
    merged.label = "lora-merged(base)".into();
    out.push(merged);
    let mut unmerged = measure_serving(rt, "serve", "lora", 1, 1, 4, new_tokens, seed)?;
    unmerged.label = "lora-unmerged".into();
    out.push(unmerged);
    let mut road = measure_serving(rt, "serve", "road", 1, 1, 4, new_tokens, seed)?;
    road.label = "road-unmerged".into();
    out.push(road);
    Ok(out)
}

/// Figure 4 (Middle): throughput vs #generated tokens at batch 8, eight
/// distinct adapters (fully heterogeneous).
pub fn fig4_middle(
    rt: &Rc<Runtime>,
    token_counts: &[usize],
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &nt in token_counts {
        for mode in ["road", "lora"] {
            let mut p = measure_serving(rt, "serve", mode, 8, 8, 16, nt, seed)?;
            p.label = format!("{mode}/t{nt}");
            out.push(p);
        }
    }
    Ok(out)
}

/// Figure 4 (Right): throughput vs #distinct adapters at batch 8.
pub fn fig4_right(
    rt: &Rc<Runtime>,
    distinct_counts: &[usize],
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &d in distinct_counts {
        for mode in ["road", "lora"] {
            out.push(measure_serving(rt, "serve", mode, 8, d, 16, new_tokens, seed)?);
        }
    }
    Ok(out)
}

/// Render the bank-churn study with its paging counters; the `upload(KB)`
/// column is the comparison the study exists for (paged rows strictly
/// below the whole-bank baseline).
pub fn render_bank_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "reqs", "tok/s", "hits", "misses", "evictions",
        "upload(KB)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.requests.to_string(),
            fmt_f(p.tokens_per_sec, 1),
            p.bank_hits.to_string(),
            p.bank_misses.to_string(),
            p.bank_evictions.to_string(),
            fmt_f(p.bank_upload_bytes as f64 / 1e3, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nupload(KB) is the comparison axis (host-to-device bank traffic). \
         On the offline stub, paged wall-time additionally pays the device-side scatter \
         stand-in (see AdapterBank::upload_dirty), so tok/s there favors no side.\n",
        t.render()
    )
}

pub fn render_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "new-toks", "reqs", "wall(s)", "tok/s", "ms/step",
    ]);
    for p in points {
        let ms_per_step = p.ms_per_step().unwrap_or(0.0);
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.new_tokens.to_string(),
            p.requests.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.tokens_per_sec, 1),
            fmt_f(ms_per_step, 3),
        ]);
    }
    format!("## {title}\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Table D.1: finetuning efficiency (RoAd vs OFT Cayley)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct TrainEfficiency {
    pub method: String,
    pub n_trainable: usize,
    pub iters: usize,
    pub wall_secs: f64,
    pub secs_per_step: f64,
    /// Trainable + AdamW state footprint in bytes (the part that scales
    /// with the method; the paper's "peak GPU memory" analogue on a
    /// host-state basis).
    pub state_bytes: usize,
}

/// Time `iters` optimizer steps of `method` on random LM batches.
pub fn measure_train_efficiency(
    rt: &Rc<Runtime>,
    config: &str,
    method: &str,
    iters: usize,
    seed: u64,
) -> Result<TrainEfficiency> {
    let mut tr = Trainer::new(rt.clone(), config, method)?;
    let (b, l) = (tr.batch, tr.seq_len);
    let mut rng = Rng::seed_from(seed);
    let recipe = Recipe::default().with_steps(iters);

    // Warm-up step excluded from timing (compile/caches).
    let mk = |rng: &mut Rng| -> TrainBatch {
        let tokens: Vec<i32> = (0..b * l).map(|_| 1 + rng.below(255) as i32).collect();
        let mut targets = vec![0i32; b * l];
        for row in 0..b {
            for p in 0..l - 1 {
                targets[row * l + p] = tokens[row * l + p + 1];
            }
        }
        TrainBatch { tokens, targets, mask: vec![1.0; b * l] }
    };
    let warm = mk(&mut rng);
    tr.step(&warm, recipe.lr_at(0))?;

    // roadlint: allow(clock-discipline) -- wall-profiles real optimizer
    // throughput (secs/step); virtual time has no meaning here.
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let batch = mk(&mut rng);
        tr.step(&batch, recipe.lr_at(i))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let state_bytes = tr.n_trainable * 4 * 3; // params + m + v
    Ok(TrainEfficiency {
        method: method.to_string(),
        n_trainable: tr.n_trainable,
        iters,
        wall_secs: wall,
        secs_per_step: wall / iters as f64,
        state_bytes,
    })
}

pub fn render_train_efficiency(rows: &[TrainEfficiency]) -> String {
    let mut t = Table::new(&[
        "method", "#trainable", "iters", "wall(s)", "s/step", "state(KB)",
    ]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            r.n_trainable.to_string(),
            r.iters.to_string(),
            fmt_f(r.wall_secs, 2),
            fmt_f(r.secs_per_step, 4),
            fmt_f(r.state_bytes as f64 / 1024.0, 1),
        ]);
    }
    format!("## Table D.1 analogue: finetuning efficiency\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_round_robins_adapters() {
        let mut rng = Rng::seed_from(1);
        let reqs = hetero_workload(&mut rng, 8, 4, 8, 16);
        assert_eq!(reqs.len(), 8);
        assert_eq!(reqs[0].adapter.as_deref(), Some("adapter-0"));
        assert_eq!(reqs[5].adapter.as_deref(), Some("adapter-1"));
        assert!(reqs.iter().all(|r| r.prompt.len() == 8));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t > 0)));
    }

    #[test]
    fn workload_without_adapters_is_base() {
        let mut rng = Rng::seed_from(2);
        let reqs = hetero_workload(&mut rng, 3, 0, 4, 8);
        assert!(reqs.iter().all(|r| r.adapter.is_none()));
    }

    #[test]
    fn render_produces_rows() {
        let p = ServingPoint {
            label: "road/d8".into(),
            batch: 8,
            distinct_adapters: 8,
            new_tokens: 128,
            requests: 16,
            wall_secs: 1.5,
            tokens_per_sec: 1365.3,
            decode_steps: 256,
            decode_secs: 1.28,
            bank_hits: 12,
            bank_misses: 4,
            bank_evictions: 1,
            bank_upload_bytes: 8192,
        };
        let s = render_points("Fig 4 (Right)", &[p.clone()]);
        assert!(s.contains("road/d8"));
        assert!(s.contains("1365.3"));
        let b = render_bank_points("Bank churn", &[p]);
        assert!(b.contains("hits"), "{b}");
        assert!(b.contains("12"), "{b}");
        assert!(b.contains("8.2"), "upload KB column: {b}");
    }

    #[test]
    fn render_streaming_table_has_reclaim_columns() {
        let p = StreamingPoint {
            label: "stream/cancel-half".into(),
            requests: 16,
            completed: 7,
            cancelled: 8,
            errored: 1,
            tokens_streamed: 512,
            wall_secs: 2.5,
            observed_ttft_p50_ms: 12.5,
            observed_ttft_p90_ms: 31.0,
        };
        let s = render_streaming_points("Streaming", &[p]);
        for needle in ["cancelled", "errored", "tok-streamed", "obs-ttft p50(ms)", "12.5", "512"] {
            assert!(s.contains(needle), "missing {needle:?} in\n{s}");
        }
    }

    #[test]
    fn sched_study_sim_conserves_and_renders() {
        let pts = sched_study_sim(24, 4, 6, 3);
        assert_eq!(pts.len(), PolicyKind::ALL.len());
        for p in &pts {
            // No cancels in the study: every request finishes or is shed.
            assert_eq!(p.finished + p.shed, p.requests, "{}: leaked requests", p.policy);
            assert!(!p.per_adapter.is_empty());
        }
        let md = render_sched_points("Sched", &pts);
        for needle in ["fcfs", "edf", "priority", "fair", "miss-rate", "starvation(ms)"] {
            assert!(md.contains(needle), "missing {needle:?} in\n{md}");
        }
        let j = sched_points_json(&pts).to_string_compact();
        assert!(!j.contains('\n'), "compact JSON is one line");
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 4);
        assert_eq!(back.as_arr().unwrap()[0].get("policy").unwrap().as_str().unwrap(), "fcfs");
    }

    #[test]
    fn sched_workload_decoration_is_deterministic() {
        let (a, b) = (sched_workload(30, 5, 1.2, 8, 11), sched_workload(30, 5, 1.2, 8, 11));
        assert_eq!(a.len(), 30);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.adapter, y.adapter);
            assert_eq!(x.deadline, y.deadline);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.prompt, y.prompt);
        }
        // The decoration actually lands: some deadlines, some tiers.
        assert!(a.iter().any(|r| r.deadline.is_some()));
        assert!(a.iter().any(|r| r.priority > 0));
        assert!(a.iter().any(|r| r.deadline.is_none() && r.priority == 0));
    }

    #[test]
    fn zipf_workload_skews_to_head_adapters() {
        let mut rng = Rng::seed_from(5);
        let n = 64;
        let reqs = zipf_workload(&mut rng, 512, n, 1.1, 8, 16);
        assert_eq!(reqs.len(), 512);
        let mut counts = vec![0usize; n];
        for r in &reqs {
            let name = r.adapter.as_deref().unwrap();
            let k: usize = name.strip_prefix("adapter-").unwrap().parse().unwrap();
            counts[k] += 1;
        }
        let head: usize = counts[..4].iter().sum();
        let tail: usize = counts[n - 4..].iter().sum();
        assert!(head > tail * 4, "zipf head {head} should dominate tail {tail}");
        // Rank 0 is the most popular adapter.
        let max = counts.iter().copied().max().unwrap();
        assert_eq!(counts[0], max, "{counts:?}");
    }

    #[test]
    fn zipf_sample_in_range_and_deterministic() {
        let mut a = Rng::seed_from(9);
        let mut b = Rng::seed_from(9);
        for _ in 0..200 {
            let x = zipf_sample(&mut a, 7, 1.0);
            assert!(x < 7);
            assert_eq!(x, zipf_sample(&mut b, 7, 1.0));
        }
    }
}
