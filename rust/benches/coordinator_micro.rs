//! Microbench of coordinator data structures on the hot path: slot
//! allocation, queue admission/pop, adapter bank slot writes, LRU paging
//! bookkeeping, per-slot vs whole-bank upload cost, request construction,
//! and the decode step's KV transfer cost under host-round-trip vs
//! device-resident residency.  The data-structure ops must stay negligible
//! next to a decode step (~10ms); the bench prints each op's cost so
//! regressions are visible.
//!
//! ```bash
//! cargo bench --bench coordinator_micro
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use road::adapters::{Adapter, AdapterBank, AdapterRegistry, PageOutcome, RoadAdapter};
use road::coordinator::kv::SlotAllocator;
use road::coordinator::queue::AdmissionQueue;
use road::coordinator::request::Request;
use road::manifest::ModelConfigInfo;
use road::runtime::{buffer_to_host, upload};
use road::tensor::{DType, HostTensor};
use road::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    println!("{name:<44} {:>10.1} ns/op", t0.elapsed().as_secs_f64() / iters as f64 * 1e9);
}

fn serve_cfg() -> ModelConfigInfo {
    ModelConfigInfo {
        name: "serve".into(),
        vocab: 256,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 768,
        max_seq: 288,
        head_dim: 32,
        n_adapters: 16,
        lora_rank: 8,
    }
}

fn main() {
    let mut rng = Rng::seed_from(9);

    bench("slot alloc+release cycle (8 slots)", 100_000, || {
        let mut a = SlotAllocator::new(8);
        for _ in 0..8 {
            std::hint::black_box(a.alloc());
        }
        for s in 0..8 {
            a.release(s).unwrap();
        }
    });

    bench("queue push+pop_fitting (32 requests)", 10_000, || {
        let mut q = AdmissionQueue::new(64);
        for _ in 0..32 {
            q.push(Request::new(vec![1; 8], 16)).unwrap();
        }
        while !q.is_empty() {
            std::hint::black_box(q.pop_fitting(8, 16));
        }
    });

    let cfg = serve_cfg();
    let adapter = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.2));
    let mut bank = AdapterBank::new(&cfg, "road", cfg.n_adapters).unwrap();
    bench("adapter bank set_slot (serve-size road)", 2_000, || {
        bank.set_slot(3, &adapter).unwrap();
    });

    // LRU paging bookkeeping: a worst-case miss+evict page-in on a fully
    // occupied bank (store lookup, victim scan, set_slot, map updates).
    {
        let n_adapters = 64;
        let mut reg =
            AdapterRegistry::new(AdapterBank::new(&cfg, "road", cfg.n_adapters).unwrap());
        for i in 0..n_adapters {
            let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.2));
            reg.register(&format!("user-{i}"), &a).unwrap();
        }
        let mut next = 0usize;
        bench("registry page-in (miss+evict, 64 adapters)", 2_000, || {
            let out = reg.ensure_resident(&format!("user-{next}")).unwrap();
            std::hint::black_box(&out);
            next = (next + 1) % n_adapters; // cycling 64 names through 15 slots: always a miss
        });
        let resident = reg.resident_names()[0].to_string();
        bench("registry page hit (resident adapter)", 100_000, || {
            match reg.ensure_resident(&resident).unwrap() {
                PageOutcome::Hit(s) => {
                    std::hint::black_box(s);
                }
                o => panic!("expected hit, got {o:?}"),
            }
        });
    }

    // ------------------------------------------------------------------
    // Bank refresh after a single-slot change: paged per-slot rows vs the
    // whole-bank re-upload baseline.  The byte figures are what crosses
    // the host/device boundary as *bank content* on each path; the paged
    // stub path additionally rebuilds the stacked buffers in place of the
    // device-side scatter a native backend would run (see
    // AdapterBank::upload_dirty).
    // ------------------------------------------------------------------
    {
        let client = xla::PjRtClient::cpu().expect("xla client");
        let mut bank = AdapterBank::new(&cfg, "road", cfg.n_adapters).unwrap();
        let mut bufs = BTreeMap::new();
        bank.upload_dirty(&client, &mut bufs, true).unwrap();
        let slot_kb = bank.slot_bytes() as f64 / 1e3;
        let total_kb = bank.total_bytes() as f64 / 1e3;
        // NB: compare the KB figures, not the ns/op — the stub's paged
        // path also executes the scatter stand-in (a full host-mirror
        // refresh), so its wall time is an upper bound, not the win.
        bench(
            &format!("bank refresh, paged ({slot_kb:.1} KB traffic/slot + scatter stand-in)"),
            500,
            || {
                bank.set_slot(3, &adapter).unwrap();
                std::hint::black_box(bank.upload_dirty(&client, &mut bufs, true).unwrap());
            },
        );
        bench(
            &format!("bank refresh, whole-bank ({total_kb:.1} KB bank traffic)"),
            500,
            || {
                bank.set_slot(3, &adapter).unwrap();
                std::hint::black_box(bank.upload_dirty(&client, &mut bufs, false).unwrap());
            },
        );
    }

    bench("request construction (8-token prompt)", 100_000, || {
        std::hint::black_box(
            Request::new(vec![1, 2, 3, 4, 5, 6, 7, 8], 64).with_adapter("user-1"),
        );
    });

    // Host-side decode bookkeeping proxy: scanning 8 slots and building the
    // i32 step inputs, the per-step constant cost of the engine loop.
    let slots: Vec<Option<(i32, i32, i32)>> =
        (0..8).map(|i| if i % 3 == 0 { None } else { Some((i, i * 2, 1)) }).collect();
    bench("decode-step input assembly (8 lanes)", 100_000, || {
        let b = slots.len();
        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut ids = vec![0i32; b];
        for (s, slot) in slots.iter().enumerate() {
            if let Some((t, p, id)) = slot {
                token[s] = *t;
                pos[s] = *p;
                ids[s] = *id;
            }
        }
        std::hint::black_box((token, pos, ids));
    });

    // ------------------------------------------------------------------
    // Per-decode-step KV transfer: host round-trip vs device-resident.
    //
    // Host round-trip (the pre-refactor engine): both serve-sized caches
    // are uploaded as step inputs and downloaded as step outputs, every
    // step.  Device-resident: the step's output buffers are handed back as
    // the next step's inputs (a handle move) and only the [B, vocab]
    // logits are downloaded.  Buffers come from the xla client (the
    // offline build's host-memory stand-in moves the same byte volumes),
    // so the printed gap is the transfer work the refactor removes from
    // every step.
    // ------------------------------------------------------------------
    let slots_b = 8usize;
    let client = xla::PjRtClient::cpu().expect("xla client");
    let kv_shape = vec![cfg.n_layers, slots_b, cfg.n_heads, cfg.max_seq, cfg.head_dim];
    let kv_elems: usize = kv_shape.iter().product();
    let k = HostTensor::zeros(kv_shape.clone(), DType::F32);
    let v = HostTensor::zeros(kv_shape, DType::F32);
    let roundtrip_mb = 2.0 * 2.0 * kv_elems as f64 * 4.0 / 1e6; // k+v, up+down
    let logits = HostTensor::zeros(vec![slots_b, cfg.vocab], DType::F32);
    let logits_kb = (slots_b * cfg.vocab * 4) as f64 / 1e3;

    bench(
        &format!("decode-step KV host-roundtrip ({roundtrip_mb:.1} MB moved)"),
        30,
        || {
            let kb = upload(&client, &k).unwrap();
            let vb = upload(&client, &v).unwrap();
            std::hint::black_box(buffer_to_host(&kb, DType::F32).unwrap());
            std::hint::black_box(buffer_to_host(&vb, DType::F32).unwrap());
            std::hint::black_box(buffer_to_host(&upload(&client, &logits).unwrap(), DType::F32).unwrap());
        },
    );

    let mut dev_k = upload(&client, &k).unwrap();
    let mut dev_v = upload(&client, &v).unwrap();
    let dev_logits = upload(&client, &logits).unwrap();
    bench(
        &format!("decode-step KV device-resident ({logits_kb:.1} KB moved)"),
        10_000,
        || {
            // Installing the step's output buffers is a handle move.
            std::mem::swap(&mut dev_k, &mut dev_v);
            std::hint::black_box(buffer_to_host(&dev_logits, DType::F32).unwrap());
        },
    );
}
