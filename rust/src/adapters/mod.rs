//! Adapter representations, banks and the serving-side registry.
//!
//! All three RoAd variants share the serving representation of two
//! effective vectors (R1, R2) per adapted projection (Eq. 4); training
//! parameterizations (theta/alpha in 1/2/4-way sharing, Table 1) convert
//! through [`RoadVectors::from_theta_alpha`].  LoRA and (IA)³ adapters are
//! carried for the Figure-4 baseline comparison.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::manifest::ModelConfigInfo;
use crate::model::{proj_dims, PROJS};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// Effective serving vectors for one projection: z = r1⊗h + r2⊗ĥ.
#[derive(Clone, Debug, PartialEq)]
pub struct RoadVectors {
    pub r1: Vec<f32>,
    pub r2: Vec<f32>,
}

impl RoadVectors {
    pub fn identity(d: usize) -> RoadVectors {
        RoadVectors { r1: vec![1.0; d], r2: vec![0.0; d] }
    }

    /// Convert trainable (theta, alpha) to effective vectors.
    ///
    /// variant 1: theta/alpha `[d/2]`;  variant 2: `[d/2, 2]` row-shared;
    /// variant 4: `[d/2, 4]` all-distinct (t11, t12, t21, t22) — mirrors
    /// python/compile/kernels/ref.py exactly.
    pub fn from_theta_alpha(variant: usize, theta: &[f32], alpha: &[f32]) -> Result<RoadVectors> {
        let per = match variant {
            1 => 1,
            2 => 2,
            4 => 4,
            _ => bail!("unknown RoAd variant {variant}"),
        };
        if theta.len() != alpha.len() || theta.len() % per != 0 {
            bail!("bad theta/alpha lengths for variant {variant}");
        }
        let half = theta.len() / per;
        let d = half * 2;
        let mut r1 = vec![0f32; d];
        let mut r2 = vec![0f32; d];
        for k in 0..half {
            let (c1, s1, s2, c2) = match variant {
                1 => {
                    let (t, a) = (theta[k], alpha[k]);
                    (a * t.cos(), a * t.sin(), a * t.sin(), a * t.cos())
                }
                2 => {
                    let (t1, a1) = (theta[2 * k], alpha[2 * k]);
                    let (t2, a2) = (theta[2 * k + 1], alpha[2 * k + 1]);
                    (a1 * t1.cos(), a1 * t1.sin(), a2 * t2.sin(), a2 * t2.cos())
                }
                _ => {
                    let t = &theta[4 * k..4 * k + 4];
                    let a = &alpha[4 * k..4 * k + 4];
                    (a[0] * t[0].cos(), a[1] * t[1].sin(), a[2] * t[2].sin(), a[3] * t[3].cos())
                }
            };
            r1[2 * k] = c1;
            r1[2 * k + 1] = c2;
            r2[2 * k] = s1;
            r2[2 * k + 1] = s2;
        }
        Ok(RoadVectors { r1, r2 })
    }

    pub fn dim(&self) -> usize {
        self.r1.len()
    }
}

/// A trained RoAd adapter: effective vectors per adapted projection, keyed
/// "blocks.<i>.<proj>".
#[derive(Clone, Debug, Default)]
pub struct RoadAdapter {
    pub per_proj: BTreeMap<String, RoadVectors>,
}

impl RoadAdapter {
    pub fn identity(cfg: &ModelConfigInfo) -> RoadAdapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (_, d_out) = proj_dims(cfg, proj);
                per_proj.insert(format!("blocks.{i}.{proj}"), RoadVectors::identity(d_out));
            }
        }
        RoadAdapter { per_proj }
    }

    /// Random small rotations (used by serving benchmarks where only the
    /// *cost* of heterogeneous adapters matters, not trained quality).
    pub fn random(cfg: &ModelConfigInfo, rng: &mut Rng, scale: f32) -> RoadAdapter {
        let mut a = RoadAdapter::identity(cfg);
        for vecs in a.per_proj.values_mut() {
            let d = vecs.dim();
            let theta: Vec<f32> = (0..d / 2).map(|_| rng.normal() * scale).collect();
            let alpha: Vec<f32> = (0..d / 2).map(|_| 1.0 + rng.normal() * 0.02).collect();
            *vecs = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        }
        a
    }

    /// Build from a trainer's flat trainable tensors
    /// ("blocks.i.proj.theta"/".alpha").
    pub fn from_trainable(
        variant: usize,
        named: &[(String, HostTensor)],
    ) -> Result<RoadAdapter> {
        let mut per_proj = BTreeMap::new();
        let mut thetas: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        let mut alphas: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for (name, t) in named {
            if let Some(base) = name.strip_suffix(".theta") {
                thetas.insert(base.to_string(), t.as_f32());
            } else if let Some(base) = name.strip_suffix(".alpha") {
                alphas.insert(base.to_string(), t.as_f32());
            }
        }
        for (base, th) in &thetas {
            let al = alphas
                .get(base)
                .ok_or_else(|| anyhow!("theta without alpha for {base}"))?;
            per_proj.insert(base.clone(), RoadVectors::from_theta_alpha(variant, th, al)?);
        }
        if per_proj.is_empty() {
            bail!("no road trainables found");
        }
        Ok(RoadAdapter { per_proj })
    }

    /// Subspace composition (paper §4.3 / Fig 5): take 2×2 blocks with index
    /// < split_blocks from `a`, the rest from `b`.  Disjoint blocks are
    /// orthogonal subspaces, so both tasks' rotations coexist in one R.
    pub fn compose(a: &RoadAdapter, b: &RoadAdapter, split_frac: f32) -> Result<RoadAdapter> {
        if !split_frac.is_finite() {
            bail!("split_frac must be finite, got {split_frac}");
        }
        let mut per_proj = BTreeMap::new();
        for (key, va) in &a.per_proj {
            let vb = b
                .per_proj
                .get(key)
                .ok_or_else(|| anyhow!("composition: {key} missing from second adapter"))?;
            let d = va.dim();
            if vb.dim() != d {
                bail!("composition dim mismatch at {key}");
            }
            let split = subspace_split(d, split_frac);
            let mut r1 = va.r1.clone();
            let mut r2 = va.r2.clone();
            r1[split..].copy_from_slice(&vb.r1[split..]);
            r2[split..].copy_from_slice(&vb.r2[split..]);
            per_proj.insert(key.clone(), RoadVectors { r1, r2 });
        }
        Ok(RoadAdapter { per_proj })
    }
}

/// Element index where the composed subspace boundary falls: `split_frac`
/// of the `d/2` rotation blocks (rounded to the nearest block, ties
/// down), times two elements per block.  Always even and within `[0, d]`.
///
/// Rounding happens once, in f64, on the *block count* — the earlier
/// `((d / 2) as f32 * split_frac) as usize` formulation both truncated
/// (0.7·10 blocks → 6, biased low by f32 representation) and lost integer
/// precision for d/2 beyond f32's 24-bit mantissa.  Ties round *down*
/// (`ceil(x - 0.5)`) so that `split_frac = 0.5` over an odd block count
/// lands on the same `n_blocks / 2` boundary as the trainer's half mask
/// ([`crate::compose::half_mask_sized`]) — composed halves take exactly
/// the blocks each task trained.
pub fn subspace_split(d: usize, split_frac: f32) -> usize {
    let half = d / 2;
    let x = split_frac.clamp(0.0, 1.0) as f64 * half as f64;
    let blocks = (x - 0.5).ceil().max(0.0) as usize;
    blocks.min(half) * 2
}

/// A trained LoRA adapter (the unmerged-serving baseline of Figure 4).
#[derive(Clone, Debug, Default)]
pub struct LoraAdapter {
    pub per_proj: BTreeMap<String, LoraMats>,
}

#[derive(Clone, Debug)]
pub struct LoraMats {
    pub lb: Vec<f32>, // [d_in, r]
    pub la: Vec<f32>, // [r, d_out]
    pub rank: usize,
    pub d_in: usize,
    pub d_out: usize,
}

impl LoraAdapter {
    pub fn zeros(cfg: &ModelConfigInfo) -> LoraAdapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (d_in, d_out) = proj_dims(cfg, proj);
                per_proj.insert(
                    format!("blocks.{i}.{proj}"),
                    LoraMats {
                        lb: vec![0.0; d_in * cfg.lora_rank],
                        la: vec![0.0; cfg.lora_rank * d_out],
                        rank: cfg.lora_rank,
                        d_in,
                        d_out,
                    },
                );
            }
        }
        LoraAdapter { per_proj }
    }

    pub fn random(cfg: &ModelConfigInfo, rng: &mut Rng, scale: f32) -> LoraAdapter {
        let mut a = LoraAdapter::zeros(cfg);
        for m in a.per_proj.values_mut() {
            let s_in = scale / (m.d_in as f32).sqrt();
            m.lb = rng.normal_vec(m.d_in * m.rank, s_in);
            m.la = rng.normal_vec(m.rank * m.d_out, scale / (m.rank as f32).sqrt());
        }
        a
    }

    pub fn from_trainable(named: &[(String, HostTensor)]) -> Result<LoraAdapter> {
        let mut lbs: BTreeMap<String, HostTensor> = BTreeMap::new();
        let mut las: BTreeMap<String, HostTensor> = BTreeMap::new();
        for (name, t) in named {
            if let Some(base) = name.strip_suffix(".lb") {
                lbs.insert(base.to_string(), t.clone());
            } else if let Some(base) = name.strip_suffix(".la") {
                las.insert(base.to_string(), t.clone());
            }
        }
        let mut per_proj = BTreeMap::new();
        for (base, lb) in &lbs {
            let la = las.get(base).ok_or_else(|| anyhow!("lb without la at {base}"))?;
            per_proj.insert(
                base.clone(),
                LoraMats {
                    d_in: lb.shape[0],
                    rank: lb.shape[1],
                    d_out: la.shape[1],
                    lb: lb.as_f32(),
                    la: la.as_f32(),
                },
            );
        }
        if per_proj.is_empty() {
            bail!("no lora trainables found");
        }
        Ok(LoraAdapter { per_proj })
    }
}

/// (IA)³ scaling adapter.
#[derive(Clone, Debug, Default)]
pub struct Ia3Adapter {
    pub per_proj: BTreeMap<String, Vec<f32>>,
}

impl Ia3Adapter {
    pub fn identity(cfg: &ModelConfigInfo) -> Ia3Adapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (_, d_out) = proj_dims(cfg, proj);
                per_proj.insert(format!("blocks.{i}.{proj}"), vec![1.0; d_out]);
            }
        }
        Ia3Adapter { per_proj }
    }
}

/// Any trained adapter.
#[derive(Clone, Debug)]
pub enum Adapter {
    Road(RoadAdapter),
    Lora(LoraAdapter),
    Ia3(Ia3Adapter),
}

impl Adapter {
    pub fn mode(&self) -> &'static str {
        match self {
            Adapter::Road(_) => "road",
            Adapter::Lora(_) => "lora",
            Adapter::Ia3(_) => "ia3",
        }
    }
}

/// Bank of adapter slots matching the HLO bank inputs: per bank key a
/// [n_slots, ...] tensor.  Slot 0 is pinned to identity so unoccupied
/// decode lanes are no-ops.
pub struct AdapterBank {
    pub mode: String,
    pub n_slots: usize,
    /// bank key ("blocks.i.proj.r1" / ".lb" / ...) -> stacked tensor.
    pub tensors: BTreeMap<String, HostTensor>,
    pub dirty: bool,
}

impl AdapterBank {
    pub fn new(cfg: &ModelConfigInfo, mode: &str, n_slots: usize) -> Result<AdapterBank> {
        let mut tensors = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (d_in, d_out) = proj_dims(cfg, proj);
                let key = format!("blocks.{i}.{proj}");
                match mode {
                    "road" => {
                        let mut r1 = HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32);
                        for s in 0..n_slots {
                            r1.write_f32_range(s * d_out, &vec![1.0; d_out]);
                        }
                        tensors.insert(format!("{key}.r1"), r1);
                        tensors.insert(
                            format!("{key}.r2"),
                            HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32),
                        );
                    }
                    "lora" => {
                        tensors.insert(
                            format!("{key}.lb"),
                            HostTensor::zeros(
                                vec![n_slots, d_in, cfg.lora_rank],
                                crate::tensor::DType::F32,
                            ),
                        );
                        tensors.insert(
                            format!("{key}.la"),
                            HostTensor::zeros(
                                vec![n_slots, cfg.lora_rank, d_out],
                                crate::tensor::DType::F32,
                            ),
                        );
                    }
                    "ia3" => {
                        let mut s_t =
                            HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32);
                        for s in 0..n_slots {
                            s_t.write_f32_range(s * d_out, &vec![1.0; d_out]);
                        }
                        tensors.insert(format!("{key}.s"), s_t);
                    }
                    "base" => {}
                    _ => bail!("unknown adapter mode {mode}"),
                }
            }
        }
        Ok(AdapterBank { mode: mode.to_string(), n_slots, tensors, dirty: true })
    }

    /// Install an adapter into bank slot `slot`.
    pub fn set_slot(&mut self, slot: usize, adapter: &Adapter) -> Result<()> {
        if slot >= self.n_slots {
            bail!("slot {slot} out of range ({})", self.n_slots);
        }
        match (adapter, self.mode.as_str()) {
            (Adapter::Road(a), "road") => {
                for (key, vecs) in &a.per_proj {
                    let d = vecs.dim();
                    self.tensors
                        .get_mut(&format!("{key}.r1"))
                        .ok_or_else(|| anyhow!("bank missing {key}.r1"))?
                        .write_f32_range(slot * d, &vecs.r1);
                    self.tensors
                        .get_mut(&format!("{key}.r2"))
                        .ok_or_else(|| anyhow!("bank missing {key}.r2"))?
                        .write_f32_range(slot * d, &vecs.r2);
                }
            }
            (Adapter::Lora(a), "lora") => {
                for (key, m) in &a.per_proj {
                    self.tensors
                        .get_mut(&format!("{key}.lb"))
                        .ok_or_else(|| anyhow!("bank missing {key}.lb"))?
                        .write_f32_range(slot * m.d_in * m.rank, &m.lb);
                    self.tensors
                        .get_mut(&format!("{key}.la"))
                        .ok_or_else(|| anyhow!("bank missing {key}.la"))?
                        .write_f32_range(slot * m.rank * m.d_out, &m.la);
                }
            }
            (Adapter::Ia3(a), "ia3") => {
                for (key, s) in &a.per_proj {
                    self.tensors
                        .get_mut(&format!("{key}.s"))
                        .ok_or_else(|| anyhow!("bank missing {key}.s"))?
                        .write_f32_range(slot * s.len(), s);
                }
            }
            (a, m) => bail!("adapter mode {} incompatible with bank mode {m}", a.mode()),
        }
        self.dirty = true;
        Ok(())
    }
}

/// Registry mapping user-visible adapter names to bank slots.
///
/// Slot 0 is reserved for identity (requests without an adapter).
pub struct AdapterRegistry {
    pub bank: AdapterBank,
    by_name: BTreeMap<String, usize>,
    next_slot: usize,
}

impl AdapterRegistry {
    pub fn new(bank: AdapterBank) -> AdapterRegistry {
        AdapterRegistry { bank, by_name: BTreeMap::new(), next_slot: 1 }
    }

    /// Register a named adapter; returns its slot id.
    pub fn register(&mut self, name: &str, adapter: &Adapter) -> Result<usize> {
        if let Some(&slot) = self.by_name.get(name) {
            self.bank.set_slot(slot, adapter)?;
            return Ok(slot);
        }
        if self.next_slot >= self.bank.n_slots {
            bail!(
                "adapter bank full ({} slots); unregister something first",
                self.bank.n_slots
            );
        }
        let slot = self.next_slot;
        self.bank.set_slot(slot, adapter)?;
        self.by_name.insert(name.to_string(), slot);
        self.next_slot += 1;
        Ok(slot)
    }

    pub fn slot_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.bank.n_slots - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfigInfo {
        ModelConfigInfo {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 12,
            max_seq: 16,
            head_dim: 4,
            n_adapters: 4,
            lora_rank: 2,
        }
    }

    #[test]
    fn variant1_identity() {
        let v = RoadVectors::from_theta_alpha(1, &[0.0; 4], &[1.0; 4]).unwrap();
        assert_eq!(v.r1, vec![1.0; 8]);
        assert_eq!(v.r2, vec![0.0; 8]);
    }

    #[test]
    fn variant2_matches_variant1_when_shared(){
        let theta = [0.3f32, -0.2];
        let alpha = [1.1f32, 0.9];
        let v1 = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let t2 = [0.3f32, 0.3, -0.2, -0.2];
        let a2 = [1.1f32, 1.1, 0.9, 0.9];
        let v2 = RoadVectors::from_theta_alpha(2, &t2, &a2).unwrap();
        for i in 0..4 {
            assert!((v1.r1[i] - v2.r1[i]).abs() < 1e-6);
            assert!((v1.r2[i] - v2.r2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn compose_takes_halves() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(0);
        let a = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let c = RoadAdapter::compose(&a, &b, 0.5).unwrap();
        for (key, vc) in &c.per_proj {
            let va = &a.per_proj[key];
            let vb = &b.per_proj[key];
            let d = vc.dim();
            assert_eq!(&vc.r1[..d / 2], &va.r1[..d / 2]);
            assert_eq!(&vc.r1[d / 2..], &vb.r1[d / 2..]);
            assert_eq!(&vc.r2[..d / 2], &va.r2[..d / 2]);
            assert_eq!(&vc.r2[d / 2..], &vb.r2[d / 2..]);
        }
    }

    #[test]
    fn subspace_split_edges() {
        // 0.0 → everything from b; 1.0 → everything from a.
        assert_eq!(subspace_split(8, 0.0), 0);
        assert_eq!(subspace_split(8, 1.0), 8);
        // Out-of-range fractions clamp instead of over/underflowing.
        assert_eq!(subspace_split(8, -0.5), 0);
        assert_eq!(subspace_split(8, 1.5), 8);
        // Odd block counts: nearest block, ties down — 0.5 must land on the
        // trainer's `n_blocks / 2` mask boundary so composed halves take
        // exactly the blocks each task trained.
        assert_eq!(subspace_split(6, 0.5), 2); // 3 blocks · 0.5 = 1.5 → 1 block
        assert_eq!(subspace_split(10, 0.5), 4); // 5 blocks · 0.5 = 2.5 → 2 blocks
        for d in [6usize, 10, 14, 22] {
            assert_eq!(subspace_split(d, 0.5), (d / 2 / 2) * 2, "mask alignment at d={d}");
        }
        // Non-tie fractions round to nearest (the old f32 formulation
        // truncated: 0.7 · 10 blocks gave 6).
        assert_eq!(subspace_split(20, 0.7), 14);
        assert_eq!(subspace_split(10, 0.49), 4);
        // Large d: 2^25 + 2 elements has d/2 beyond f32's mantissa; the f32
        // formulation misplaced the boundary, the f64 one does not.
        let d = (1usize << 25) + 2;
        let half = d / 2;
        assert_eq!(subspace_split(d, 1.0), d);
        assert_eq!(subspace_split(d, 0.25), (half / 4) * 2);
        // Every result is even and bounded by d.
        for frac in [0.0f32, 0.1, 0.3333, 0.5, 0.9999, 1.0] {
            let s = subspace_split(14, frac);
            assert_eq!(s % 2, 0);
            assert!(s <= 14);
        }
    }

    #[test]
    fn compose_edge_fractions_take_whole_adapter() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(11);
        let a = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let all_b = RoadAdapter::compose(&a, &b, 0.0).unwrap();
        let all_a = RoadAdapter::compose(&a, &b, 1.0).unwrap();
        for key in a.per_proj.keys() {
            assert_eq!(all_b.per_proj[key], b.per_proj[key]);
            assert_eq!(all_a.per_proj[key], a.per_proj[key]);
        }
        assert!(RoadAdapter::compose(&a, &b, f32::NAN).is_err());
    }

    #[test]
    fn bank_slot0_identity_and_set() {
        let cfg = tiny_cfg();
        let mut bank = AdapterBank::new(&cfg, "road", 4).unwrap();
        let r1 = bank.tensors.get("blocks.0.wq.r1").unwrap();
        assert_eq!(r1.read_f32_range(0, 8), vec![1.0; 8]);
        let mut rng = Rng::seed_from(1);
        let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3));
        bank.set_slot(2, &a).unwrap();
        let r1 = bank.tensors.get("blocks.0.wq.r1").unwrap();
        // slot 0 untouched, slot 2 changed
        assert_eq!(r1.read_f32_range(0, 8), vec![1.0; 8]);
        assert_ne!(r1.read_f32_range(16, 8), vec![1.0; 8]);
    }

    #[test]
    fn registry_assigns_and_reuses_slots() {
        let cfg = tiny_cfg();
        let bank = AdapterBank::new(&cfg, "road", 4).unwrap();
        let mut reg = AdapterRegistry::new(bank);
        let mut rng = Rng::seed_from(2);
        let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3));
        let s1 = reg.register("user-a", &a).unwrap();
        let s2 = reg.register("user-b", &a).unwrap();
        assert_eq!((s1, s2), (1, 2));
        assert_eq!(reg.register("user-a", &a).unwrap(), 1); // update in place
        assert_eq!(reg.slot_of("user-b"), Some(2));
        let _ = reg.register("user-c", &a).unwrap();
        assert!(reg.register("user-d", &a).is_err()); // bank full (slot 0 reserved)
    }

    #[test]
    fn mode_mismatch_rejected() {
        let cfg = tiny_cfg();
        let mut bank = AdapterBank::new(&cfg, "road", 2).unwrap();
        let l = Adapter::Lora(LoraAdapter::zeros(&cfg));
        assert!(bank.set_slot(1, &l).is_err());
    }
}
