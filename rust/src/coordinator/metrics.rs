//! Serving metrics: throughput, TTFT, per-token and end-to-end latency,
//! queueing delay/depth, step-time accounting split by phase, KV-cache
//! transfer counters, adapter-bank paging counters
//! (hits/misses/evictions and host-to-device upload bytes), and the
//! streaming-lifecycle counters (cancellations, deadline sheds).
//!
//! Latency clocks start at `Engine::submit` (the request's
//! `submitted_at` stamp), so TTFT and e2e include time spent waiting in
//! the admission queue — what a client actually observes — not just
//! compute after admission.
//!
//! The live [`Metrics`] struct is engine-thread-only (it owns histogram
//! buffers); everything that crosses a channel is a [`MetricsSnapshot`] —
//! a plain serializable value with the rendered reports as methods and a
//! JSON form for the NDJSON `stats` op.

use std::time::{Duration, Instant};

use crate::util::clock::Clock;
use crate::util::json::{self, Json};
use crate::util::stats::{LatencyRecorder, Summary};
use crate::util::table::kv_table;

#[derive(Default)]
pub struct Metrics {
    /// Time source for the `started`/`finished` stamps and live `wall()`
    /// reads.  The engine installs its own clock here, so a manual-clock
    /// run reports exact virtual wall time (deterministic snapshots).
    pub clock: Clock,
    pub requests_completed: usize,
    /// Requests cancelled after submission (explicit `cancel`, dropped
    /// stream handles) — their decode slot and bank pin were reclaimed.
    pub requests_cancelled: usize,
    /// Requests that blew their deadline: shed from the queue at admission
    /// or reaped from a decode slot between steps.
    pub deadline_shed: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    pub prefill_batches: usize,
    pub decode_steps: usize,
    /// Submit → first generated token (queue wait included).
    pub ttft: LatencyRecorder,
    /// Submit → request finished (queue wait included).
    pub e2e: LatencyRecorder,
    /// Submit → admission into a prefill batch (the queueing component of
    /// ttft/e2e, recorded separately so saturation is visible).
    pub queue_wait: LatencyRecorder,
    /// Admission-queue depth sampled at each scheduler step (a depth
    /// histogram, not a latency — samples are request counts).
    pub queue_depth: LatencyRecorder,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// Full K/V cache device→host transfers.  Device-resident decode:
    /// admission-time materializations only (tracks prefill batches, not
    /// decode steps).  `kv_host_roundtrip` baseline: one per decode step.
    pub kv_host_syncs: usize,
    /// Full K/V cache host→device transfers (mirror of `kv_host_syncs`:
    /// re-uploads after materialization, or per-step in baseline mode).
    pub kv_uploads: usize,
    /// Admissions whose adapter was already device-resident.
    pub bank_hits: usize,
    /// Admissions that had to page their adapter into a bank slot.
    pub bank_misses: usize,
    /// Page-ins that displaced another resident adapter (LRU victim).
    pub bank_evictions: usize,
    /// Host→device bytes attributed to adapter-bank content (per-slot rows
    /// on the paged path, full tensors on the whole-bank baseline).
    pub bank_upload_bytes: usize,
    /// Whole-bank uploads (first upload, or every change in baseline mode).
    pub bank_full_uploads: usize,
    /// Per-slot row tensors staged on the paged upload path.
    pub bank_staged_rows: usize,
    /// Submit → admission for requests that suffered a bank miss (the
    /// queue-wait cost of paging, recorded separately from `queue_wait`).
    pub paged_wait: LatencyRecorder,
    /// KV blocks reused from the shared-prefix cache at admission
    /// (refcounted, not copied in the pool — the prefill work they replace
    /// is `kv_prefill_tokens_saved`).
    pub kv_block_hits: usize,
    /// KV blocks privately allocated at admission (cold footprint).
    pub kv_block_misses: usize,
    /// Cached prefix blocks LRU-evicted to satisfy an allocation.
    pub kv_block_evictions: usize,
    /// Private blocks promoted into the shared-prefix cache after a cold
    /// prefill.
    pub kv_blocks_published: usize,
    /// Admissions that reused at least one cached prefix block.
    pub kv_prefix_hits: usize,
    /// Prompt tokens whose prefill was skipped via cached prefix blocks.
    pub kv_prefill_tokens_saved: usize,
    /// Prompt tokens that actually went through a prefill executable
    /// (cold lanes only; compare against `prompt_tokens`).
    pub prefill_lane_tokens: usize,
    /// Requests that *entered* a stall at the KV-block admission gate
    /// (pool could not cover their footprint).  Counts stall transitions,
    /// not per-iteration retries: one stuck request is one stall however
    /// many scheduler ticks it waits.
    pub kv_admission_stalls: usize,
    /// Requests that entered a stall at the adapter-bank gate (every
    /// pageable slot pinned by in-flight lanes).  Transition-counted like
    /// `kv_admission_stalls`.
    pub bank_admission_stalls: usize,
    /// Prompt tokens prefilled through the chunked-prefill entry (mixed
    /// steps; compare against `prefill_lane_tokens` for the bucketed
    /// path).
    pub chunk_prefill_tokens: usize,
    /// Gap between consecutive sampled tokens on one lane, as the
    /// request's consumer sees it (inter-token latency).
    pub itl: LatencyRecorder,
    /// Gap between consecutive decode steps while lanes are active — an
    /// atomic prefill wedged between steps is exactly what widens this.
    pub decode_stall: LatencyRecorder,
    /// Low-water mark of free pool blocks (memory headroom under load).
    pub kv_blocks_free_min: usize,
    /// High-water mark of outstanding shared-prefix refcounts.
    pub kv_shared_refs_peak: usize,
    /// Submit → first token for prefix-hit admissions only (the TTFT the
    /// shared-prefix cache buys, vs the all-requests `ttft`).
    pub prefix_hit_ttft: LatencyRecorder,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    /// Metrics whose time stamps come from `clock` — the engine passes its
    /// own clock so a simulated run reports virtual time.
    pub fn with_clock(clock: Clock) -> Metrics {
        Metrics { clock, ..Metrics::default() }
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(self.clock.now());
        }
    }

    pub fn stop(&mut self) {
        self.finished = Some(self.clock.now());
    }

    pub fn wall(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => (f - s).as_secs_f64(),
            (Some(s), None) => self.clock.now().saturating_duration_since(s).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of wall time — Figure 4's y-axis.
    pub fn throughput(&self) -> f64 {
        let w = self.wall();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttft.summary()
    }

    pub fn e2e_summary(&self) -> Summary {
        self.e2e.summary()
    }

    pub fn queue_wait_summary(&self) -> Summary {
        self.queue_wait.summary()
    }

    pub fn queue_depth_summary(&self) -> Summary {
        self.queue_depth.summary()
    }

    pub fn paged_wait_summary(&self) -> Summary {
        self.paged_wait.summary()
    }

    /// Freeze the current state into a plain serializable value — the only
    /// form that crosses the engine-thread channel boundary.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_completed: self.requests_completed,
            requests_cancelled: self.requests_cancelled,
            deadline_shed: self.deadline_shed,
            tokens_generated: self.tokens_generated,
            prompt_tokens: self.prompt_tokens,
            prefill_batches: self.prefill_batches,
            decode_steps: self.decode_steps,
            wall_secs: self.wall(),
            throughput: self.throughput(),
            ttft: self.ttft_summary(),
            e2e: self.e2e_summary(),
            queue_wait: self.queue_wait_summary(),
            paged_wait: self.paged_wait_summary(),
            queue_depth: self.queue_depth_summary(),
            prefill_secs: self.prefill_time.as_secs_f64(),
            decode_secs: self.decode_time.as_secs_f64(),
            kv_host_syncs: self.kv_host_syncs,
            kv_uploads: self.kv_uploads,
            bank_hits: self.bank_hits,
            bank_misses: self.bank_misses,
            bank_evictions: self.bank_evictions,
            bank_upload_bytes: self.bank_upload_bytes,
            bank_full_uploads: self.bank_full_uploads,
            bank_staged_rows: self.bank_staged_rows,
            kv_block_hits: self.kv_block_hits,
            kv_block_misses: self.kv_block_misses,
            kv_block_evictions: self.kv_block_evictions,
            kv_blocks_published: self.kv_blocks_published,
            kv_prefix_hits: self.kv_prefix_hits,
            kv_prefill_tokens_saved: self.kv_prefill_tokens_saved,
            prefill_lane_tokens: self.prefill_lane_tokens,
            kv_admission_stalls: self.kv_admission_stalls,
            bank_admission_stalls: self.bank_admission_stalls,
            chunk_prefill_tokens: self.chunk_prefill_tokens,
            kv_blocks_free_min: self.kv_blocks_free_min,
            kv_shared_refs_peak: self.kv_shared_refs_peak,
            prefix_hit_ttft: self.prefix_hit_ttft.summary(),
            itl: self.itl.summary(),
            decode_stall: self.decode_stall.summary(),
        }
    }

    /// One-line rendering of [`Metrics::snapshot`].
    pub fn report(&self) -> String {
        self.snapshot().report()
    }

    /// Two-column table rendering of [`Metrics::snapshot`].
    pub fn report_table(&self) -> String {
        self.snapshot().report_table()
    }
}

/// Frozen, serializable metrics value: what `EngineClient::stats` returns
/// and what the NDJSON `stats` op puts on the wire ([`MetricsSnapshot::to_json`]).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests_completed: usize,
    pub requests_cancelled: usize,
    pub deadline_shed: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    pub prefill_batches: usize,
    pub decode_steps: usize,
    pub wall_secs: f64,
    pub throughput: f64,
    pub ttft: Summary,
    pub e2e: Summary,
    pub queue_wait: Summary,
    pub paged_wait: Summary,
    pub queue_depth: Summary,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub kv_host_syncs: usize,
    pub kv_uploads: usize,
    pub bank_hits: usize,
    pub bank_misses: usize,
    pub bank_evictions: usize,
    pub bank_upload_bytes: usize,
    pub bank_full_uploads: usize,
    pub bank_staged_rows: usize,
    pub kv_block_hits: usize,
    pub kv_block_misses: usize,
    pub kv_block_evictions: usize,
    pub kv_blocks_published: usize,
    pub kv_prefix_hits: usize,
    pub kv_prefill_tokens_saved: usize,
    pub prefill_lane_tokens: usize,
    pub kv_admission_stalls: usize,
    pub bank_admission_stalls: usize,
    pub chunk_prefill_tokens: usize,
    pub kv_blocks_free_min: usize,
    pub kv_shared_refs_peak: usize,
    pub prefix_hit_ttft: Summary,
    pub itl: Summary,
    pub decode_stall: Summary,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} cancelled={} shed={} tokens={} wall={:.2}s throughput={:.1} tok/s \
             prefill_batches={} decode_steps={} \
             ttft(p50/p90)={:.1}/{:.1}ms e2e(p50/p90)={:.1}/{:.1}ms \
             queue_wait(p50/p90)={:.1}/{:.1}ms queue_depth(p50/max)={:.0}/{:.0} \
             prefill={:.2}s decode={:.2}s kv_dl/ul={}/{} \
             bank(h/m/e)={}/{}/{} bank_upload={}B \
             kvblk(h/m/e)={}/{}/{} prefix_hits={} prefill_saved={}",
            self.requests_completed,
            self.requests_cancelled,
            self.deadline_shed,
            self.tokens_generated,
            self.wall_secs,
            self.throughput,
            self.prefill_batches,
            self.decode_steps,
            self.ttft.p50 / 1e3,
            self.ttft.p90 / 1e3,
            self.e2e.p50 / 1e3,
            self.e2e.p90 / 1e3,
            self.queue_wait.p50 / 1e3,
            self.queue_wait.p90 / 1e3,
            self.queue_depth.p50,
            self.queue_depth.max,
            self.prefill_secs,
            self.decode_secs,
            self.kv_host_syncs,
            self.kv_uploads,
            self.bank_hits,
            self.bank_misses,
            self.bank_evictions,
            self.bank_upload_bytes,
            self.kv_block_hits,
            self.kv_block_misses,
            self.kv_block_evictions,
            self.kv_prefix_hits,
            self.kv_prefill_tokens_saved,
        )
    }

    /// Full serving report as a two-column markdown table (`road serve
    /// --stats`), including the bank paging counters the one-line
    /// [`MetricsSnapshot::report`] summarizes.
    pub fn report_table(&self) -> String {
        let (t, e, qw, pw, qd) =
            (&self.ttft, &self.e2e, &self.queue_wait, &self.paged_wait, &self.queue_depth);
        let ph = &self.prefix_hit_ttft;
        kv_table(&[
            ("requests completed", self.requests_completed.to_string()),
            ("requests cancelled", self.requests_cancelled.to_string()),
            ("deadline shed", self.deadline_shed.to_string()),
            ("tokens generated", self.tokens_generated.to_string()),
            ("throughput (tok/s)", format!("{:.1}", self.throughput)),
            ("prefill batches", self.prefill_batches.to_string()),
            ("decode steps", self.decode_steps.to_string()),
            ("ttft p50/p90 (ms)", format!("{:.1} / {:.1}", t.p50 / 1e3, t.p90 / 1e3)),
            ("e2e p50/p90 (ms)", format!("{:.1} / {:.1}", e.p50 / 1e3, e.p90 / 1e3)),
            ("queue wait p50/p90 (ms)", format!("{:.1} / {:.1}", qw.p50 / 1e3, qw.p90 / 1e3)),
            (
                "paged-adapter wait p50/p90 (ms)",
                format!("{:.1} / {:.1}", pw.p50 / 1e3, pw.p90 / 1e3),
            ),
            ("queue depth p50/max", format!("{:.0} / {:.0}", qd.p50, qd.max)),
            ("kv downloads/uploads", format!("{} / {}", self.kv_host_syncs, self.kv_uploads)),
            ("bank hits", self.bank_hits.to_string()),
            ("bank misses", self.bank_misses.to_string()),
            ("bank evictions", self.bank_evictions.to_string()),
            ("bank upload bytes", self.bank_upload_bytes.to_string()),
            ("bank full uploads", self.bank_full_uploads.to_string()),
            ("bank staged rows", self.bank_staged_rows.to_string()),
            ("kv block hits", self.kv_block_hits.to_string()),
            ("kv block misses", self.kv_block_misses.to_string()),
            ("kv block evictions", self.kv_block_evictions.to_string()),
            ("kv blocks published", self.kv_blocks_published.to_string()),
            ("kv prefix hits", self.kv_prefix_hits.to_string()),
            ("kv prefill tokens saved", self.kv_prefill_tokens_saved.to_string()),
            ("prefill lane tokens", self.prefill_lane_tokens.to_string()),
            ("chunk prefill tokens", self.chunk_prefill_tokens.to_string()),
            ("kv admission stalls", self.kv_admission_stalls.to_string()),
            ("bank admission stalls", self.bank_admission_stalls.to_string()),
            ("kv blocks free (min)", self.kv_blocks_free_min.to_string()),
            ("kv shared refs (peak)", self.kv_shared_refs_peak.to_string()),
            (
                "prefix-hit ttft p50/p90 (ms)",
                format!("{:.1} / {:.1}", ph.p50 / 1e3, ph.p90 / 1e3),
            ),
            (
                "itl p50/p99 (ms)",
                format!("{:.1} / {:.1}", self.itl.p50 / 1e3, self.itl.p99 / 1e3),
            ),
            (
                "decode stall p50/p99 (ms)",
                format!("{:.1} / {:.1}", self.decode_stall.p50 / 1e3, self.decode_stall.p99 / 1e3),
            ),
        ])
    }

    /// JSON form for the wire (`{"op":"stats"}` on the NDJSON front end).
    pub fn to_json(&self) -> Json {
        let summary = |s: &Summary| {
            json::obj(vec![
                ("n", json::num(s.n as f64)),
                ("mean_us", json::num(s.mean)),
                ("p50_us", json::num(s.p50)),
                ("p90_us", json::num(s.p90)),
                ("p99_us", json::num(s.p99)),
                ("max_us", json::num(s.max)),
            ])
        };
        json::obj(vec![
            ("requests_completed", json::num(self.requests_completed as f64)),
            ("requests_cancelled", json::num(self.requests_cancelled as f64)),
            ("deadline_shed", json::num(self.deadline_shed as f64)),
            ("tokens_generated", json::num(self.tokens_generated as f64)),
            ("prompt_tokens", json::num(self.prompt_tokens as f64)),
            ("prefill_batches", json::num(self.prefill_batches as f64)),
            ("decode_steps", json::num(self.decode_steps as f64)),
            ("wall_secs", json::num(self.wall_secs)),
            ("throughput_tok_s", json::num(self.throughput)),
            ("ttft", summary(&self.ttft)),
            ("e2e", summary(&self.e2e)),
            ("queue_wait", summary(&self.queue_wait)),
            ("paged_wait", summary(&self.paged_wait)),
            ("queue_depth", summary(&self.queue_depth)),
            ("kv_host_syncs", json::num(self.kv_host_syncs as f64)),
            ("kv_uploads", json::num(self.kv_uploads as f64)),
            ("bank_hits", json::num(self.bank_hits as f64)),
            ("bank_misses", json::num(self.bank_misses as f64)),
            ("bank_evictions", json::num(self.bank_evictions as f64)),
            ("bank_upload_bytes", json::num(self.bank_upload_bytes as f64)),
            ("bank_full_uploads", json::num(self.bank_full_uploads as f64)),
            ("bank_staged_rows", json::num(self.bank_staged_rows as f64)),
            ("kv_block_hits", json::num(self.kv_block_hits as f64)),
            ("kv_block_misses", json::num(self.kv_block_misses as f64)),
            ("kv_block_evictions", json::num(self.kv_block_evictions as f64)),
            ("kv_blocks_published", json::num(self.kv_blocks_published as f64)),
            ("kv_prefix_hits", json::num(self.kv_prefix_hits as f64)),
            ("kv_prefill_tokens_saved", json::num(self.kv_prefill_tokens_saved as f64)),
            ("prefill_lane_tokens", json::num(self.prefill_lane_tokens as f64)),
            ("chunk_prefill_tokens", json::num(self.chunk_prefill_tokens as f64)),
            ("kv_admission_stalls", json::num(self.kv_admission_stalls as f64)),
            ("bank_admission_stalls", json::num(self.bank_admission_stalls as f64)),
            ("kv_blocks_free_min", json::num(self.kv_blocks_free_min as f64)),
            ("kv_shared_refs_peak", json::num(self.kv_shared_refs_peak as f64)),
            ("prefix_hit_ttft", summary(&self.prefix_hit_ttft)),
            ("itl", summary(&self.itl)),
            ("decode_stall", summary(&self.decode_stall)),
        ])
    }

    /// Merge per-replica snapshots into one fleet-level view — what the
    /// NDJSON `stats` op reports as the aggregate next to the per-replica
    /// snapshots ([`crate::coordinator::FleetStats`]).
    ///
    /// Counters sum.  `wall_secs` is the max (replicas run concurrently,
    /// so fleet wall time is the longest replica's, not the sum) and
    /// `throughput` is recomputed from the merged tokens over that wall.
    /// CPU-time accumulators (`prefill_secs`/`decode_secs`) sum — they are
    /// work, not wall.  `kv_blocks_free_min` and `kv_shared_refs_peak` sum
    /// per-replica extrema: each replica owns a separate pool, so the sums
    /// read as "fleet-wide headroom with every replica at its own worst
    /// moment".  Latency summaries merge via
    /// [`crate::util::stats::merge_summaries`] (percentiles approximate;
    /// studies that need exact percentiles keep raw records).
    pub fn merge(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
        let merged_summary = |pick: fn(&MetricsSnapshot) -> &Summary| {
            crate::util::stats::merge_summaries(parts.iter().map(pick))
        };
        let mut out = MetricsSnapshot {
            ttft: merged_summary(|s| &s.ttft),
            e2e: merged_summary(|s| &s.e2e),
            queue_wait: merged_summary(|s| &s.queue_wait),
            paged_wait: merged_summary(|s| &s.paged_wait),
            queue_depth: merged_summary(|s| &s.queue_depth),
            prefix_hit_ttft: merged_summary(|s| &s.prefix_hit_ttft),
            itl: merged_summary(|s| &s.itl),
            decode_stall: merged_summary(|s| &s.decode_stall),
            ..MetricsSnapshot::default()
        };
        for s in parts {
            out.requests_completed += s.requests_completed;
            out.requests_cancelled += s.requests_cancelled;
            out.deadline_shed += s.deadline_shed;
            out.tokens_generated += s.tokens_generated;
            out.prompt_tokens += s.prompt_tokens;
            out.prefill_batches += s.prefill_batches;
            out.decode_steps += s.decode_steps;
            out.wall_secs = out.wall_secs.max(s.wall_secs);
            out.prefill_secs += s.prefill_secs;
            out.decode_secs += s.decode_secs;
            out.kv_host_syncs += s.kv_host_syncs;
            out.kv_uploads += s.kv_uploads;
            out.bank_hits += s.bank_hits;
            out.bank_misses += s.bank_misses;
            out.bank_evictions += s.bank_evictions;
            out.bank_upload_bytes += s.bank_upload_bytes;
            out.bank_full_uploads += s.bank_full_uploads;
            out.bank_staged_rows += s.bank_staged_rows;
            out.kv_block_hits += s.kv_block_hits;
            out.kv_block_misses += s.kv_block_misses;
            out.kv_block_evictions += s.kv_block_evictions;
            out.kv_blocks_published += s.kv_blocks_published;
            out.kv_prefix_hits += s.kv_prefix_hits;
            out.kv_prefill_tokens_saved += s.kv_prefill_tokens_saved;
            out.prefill_lane_tokens += s.prefill_lane_tokens;
            out.chunk_prefill_tokens += s.chunk_prefill_tokens;
            out.kv_admission_stalls += s.kv_admission_stalls;
            out.bank_admission_stalls += s.bank_admission_stalls;
            out.kv_blocks_free_min += s.kv_blocks_free_min;
            out.kv_shared_refs_peak += s.kv_shared_refs_peak;
        }
        out.throughput =
            if out.wall_secs > 0.0 { out.tokens_generated as f64 / out.wall_secs } else { 0.0 };
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_includes_queue_and_kv_fields() {
        let mut m = Metrics::default();
        m.queue_wait.record(Duration::from_millis(4));
        m.queue_depth.record_value(3.0);
        m.queue_depth.record_value(7.0);
        m.kv_host_syncs = 2;
        m.kv_uploads = 2;
        let r = m.report();
        assert!(r.contains("queue_wait"), "{r}");
        assert!(r.contains("queue_depth(p50/max)"), "{r}");
        assert!(r.contains("kv_dl/ul=2/2"), "{r}");
        assert!((m.queue_wait_summary().p50 - 4000.0).abs() < 1e-6);
        assert_eq!(m.queue_depth_summary().max, 7.0);
    }

    #[test]
    fn report_includes_bank_paging_counters() {
        let mut m = Metrics::default();
        m.paged_wait.record(Duration::from_millis(8));
        m.bank_hits = 10;
        m.bank_misses = 3;
        m.bank_evictions = 2;
        m.bank_upload_bytes = 4096;
        let r = m.report();
        assert!(r.contains("bank(h/m/e)=10/3/2"), "{r}");
        assert!(r.contains("bank_upload=4096B"), "{r}");
        let t = m.report_table();
        let needles = [
            "bank hits",
            "bank misses",
            "bank evictions",
            "bank upload bytes",
            "10",
            "4096",
            "paged-adapter wait",
        ];
        for needle in needles {
            assert!(t.contains(needle), "missing {needle:?} in\n{t}");
        }
    }

    #[test]
    fn snapshot_freezes_counters_and_reports_lifecycle() {
        let mut m = Metrics::default();
        m.requests_completed = 5;
        m.requests_cancelled = 2;
        m.deadline_shed = 1;
        m.tokens_generated = 40;
        m.ttft.record(Duration::from_millis(3));
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 5);
        assert_eq!(s.requests_cancelled, 2);
        assert_eq!(s.deadline_shed, 1);
        assert_eq!(s.ttft.n, 1);
        let line = s.report();
        assert!(line.contains("cancelled=2"), "{line}");
        assert!(line.contains("shed=1"), "{line}");
        let table = s.report_table();
        assert!(table.contains("requests cancelled"), "{table}");
        assert!(table.contains("deadline shed"), "{table}");
    }

    #[test]
    fn wall_time_follows_the_installed_clock() {
        let clock = crate::util::clock::Clock::manual();
        let mut m = Metrics::with_clock(clock.clone());
        m.start();
        clock.advance(Duration::from_millis(500));
        assert!((m.wall() - 0.5).abs() < 1e-12, "live wall read is virtual: {}", m.wall());
        m.stop();
        clock.advance(Duration::from_secs(9));
        assert!((m.wall() - 0.5).abs() < 1e-12, "stopped wall is frozen: {}", m.wall());
    }

    #[test]
    fn snapshot_json_is_parseable_and_complete() {
        let mut m = Metrics::default();
        m.requests_completed = 3;
        m.requests_cancelled = 1;
        m.bank_full_uploads = 2;
        m.bank_staged_rows = 9;
        m.e2e.record(Duration::from_millis(9));
        let j = m.snapshot().to_json();
        // Round-trips through the serializer (compact form is one line —
        // the NDJSON invariant).
        let compact = j.to_string_compact();
        assert!(!compact.contains('\n'), "{compact}");
        let back = Json::parse(&compact).unwrap();
        assert_eq!(back.get("requests_completed").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("requests_cancelled").unwrap().as_usize().unwrap(), 1);
        assert!(back.get("e2e").unwrap().get("p50_us").unwrap().as_f64().unwrap() > 0.0);
        // Every scalar counter the table report exposes is on the wire too.
        for key in [
            "deadline_shed",
            "tokens_generated",
            "prompt_tokens",
            "prefill_batches",
            "decode_steps",
            "kv_host_syncs",
            "kv_uploads",
            "bank_hits",
            "bank_misses",
            "bank_evictions",
            "bank_upload_bytes",
            "bank_full_uploads",
            "bank_staged_rows",
            "kv_block_hits",
            "kv_block_misses",
            "kv_block_evictions",
            "kv_blocks_published",
            "kv_prefix_hits",
            "kv_prefill_tokens_saved",
            "prefill_lane_tokens",
            "chunk_prefill_tokens",
            "kv_admission_stalls",
            "bank_admission_stalls",
            "kv_blocks_free_min",
            "kv_shared_refs_peak",
        ] {
            assert!(back.opt(key).is_some(), "stats JSON missing {key}");
        }
        assert_eq!(back.get("bank_full_uploads").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("bank_staged_rows").unwrap().as_usize().unwrap(), 9);
        assert!(back.opt("prefix_hit_ttft").is_some(), "prefix-hit TTFT histogram on the wire");
        assert!(back.opt("itl").is_some(), "inter-token latency histogram on the wire");
        assert!(back.opt("decode_stall").is_some(), "decode-stall histogram on the wire");
    }

    #[test]
    fn merge_sums_counters_maxes_wall_and_recomputes_throughput() {
        let mut a = MetricsSnapshot::default();
        a.requests_completed = 3;
        a.tokens_generated = 30;
        a.bank_upload_bytes = 1000;
        a.kv_prefix_hits = 2;
        a.kv_blocks_free_min = 5;
        a.wall_secs = 2.0;
        let mut b = MetricsSnapshot::default();
        b.requests_completed = 1;
        b.tokens_generated = 10;
        b.bank_upload_bytes = 500;
        b.kv_blocks_free_min = 7;
        b.wall_secs = 4.0;
        let m = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(m.requests_completed, 4);
        assert_eq!(m.tokens_generated, 40);
        assert_eq!(m.bank_upload_bytes, 1500);
        assert_eq!(m.kv_prefix_hits, 2);
        assert_eq!(m.kv_blocks_free_min, 12, "per-replica headroom sums");
        assert!((m.wall_secs - 4.0).abs() < 1e-12, "fleet wall is the longest replica");
        assert!((m.throughput - 10.0).abs() < 1e-9, "recomputed: 40 tok / 4 s");
    }

    #[test]
    fn merge_pools_latency_summaries_sample_weighted() {
        let mut a = Metrics::default();
        for _ in 0..3 {
            a.ttft.record(Duration::from_millis(10));
        }
        let mut b = Metrics::default();
        b.ttft.record(Duration::from_millis(50));
        let m = MetricsSnapshot::merge(&[a.snapshot(), b.snapshot()]);
        assert_eq!(m.ttft.n, 4);
        assert!((m.ttft.mean - 20_000.0).abs() < 1e-6, "weighted mean: {}", m.ttft.mean);
        assert!((m.ttft.min - 10_000.0).abs() < 1e-6);
        assert!((m.ttft.max - 50_000.0).abs() < 1e-6);
        // Merging with an empty snapshot is the identity.
        let id = MetricsSnapshot::merge(&[m.clone(), MetricsSnapshot::default()]);
        assert_eq!(id.ttft.n, m.ttft.n);
        assert!((id.ttft.mean - m.ttft.mean).abs() < 1e-9);
    }

    #[test]
    fn report_includes_kv_block_counters() {
        let mut m = Metrics::default();
        m.kv_block_hits = 6;
        m.kv_block_misses = 4;
        m.kv_block_evictions = 1;
        m.kv_prefix_hits = 3;
        m.kv_prefill_tokens_saved = 96;
        m.kv_blocks_published = 5;
        m.prefill_lane_tokens = 64;
        m.kv_admission_stalls = 2;
        m.kv_blocks_free_min = 7;
        m.kv_shared_refs_peak = 4;
        m.prefix_hit_ttft.record(Duration::from_millis(2));
        let r = m.report();
        assert!(r.contains("kvblk(h/m/e)=6/4/1"), "{r}");
        assert!(r.contains("prefix_hits=3"), "{r}");
        assert!(r.contains("prefill_saved=96"), "{r}");
        let t = m.report_table();
        for needle in [
            "kv block hits",
            "kv block misses",
            "kv block evictions",
            "kv blocks published",
            "kv prefix hits",
            "kv prefill tokens saved",
            "prefill lane tokens",
            "kv admission stalls",
            "kv blocks free (min)",
            "kv shared refs (peak)",
            "prefix-hit ttft",
        ] {
            assert!(t.contains(needle), "missing {needle:?} in\n{t}");
        }
        let s = m.snapshot();
        assert_eq!(s.kv_block_hits, 6);
        assert_eq!(s.kv_blocks_free_min, 7);
        assert_eq!(s.prefix_hit_ttft.n, 1);
    }
}
