//! The arithmetic suite: four evaluation tasks standing in for AQuA /
//! GSM8K / MAWPS / SVAMP (Table 4), trained on a Math10K-analogue mix.
//!
//! Following the paper, a single model is finetuned on the *training mix*
//! (built from the add/sub/two-step generators, like Math10K is built from
//! GSM8K+MAWPS+AQuA trains) and evaluated per task: exact-match on the
//! generated digits for the open-ended tasks, choice accuracy for the
//! AQuA-style multiple-choice task.

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

/// MAWPS analogue: single addition, two-digit operands.
pub struct MawpsX;

impl Task for MawpsX {
    fn name(&self) -> &'static str {
        "mawps-x"
    }
    fn metric(&self) -> Metric {
        Metric::ExactMatch
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.range(10, 60);
        let b = rng.range(10, 40);
        Example::gen(&format!("{a}+{b}="), &format!("{}.", a + b))
    }
}

/// SVAMP analogue: single subtraction with a distractor operand the model
/// must learn to ignore (SVAMP's signature perturbation).
pub struct SvampX;

impl Task for SvampX {
    fn name(&self) -> &'static str {
        "svamp-x"
    }
    fn metric(&self) -> Metric {
        Metric::ExactMatch
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.range(50, 99);
        let b = rng.range(10, 49);
        let d = rng.range(10, 99); // distractor
        Example::gen(&format!("{a}-{b}[{d}]="), &format!("{}.", a - b))
    }
}

/// GSM8K analogue: two-step chain a+b-c.
pub struct Gsm8kX;

impl Task for Gsm8kX {
    fn name(&self) -> &'static str {
        "gsm8k-x"
    }
    fn metric(&self) -> Metric {
        Metric::ExactMatch
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.range(10, 50);
        let b = rng.range(10, 50);
        let c = rng.range(1, 10);
        Example::gen(&format!("{a}+{b}-{c}="), &format!("{}.", a + b - c))
    }
}

/// AQuA analogue: multiple-choice addition — pick the option letter whose
/// value equals a+b (scored as choice accuracy, like AQuA's option letter).
pub struct AquaX;

impl Task for AquaX {
    fn name(&self) -> &'static str {
        "aqua-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.range(10, 60);
        let b = rng.range(10, 40);
        let gold = a + b;
        let mut opts = vec![gold];
        while opts.len() < 4 {
            let delta = rng.range(1, 15) * if rng.chance(0.5) { 1 } else { -1 };
            let v = gold + delta;
            if !opts.contains(&v) && v > 0 {
                opts.push(v);
            }
        }
        rng.shuffle(&mut opts[..]);
        let ans = opts.iter().position(|&v| v == gold).unwrap();
        let strs: Vec<String> = opts.iter().map(|v| v.to_string()).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        Example::choice(&format!("{a}+{b}=?"), &refs, ans)
    }
}

/// The four evaluation tasks in Table-4 column order.
pub fn eval_tasks() -> Vec<Box<dyn Task>> {
    vec![Box::new(AquaX), Box::new(Gsm8kX), Box::new(MawpsX), Box::new(SvampX)]
}

/// The Math10K-analogue training mix: generators covering the operations
/// the eval tasks need (note: like Math10K, it contains no SVAMP training
/// split — transfer from the subtraction generator is required).
pub fn train_mix() -> Vec<Box<dyn Task>> {
    vec![Box::new(AquaX), Box::new(Gsm8kX), Box::new(MawpsX), Box::new(SubX)]
}

/// Plain subtraction (training-mix only; SVAMP transfers from this).
pub struct SubX;

impl Task for SubX {
    fn name(&self) -> &'static str {
        "sub-x"
    }
    fn metric(&self) -> Metric {
        Metric::ExactMatch
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a = rng.range(50, 99);
        let b = rng.range(10, 49);
        Example::gen(&format!("{a}-{b}="), &format!("{}.", a - b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answers_are_correct_arithmetic() {
        let mut rng = Rng::seed_from(55);
        for _ in 0..200 {
            let ex = MawpsX.sample(&mut rng);
            let p = crate::tokenizer::decode(&ex.prompt);
            let (a, b) = p.trim_end_matches('=').split_once('+').unwrap();
            let want: i64 = a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap();
            assert_eq!(crate::tokenizer::decode(&ex.completion), format!("{want}."));
        }
    }

    #[test]
    fn gsm_two_step() {
        let mut rng = Rng::seed_from(56);
        let ex = Gsm8kX.sample(&mut rng);
        let p = crate::tokenizer::decode(&ex.prompt);
        assert!(p.contains('+') && p.contains('-'));
    }

    #[test]
    fn aqua_choices_unique_and_positive() {
        let mut rng = Rng::seed_from(57);
        for _ in 0..100 {
            let ex = AquaX.sample(&mut rng);
            assert_eq!(ex.choices.len(), 4);
            let vals: Vec<i64> = ex
                .choices
                .iter()
                .map(|c| crate::tokenizer::decode(c).parse().unwrap())
                .collect();
            let mut dedup = vals.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), 4);
            assert!(vals.iter().all(|&v| v > 0));
        }
    }

    #[test]
    fn completion_terminates_with_period() {
        // The '.' terminator doubles as the generation stop token.
        let mut rng = Rng::seed_from(58);
        for t in [&MawpsX as &dyn Task, &SvampX, &Gsm8kX, &SubX] {
            let ex = t.sample(&mut rng);
            assert_eq!(*ex.completion.last().unwrap(), b'.' as i32);
        }
    }
}
