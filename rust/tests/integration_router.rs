//! Multi-replica data-plane integration tests on the tiny config: the
//! fleet's placement transparency (greedy decode is byte-identical whether
//! one replica or three serve the workload), id-stride cancel routing,
//! drain semantics, router-side adapter registration fan-out, and the
//! merged + per-replica stats view.
//!
//! Like the streaming suite, every test runs unconditionally: on the
//! pure-Rust reference backend when no artifacts are built, on PJRT when
//! they exist (`ROAD_TEST_BACKEND=ref|pjrt` overrides).

use std::rc::Rc;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::EngineConfig;
use road::coordinator::queue::EngineError;
use road::coordinator::request::{FinishReason, Request, SamplingParams};
use road::coordinator::{Fleet, PlaceKind, ReplicaState, Router};
use road::runtime::Runtime;
use road::util::rng::Rng;

fn test_backend() -> road::runtime::BackendKind {
    road::runtime::BackendKind::auto()
}

fn rt() -> Rc<Runtime> {
    let rt = Runtime::for_backend(test_backend(), road::Manifest::default_dir())
        .expect("run `make artifacts` first");
    Rc::new(rt)
}

fn tiny_econf(mode: &str) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        mode: mode.into(),
        decode_slots: 2,
        queue_capacity: 64,
        backend: test_backend(),
        ..Default::default()
    }
}

/// Greedy sampling: decode is a pure function of (prompt, adapter), so the
/// same request produces the same tokens on any replica — the property the
/// identity test leans on.
fn greedy(prompt: &[i32], max_new: usize) -> Request {
    Request::new(prompt.to_vec(), max_new).with_sampling(SamplingParams {
        temperature: 0.0,
        top_k: 0,
        seed: 0,
        stop_token: None,
    })
}

fn tiny_adapter(rt: &Rc<Runtime>, seed: u64) -> Adapter {
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::seed_from(seed);
    Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3))
}

/// A fleet with adapters "a" and "b" registered on every replica by the
/// per-replica setup closure, homed in the router's placer.
fn start_fleet(n_replicas: usize, place: PlaceKind, seed: u64) -> (Fleet, Router) {
    let adapter_a = tiny_adapter(&rt(), seed);
    let adapter_b = tiny_adapter(&rt(), seed ^ 0xb);
    let (fleet, router) = Fleet::start(
        tiny_econf("road"),
        road::Manifest::default_dir(),
        n_replicas,
        place,
        move |eng| {
            eng.register_adapter("a", &adapter_a)?;
            eng.register_adapter("b", &adapter_b)?;
            Ok(())
        },
    )
    .unwrap();
    router.place_adapter("a");
    router.place_adapter("b");
    (fleet, router)
}

/// The greedy workload both fleets replay: hetero adapters, varied prompts.
fn workload() -> Vec<Request> {
    (0..9)
        .map(|i| {
            let prompt: Vec<i32> = (0..3 + i % 4).map(|p| 1 + ((7 * i + p) % 13) as i32).collect();
            let r = greedy(&prompt, 5 + i % 3);
            match i % 3 {
                0 => r.with_adapter("a"),
                1 => r.with_adapter("b"),
                _ => r,
            }
        })
        .collect()
}

/// Placement is transparent to decoding: the same greedy workload yields
/// token-identical outputs on a 1-replica fleet and a 3-replica affinity
/// fleet (requests land on different engines with different banks, but
/// greedy decode is a pure function of prompt + adapter).
#[test]
fn fleet_token_identity_one_vs_three_replicas() {
    let run = |n: usize| -> Vec<Vec<i32>> {
        let (fleet, router) = start_fleet(n, PlaceKind::Affinity, 17);
        // Submit everything up front (requests interleave across lanes and
        // replicas), then drain in submission order.
        let generations: Vec<_> =
            workload().into_iter().map(|r| router.submit(r).unwrap()).collect();
        let outs: Vec<Vec<i32>> = generations
            .into_iter()
            .map(|generation| {
                let out = generation.wait().unwrap();
                assert_eq!(out.finish, FinishReason::MaxTokens);
                out.tokens
            })
            .collect();
        fleet.shutdown().unwrap();
        outs
    };
    let single = run(1);
    let tripled = run(3);
    assert_eq!(single.len(), tripled.len());
    for (i, (s, t)) in single.iter().zip(&tripled).enumerate() {
        assert_eq!(s, t, "request {i}: placement changed greedy output");
    }
}

/// Wire ids carve the fleet's id space by stride: `(id - 1) % n` recovers
/// the serving replica, which is how `Router::cancel` routes without a
/// fan-out — and an affinity fleet actually spreads adapters across homes.
#[test]
fn fleet_ids_encode_their_replica_and_cancel_routes_by_id() {
    let n = 3usize;
    let (fleet, router) = start_fleet(n, PlaceKind::Affinity, 4);
    let mut seen_replicas = std::collections::BTreeSet::new();
    for r in workload() {
        let generation = router.submit(r).unwrap();
        assert_eq!(
            (generation.id() - 1) % n as u64,
            generation.replica() as u64,
            "id stride must encode the serving replica"
        );
        seen_replicas.insert(generation.replica());
        generation.wait().unwrap();
    }
    assert!(
        seen_replicas.len() > 1,
        "adapters a/b + base route should span replicas: {seen_replicas:?}"
    );

    // Cancel through the router by bare wire id (no handle on the serving
    // replica needed): the typed error comes back through the stream.
    let generation = router.submit(greedy(&[5, 4, 3], 120).with_adapter("a")).unwrap();
    router.cancel(generation.id()).unwrap();
    assert!(matches!(generation.wait(), Err(EngineError::Cancelled)));
    fleet.shutdown().unwrap();
}

/// Draining a replica stops new placements immediately while the rest of
/// the fleet serves on; fleet stats label the drained replica.
#[test]
fn fleet_drain_stops_placement_and_shows_in_stats() {
    let (fleet, router) = start_fleet(2, PlaceKind::RoundRobin, 9);
    router.drain(0);
    for i in 0..4 {
        let generation = router.submit(greedy(&[1 + i, 2, 3], 3)).unwrap();
        assert_eq!(generation.replica(), 1, "drained replica took new work");
        generation.wait().unwrap();
    }
    let stats = router.stats();
    let states: Vec<ReplicaState> = stats.replicas.iter().map(|r| r.health.state).collect();
    assert_eq!(states, vec![ReplicaState::Draining, ReplicaState::Ready]);
    assert_eq!(stats.replicas[0].stats.requests_completed, 0);
    assert_eq!(stats.replicas[1].stats.requests_completed, 4);
    assert_eq!(stats.merged.requests_completed, 4, "merged view sums the fleet");
    fleet.shutdown().unwrap();
}

/// Router-side registration fans out to every replica: an adapter
/// registered through the router serves spillover traffic anywhere, and
/// the merged stats equal the per-replica sum.
#[test]
fn fleet_registration_fans_out_and_stats_merge() {
    let rt = rt();
    let (fleet, router) = start_fleet(3, PlaceKind::RoundRobin, 21);
    router.register_adapter("c", tiny_adapter(&rt, 33)).unwrap();
    // Round-robin sprays the same adapter across all three replicas —
    // each must have it registered.
    let mut seen = std::collections::BTreeSet::new();
    let mut total_tokens = 0usize;
    for i in 0..6 {
        let out_gen = router.submit(greedy(&[2 + i, 7], 4).with_adapter("c")).unwrap();
        seen.insert(out_gen.replica());
        let out = out_gen.wait().unwrap();
        total_tokens += out.tokens.len();
    }
    assert_eq!(seen.len(), 3, "round-robin should touch every replica: {seen:?}");
    let stats = router.stats();
    assert_eq!(stats.merged.requests_completed, 6);
    assert_eq!(
        stats.replicas.iter().map(|r| r.stats.requests_completed).sum::<usize>(),
        6,
        "per-replica snapshots sum to the merged counter"
    );
    assert_eq!(stats.merged.tokens_generated, total_tokens);
    router.unregister_adapter("c").unwrap();
    assert!(
        router.submit(greedy(&[1, 2], 2).with_adapter("c")).is_err(),
        "unregistered adapter must be rejected at submit"
    );
    fleet.shutdown().unwrap();
}
