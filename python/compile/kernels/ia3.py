"""Layer-1 Pallas kernel for the (IA)^3 baseline [Liu et al. 2022].

(IA)^3 rescales the output of a linear layer with a trained vector — the
prior art the paper credits for element-wise-friendly batching.  RoAd
matches its batching cost while adding the rotation (mixing adjacent
dimensions), which is where the quality gap in Tables 2-4 comes from.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ia3_kernel(h_ref, s_ref, o_ref):
    h = h_ref[...]                # [1, TL, d]
    s = s_ref[...][:, None, :]    # [1, 1, d]
    o_ref[...] = s * h


def ia3_batched_apply(h, s_bank, ids):
    """Per-request element-wise scaling; h [B, L, d], s_bank [n, d]."""
    b, l, d = h.shape
    s = s_bank[ids]  # [B, d]
    tl = 1
    for t in (32, 16, 8, 4, 2, 1):
        if l % t == 0:
            tl = t
            break
    return pl.pallas_call(
        _ia3_kernel,
        grid=(b, l // tl),
        in_specs=[
            pl.BlockSpec((1, tl, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tl, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), h.dtype),
        interpret=True,
    )(h, s)
