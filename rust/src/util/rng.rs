//! Deterministic PRNG (xoshiro256**) with normal sampling.
//!
//! The offline image carries no `rand` crate; this is a small, seedable,
//! reproducible generator used by workload generation, task synthesis,
//! sampling and the property-test harness.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child stream (for per-request determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::seed_from(1);
        for _ in 0..1000 {
            let x = r.range(-5, 5);
            assert!((-5..5).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(7);
        let xs = r.normal_vec(20000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::seed_from(9);
        let mut counts = [0usize; 2];
        for _ in 0..1000 {
            counts[r.weighted(&[1.0, 9.0])] += 1;
        }
        assert!(counts[1] > 700);
    }
}
