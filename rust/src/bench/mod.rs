//! Serving workload generation + the Figure-4 / Table-D.1 sweep harness.
//!
//! Figure 4's three panels are throughput studies of the multi-adapter
//! serving engine:
//!   * Left   — merged vs unmerged LoRA vs rank (batch 1, long generation),
//!   * Middle — RoAd vs unmerged LoRA vs #generated tokens (batch 8,
//!              heterogeneous adapters),
//!   * Right  — RoAd vs unmerged LoRA vs #distinct adapters in the batch.
//!
//! The bank-churn study ([`bank_churn_study`]) goes past the paper's
//! figure: many more registered adapters than device bank slots, a
//! Zipf-distributed request-to-adapter assignment, and paged vs
//! whole-bank-upload engines compared on hit/miss/eviction counts and
//! host-to-device upload bytes.
//!
//! Table D.1 times the per-step cost of each finetuning method (RoAd's
//! inherent orthogonality vs OFT's Cayley solves) and reports the
//! optimizer-state footprint.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::adapters::{Adapter, Ia3Adapter, LoraAdapter, RoadAdapter};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{Request, SamplingParams, StreamEvent};
use crate::coordinator::router::{FleetSim, FleetSimConfig, PlaceKind};
use crate::coordinator::sched::{PolicyKind, PrefillModel, SchedSim, SimOutcome, SimRecord};
use crate::manifest::ModelConfigInfo;
use crate::model::{proj_dims, PROJS};
use crate::runtime::Runtime;
use crate::trainer::{Recipe, TrainBatch, Trainer};
use crate::util::clock::Clock;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// One serving measurement.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    pub label: String,
    pub batch: usize,
    pub distinct_adapters: usize,
    pub new_tokens: usize,
    pub requests: usize,
    pub wall_secs: f64,
    /// Generated tokens per second (the paper's throughput axis).
    pub tokens_per_sec: f64,
    pub decode_steps: usize,
    /// Time spent inside decode executions (see
    /// [`ServingPoint::ms_per_step`]; the KV residency comparison's axis).
    pub decode_secs: f64,
    /// Adapter-bank paging counters (the bank study's axes).
    pub bank_hits: usize,
    pub bank_misses: usize,
    pub bank_evictions: usize,
    pub bank_upload_bytes: usize,
}

impl ServingPoint {
    /// Mean decode-step cost in milliseconds; `None` when the run never
    /// decoded (e.g. every request finished at prefill).
    pub fn ms_per_step(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| self.decode_secs * 1e3 / self.decode_steps as f64)
    }
}

/// Build a heterogeneous workload: `n_requests` requests over
/// `distinct` registered adapters (round-robin), each generating
/// `new_tokens` tokens from a short prompt.
pub fn hetero_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if distinct > 0 {
                r = r.with_adapter(&format!("adapter-{}", i % distinct));
            }
            r
        })
        .collect()
}

/// Sample from a Zipf(s) distribution over ranks `0..n` (rank 0 most
/// popular): the canonical popularity skew for per-user adapter traffic —
/// a few hot adapters dominate while a long tail stays cold, which is the
/// regime an LRU-paged bank exploits.
pub fn zipf_sample(rng: &mut Rng, n: usize, s: f64) -> usize {
    rng.weighted(&zipf_weights(n, s))
}

/// Unnormalized Zipf(s) weights over ranks `0..n` (precompute once when
/// sampling repeatedly — [`zipf_workload`] does).
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    assert!(n > 0, "zipf distribution needs at least one rank");
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
}

/// Build an adapter-churn workload: `n_requests` requests over `distinct`
/// registered adapters with a Zipf(s)-distributed request→adapter
/// assignment (instead of [`hetero_workload`]'s uniform round-robin).
pub fn zipf_workload(
    rng: &mut Rng,
    n_requests: usize,
    distinct: usize,
    zipf_s: f64,
    prompt_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    let weights = (distinct > 0).then(|| zipf_weights(distinct, zipf_s));
    (0..n_requests)
        .map(|i| {
            let prompt: Vec<i32> = (0..prompt_len).map(|_| 1 + rng.below(255) as i32).collect();
            let mut r = Request::new(prompt, new_tokens).with_sampling(
                SamplingParams { temperature: 0.0, top_k: 0, seed: i as u64, stop_token: None },
            );
            if let Some(w) = &weights {
                let k = rng.weighted(w);
                r = r.with_adapter(&format!("adapter-{k}"));
            }
            r
        })
        .collect()
}

/// Register `distinct` random adapters of the engine's mode.
pub fn register_adapters(engine: &mut Engine, distinct: usize, seed: u64) -> Result<()> {
    let mut rng = Rng::seed_from(seed);
    for i in 0..distinct {
        let adapter = match engine.econf.mode.as_str() {
            "road" => Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.2)),
            "lora" => Adapter::Lora(LoraAdapter::random(&engine.cfg, &mut rng, 0.05)),
            "ia3" => Adapter::Ia3(Ia3Adapter::random(&engine.cfg, &mut rng, 0.05)),
            m => anyhow::bail!("no random adapter generator for mode {m}"),
        };
        engine.register_adapter(&format!("adapter-{i}"), &adapter)?;
    }
    Ok(())
}

/// Run one serving measurement: fresh engine in `mode`, `distinct`
/// adapters, `n_requests` requests × `new_tokens` tokens.
pub fn measure_serving(
    rt: &Rc<Runtime>,
    model: &str,
    mode: &str,
    slots: usize,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let econf = EngineConfig {
        model: model.into(),
        mode: mode.into(),
        decode_slots: slots,
        queue_capacity: 4096,
        ..Default::default()
    };
    measure_serving_cfg(rt, econf, distinct, n_requests, new_tokens, seed)
}

/// Like [`measure_serving`], but over an explicit engine config — the KV
/// residency comparison uses this to flip `kv_host_roundtrip` with
/// everything else held fixed.
pub fn measure_serving_cfg(
    rt: &Rc<Runtime>,
    econf: EngineConfig,
    distinct: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<ServingPoint> {
    let mode = econf.mode.clone();
    let mut engine = Engine::new(rt.clone(), econf)?;
    if distinct > 0 {
        register_adapters(&mut engine, distinct, seed)?;
    }
    let mut rng = Rng::seed_from(seed ^ 0xbe7c);
    let prompt_len = 8;
    let reqs = hetero_workload(&mut rng, n_requests, distinct, prompt_len, new_tokens);
    run_workload(&mut engine, &format!("{mode}/d{distinct}"), distinct, new_tokens, reqs)
}

/// Drive `reqs` to completion on `engine` and package the measurement.
fn run_workload(
    engine: &mut Engine,
    label: &str,
    distinct: usize,
    new_tokens: usize,
    reqs: Vec<Request>,
) -> Result<ServingPoint> {
    let n_requests = reqs.len();
    // roadlint: allow(clock-discipline) -- closed-loop throughput point:
    // wall_secs divides into tokens/sec, so it must be real hardware time
    // even when the engine itself runs on a manual clock.
    let t0 = std::time::Instant::now();
    let outs = engine.run_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let gen_tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    Ok(ServingPoint {
        label: label.to_string(),
        batch: engine.econf.decode_slots,
        distinct_adapters: distinct,
        new_tokens,
        requests: n_requests,
        wall_secs: wall,
        tokens_per_sec: gen_tokens as f64 / wall,
        decode_steps: engine.metrics.decode_steps,
        decode_secs: engine.metrics.decode_time.as_secs_f64(),
        bank_hits: engine.metrics.bank_hits,
        bank_misses: engine.metrics.bank_misses,
        bank_evictions: engine.metrics.bank_evictions,
        bank_upload_bytes: engine.metrics.bank_upload_bytes,
    })
}

/// The adapter-churn study: `n_adapters` registered adapters paged through
/// a `bank_slots`-slot device bank (adapters ≫ slots) under a Zipf(1.1)
/// request mix, measured with paged per-slot uploads vs the whole-bank
/// re-upload baseline.  Every request must complete — registration can no
/// longer fail on capacity, and eviction never touches a pinned slot.
pub fn bank_churn_study(
    rt: &Rc<Runtime>,
    n_adapters: usize,
    bank_slots: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, paged) in [("road/paged-bank", true), ("road/whole-bank-upload", false)] {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            bank_slots: Some(bank_slots),
            paged_bank_uploads: paged,
            ..Default::default()
        };
        let mut engine = Engine::new(rt.clone(), econf)?;
        register_adapters(&mut engine, n_adapters, seed)?;
        let mut rng = Rng::seed_from(seed ^ 0x21f7);
        let reqs = zipf_workload(&mut rng, n_requests, n_adapters, 1.1, 8, new_tokens);
        out.push(run_workload(&mut engine, label, n_adapters, new_tokens, reqs)?);
    }
    Ok(out)
}

/// Device-resident vs host-round-trip decode on an otherwise identical
/// heterogeneous workload (batch 8, road mode).  The second point is the
/// pre-refactor baseline that moved the full K/V cache host↔device every
/// step; `decode_secs / decode_steps` is the per-step cost to compare.
pub fn kv_residency_comparison(
    rt: &Rc<Runtime>,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for (label, kv_host_roundtrip) in
        [("road/device-resident", false), ("road/host-roundtrip", true)]
    {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            kv_host_roundtrip,
            ..Default::default()
        };
        let mut p = measure_serving_cfg(rt, econf, 8, 16, new_tokens, seed)?;
        p.label = label.into();
        out.push(p);
    }
    Ok(out)
}

/// One streaming-serving measurement (the open-loop study's row).
#[derive(Clone, Debug)]
pub struct StreamingPoint {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub cancelled: usize,
    /// Requests that never reached a `Finished` event (submit rejected or
    /// stream ended in `Error`) — kept out of `completed` so the
    /// run-to-completion vs cancel comparison stays honest.
    pub errored: usize,
    /// Token events observed client-side across all requests.
    pub tokens_streamed: usize,
    pub wall_secs: f64,
    /// Client-observed TTFT (submit call → first `Token` event received),
    /// in milliseconds — the latency a real caller sees through the
    /// channel, not the engine's internal stamp.
    pub observed_ttft_p50_ms: f64,
    pub observed_ttft_p90_ms: f64,
}

/// Open-loop streaming study over the threaded server: clients submit on
/// an arrival clock (independent of completions), consume `StreamEvent`s,
/// and measure *observed* TTFT.  The second scenario cancels every other
/// request after `cancel_after` observed tokens — the cancellation-reclaim
/// comparison: reclaimed decode lanes shrink wall time and streamed-token
/// volume versus running every request to completion.
///
/// Arrivals are driven by `clock`, which the engine shares, and paced by
/// the submitting thread itself so the arrival *order* is deterministic
/// on either clock: request `i` enters at `i*2ms` of clock time (a real
/// sleep on the wall clock, a virtual jump on a manual one — no sleeps
/// anywhere in the bench itself).  Consumer threads only drain events,
/// so their scheduling cannot reorder submissions.  Client-observed
/// latencies still carry thread-timing noise; the byte-reproducible
/// study is `sched_study_sim`.
#[allow(clippy::too_many_arguments)]
pub fn streaming_study(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    n_requests: usize,
    new_tokens: usize,
    cancel_after: usize,
    seed: u64,
    clock: Clock,
    backend: crate::runtime::BackendKind,
) -> Result<Vec<StreamingPoint>> {
    use crate::coordinator::server::EngineServer;

    let distinct = 8usize;
    let mut out = Vec::new();
    for (label, cancel_half) in [
        ("stream/run-to-completion", false),
        ("stream/cancel-half", true),
    ] {
        let econf = EngineConfig {
            model: model.into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            clock: clock.clone(),
            backend,
            ..Default::default()
        };
        let (server, client) = EngineServer::start(econf, artifacts_dir.clone(), move |eng| {
            register_adapters(eng, distinct, seed)
        })?;
        let mut rng = Rng::seed_from(seed ^ 0x57e4);
        let reqs = hetero_workload(&mut rng, n_requests, distinct, 8, new_tokens);

        let start = clock.now();
        let mut handles = Vec::new();
        for (i, req) in reqs.into_iter().enumerate() {
            let cancel_at = (cancel_half && i % 2 == 1).then_some(cancel_after);
            // Open-loop arrival clock, paced here on the submitting
            // thread: request i enters at i*2ms of clock time whether or
            // not earlier requests have finished, and submissions happen
            // in arrival order on both clock kinds.
            clock.sleep_until(start + Duration::from_millis(2 * i as u64));
            let submitted = clock.now();
            let generation = match client.submit(req) {
                Ok(g) => g,
                Err(_) => {
                    // Terminal outcome None = submit rejected (counted as
                    // errored below, like a stream that dies in Error).
                    handles.push(std::thread::spawn(move || (None, 0, None)));
                    continue;
                }
            };
            // Per-request terminal outcome: Some(true) = cancelled,
            // Some(false) = completed, None = the stream ended in an
            // Error event.
            let tclock = clock.clone();
            handles.push(std::thread::spawn(move || -> (Option<f64>, usize, Option<bool>) {
                let mut generation = generation;
                let mut ttft = None;
                let mut seen = 0usize;
                let mut cancel_sent = false;
                let mut outcome = None;
                while let Some(ev) = generation.recv() {
                    match ev {
                        StreamEvent::Token { .. } => {
                            ttft.get_or_insert_with(|| {
                                tclock.now().saturating_duration_since(submitted).as_secs_f64()
                            });
                            seen += 1;
                            if !cancel_sent && cancel_at.is_some_and(|k| seen >= k) {
                                generation.cancel();
                                cancel_sent = true;
                            }
                        }
                        StreamEvent::Finished(o) => {
                            let c = crate::coordinator::request::FinishReason::Cancelled;
                            outcome = Some(o.finish == c);
                            break;
                        }
                        StreamEvent::Error { .. } => break,
                        StreamEvent::Admitted { .. } => {}
                    }
                }
                (ttft, seen, outcome)
            }));
        }
        let mut ttfts_ms = Vec::new();
        let (mut completed, mut cancelled, mut errored) = (0usize, 0usize, 0usize);
        let mut tokens_streamed = 0usize;
        for h in handles {
            let (ttft, seen, outcome) = h.join().expect("client thread panicked");
            if let Some(t) = ttft {
                ttfts_ms.push(t * 1e3);
            }
            tokens_streamed += seen;
            match outcome {
                Some(true) => cancelled += 1,
                Some(false) => completed += 1,
                None => errored += 1,
            }
        }
        let wall = clock.now().saturating_duration_since(start).as_secs_f64();
        server.shutdown()?;
        let s = crate::util::stats::summarize(&ttfts_ms);
        out.push(StreamingPoint {
            label: label.into(),
            requests: n_requests,
            completed,
            cancelled,
            errored,
            tokens_streamed,
            wall_secs: wall,
            observed_ttft_p50_ms: s.p50,
            observed_ttft_p90_ms: s.p90,
        });
    }
    Ok(out)
}

/// Render the streaming study; the cancel row's smaller streamed-token
/// volume and wall time are the reclaim the study exists to show.
pub fn render_streaming_points(title: &str, points: &[StreamingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "reqs", "completed", "cancelled", "errored", "tok-streamed", "wall(s)",
        "obs-ttft p50(ms)", "obs-ttft p90(ms)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.requests.to_string(),
            p.completed.to_string(),
            p.cancelled.to_string(),
            p.errored.to_string(),
            p.tokens_streamed.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.observed_ttft_p50_ms, 1),
            fmt_f(p.observed_ttft_p90_ms, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nobs-ttft is measured at the client (submit call → first Token \
         event through the channel); cancelled lanes are reclaimed for waiting work, \
         which is the wall/token delta between the rows.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Admission-scheduling study (`road bench-serving --study sched`)
// ---------------------------------------------------------------------------

/// Per-adapter queue-wait aggregate in one sched-study row — the
/// fairness axis (one hot adapter must not starve the rest).
#[derive(Clone, Debug)]
pub struct AdapterWait {
    pub adapter: String,
    pub requests: usize,
    pub wait_p50_ms: f64,
    pub wait_p99_ms: f64,
    pub wait_max_ms: f64,
}

/// One (policy, prefill-chunk budget) row in the admission-scheduling
/// study.
#[derive(Clone, Debug)]
pub struct SchedPoint {
    pub policy: String,
    /// Mixed-step chunk budget (0 = atomic prefill, the baseline).
    pub prefill_chunk: usize,
    pub requests: usize,
    pub finished: usize,
    pub shed: usize,
    /// Sheds over deadline-bearing requests (0 when none carry deadlines).
    pub deadline_miss_rate: f64,
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p99_ms: f64,
    /// Worst time any single request spent waiting in the queue (time to
    /// admission, or to its terminal event if it never got a lane) — the
    /// starvation axis.
    pub starvation_ms: f64,
    /// Inter-token gap p99 across all lanes.
    pub itl_p99_ms: f64,
    /// p99 of the gap in excess of the decode cadence — what long-prompt
    /// prefills cost every already-decoding lane, the chunking headline.
    pub itl_stall_p99_ms: f64,
    /// Submit → first-token p99 (chunking's side of the trade).
    pub ttft_p99_ms: f64,
    pub per_adapter: Vec<AdapterWait>,
}

/// Decorate a Zipf workload for the sched study: every 3rd request
/// carries a deadline, every 4th a priority tier, and every 5th a
/// maximum-length (64-token) prompt — the long prefills whose head-of-line
/// stall the chunked rows exist to bound.  All derived from the request
/// index so the workload is a pure function of `seed`.
fn sched_workload(
    n_requests: usize,
    distinct: usize,
    zipf_s: f64,
    new_tokens: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::seed_from(seed ^ 0x5c4ed);
    let mut reqs = zipf_workload(&mut rng, n_requests, distinct, zipf_s, 8, new_tokens);
    for (i, r) in reqs.iter_mut().enumerate() {
        if i % 3 == 0 {
            r.deadline = Some(Duration::from_millis(200 + (i as u64 % 5) * 50));
        }
        if i % 4 == 0 {
            r.priority = (i % 3) as u8 + 1;
        }
        if i % 5 == 0 {
            while r.prompt.len() < 64 {
                r.prompt.push(((i * 31 + r.prompt.len() * 7) % 200) as i32 + 1);
            }
        }
    }
    reqs
}

/// Fold terminal records into one study row.  Works over [`SimRecord`]s
/// whether they came from the [`SchedSim`] harness or from replaying a
/// real engine's event stream.  The three latency p99s are computed by
/// the caller (the harness owns the token-stamp samples).
fn aggregate_sched(
    policy: &str,
    prefill_chunk: usize,
    requests: usize,
    records: &[SimRecord],
    itl_p99_ms: f64,
    itl_stall_p99_ms: f64,
    ttft_p99_ms: f64,
) -> SchedPoint {
    // Queue wait = submit → admission; a request that never reached a
    // lane (shed/cancelled while queued) waited until its terminal event.
    let wait_ms = |r: &SimRecord| {
        (r.admitted_at.unwrap_or(r.finished_at) - r.submitted_at).as_secs_f64() * 1e3
    };
    let mut waits: Vec<f64> = Vec::new();
    let mut per: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let (mut finished, mut shed, mut with_deadline) = (0usize, 0usize, 0usize);
    for r in records {
        match r.outcome {
            SimOutcome::Finished => finished += 1,
            SimOutcome::DeadlineShed => shed += 1,
            SimOutcome::Cancelled => {}
        }
        if r.deadline.is_some() {
            with_deadline += 1;
        }
        let w = wait_ms(r);
        waits.push(w);
        per.entry(r.adapter.clone().unwrap_or_else(|| "base".into())).or_default().push(w);
    }
    let s = crate::util::stats::summarize(&waits);
    let per_adapter = per
        .into_iter()
        .map(|(adapter, ws)| {
            let a = crate::util::stats::summarize(&ws);
            AdapterWait {
                adapter,
                requests: ws.len(),
                wait_p50_ms: a.p50,
                wait_p99_ms: a.p99,
                wait_max_ms: a.max,
            }
        })
        .collect();
    SchedPoint {
        policy: policy.to_string(),
        prefill_chunk,
        requests,
        finished,
        shed,
        deadline_miss_rate: if with_deadline > 0 {
            shed as f64 / with_deadline as f64
        } else {
            0.0
        },
        queue_wait_p50_ms: s.p50,
        queue_wait_p99_ms: s.p99,
        starvation_ms: s.max,
        itl_p99_ms,
        itl_stall_p99_ms,
        ttft_p99_ms,
        per_adapter,
    }
}

/// The admission-scheduling study on the deterministic harness
/// (`--sim-clock`): all four policies × two prefill models (atomic
/// baseline vs a 16-token mixed-step budget) over the same Zipf-skewed,
/// deadline/priority-decorated, long-prompt-injected workload.  Arrivals
/// land every 10 ms of *virtual* time; a decode step costs a fixed 5 ms
/// and each prefill token 1/8 of that, so an atomic 64-token prefill
/// stretches one step by 40 ms — the head-of-line stall the chunked rows
/// bound at the budget.  No artifacts, no sleeps, no wall-clock reads —
/// two runs produce byte-identical output (CI diffs
/// `results/BENCH_sched.json`).
pub fn sched_study_sim(
    n_requests: usize,
    distinct: usize,
    new_tokens: usize,
    seed: u64,
) -> Vec<SchedPoint> {
    let arrival_gap = Duration::from_millis(10);
    let step_cost = Duration::from_millis(5);
    let token_cost = step_cost / 8;
    let mut out = Vec::new();
    for kind in PolicyKind::ALL {
        for chunk in [0usize, 16] {
            let model = if chunk == 0 {
                PrefillModel::Atomic { token_cost }
            } else {
                PrefillModel::Chunked { budget: chunk, token_cost }
            };
            let mut sim = SchedSim::new(kind, 8, 4096, step_cost).with_prefill(model);
            let reqs = sched_workload(n_requests, distinct, 1.2, new_tokens, seed);
            let start = sim.clock.now();
            let mut pending: VecDeque<(usize, Request)> = reqs.into_iter().enumerate().collect();
            loop {
                let due = |pending: &VecDeque<(usize, Request)>| {
                    pending.front().map(|(i, _)| start + arrival_gap * (*i as u32))
                };
                while due(&pending).is_some_and(|d| d <= sim.clock.now()) {
                    let (_, req) = pending.pop_front().expect("due arrival checked");
                    sim.submit(req).expect("study queue capacity exceeds the workload");
                }
                if pending.is_empty() && !sim.has_work() {
                    break;
                }
                if !sim.has_work() {
                    // Idle until the next arrival (a virtual jump).
                    if let Some(d) = due(&pending) {
                        sim.clock.sleep_until(d);
                        continue;
                    }
                }
                sim.step();
            }
            let ms = |ds: &[Duration]| -> Vec<f64> {
                ds.iter().map(|d| d.as_secs_f64() * 1e3).collect()
            };
            let itl = crate::util::stats::summarize(&ms(sim.itl_samples()));
            let stall = crate::util::stats::summarize(&ms(sim.itl_stall_samples()));
            let ttft = crate::util::stats::summarize(&ms(sim.ttft_samples()));
            out.push(aggregate_sched(
                kind.name(),
                chunk,
                n_requests,
                sim.records(),
                itl.p99,
                stall.p99,
                ttft.p99,
            ));
        }
    }
    out
}

/// The same study over the real engine (artifacts required): one engine
/// per policy with `EngineConfig::policy` set, the identical decorated
/// workload, arrivals open-loop on the engine's clock.  Queue waits are
/// observed from the `Admitted`/terminal events the step loop emits.
pub fn sched_study_engine(
    rt: &Rc<Runtime>,
    n_requests: usize,
    distinct: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<SchedPoint>> {
    struct OpenLoop {
        adapter: Option<String>,
        priority: u8,
        deadline: Option<Duration>,
        submitted_at: Instant,
        admitted_at: Option<Instant>,
        admitted_seq: Option<usize>,
    }
    let arrival_gap = Duration::from_millis(10);
    let mut out = Vec::new();
    for kind in PolicyKind::ALL {
        let econf = EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 4096,
            policy: kind,
            ..Default::default()
        };
        let mut engine = Engine::new(rt.clone(), econf)?;
        register_adapters(&mut engine, distinct, seed)?;
        let clock = engine.clock().clone();
        let reqs = sched_workload(n_requests, distinct, 1.2, new_tokens, seed);
        let start = clock.now();
        let mut pending: VecDeque<(usize, Request)> = reqs.into_iter().enumerate().collect();
        let mut live: BTreeMap<u64, OpenLoop> = BTreeMap::new();
        let mut records: Vec<SimRecord> = Vec::new();
        let mut admissions = 0usize;
        loop {
            let due = |pending: &VecDeque<(usize, Request)>| {
                pending.front().map(|(i, _)| start + arrival_gap * (*i as u32))
            };
            while due(&pending).is_some_and(|d| d <= clock.now()) {
                let (_, req) = pending.pop_front().expect("due arrival checked");
                let info = OpenLoop {
                    adapter: req.adapter.clone(),
                    priority: req.priority,
                    deadline: req.deadline,
                    submitted_at: clock.now(),
                    admitted_at: None,
                    admitted_seq: None,
                };
                let id = engine.submit(req)?;
                live.insert(id, info);
            }
            if pending.is_empty() && !engine.has_work() {
                break;
            }
            if !engine.has_work() {
                if let Some(d) = due(&pending) {
                    clock.sleep_until(d);
                    continue;
                }
            }
            for ev in engine.step()? {
                let id = ev.id();
                match &ev {
                    StreamEvent::Admitted { .. } => {
                        if let Some(info) = live.get_mut(&id) {
                            info.admitted_at = Some(clock.now());
                            info.admitted_seq = Some(admissions);
                            admissions += 1;
                        }
                    }
                    StreamEvent::Token { .. } => {}
                    StreamEvent::Finished(o) => {
                        if let Some(info) = live.remove(&id) {
                            let cancelled =
                                o.finish == crate::coordinator::request::FinishReason::Cancelled;
                            records.push(SimRecord {
                                id,
                                adapter: info.adapter,
                                priority: info.priority,
                                deadline: info.deadline,
                                submitted_at: info.submitted_at,
                                admitted_at: info.admitted_at,
                                admitted_seq: info.admitted_seq,
                                finished_at: clock.now(),
                                outcome: if cancelled {
                                    SimOutcome::Cancelled
                                } else {
                                    SimOutcome::Finished
                                },
                            });
                        }
                    }
                    StreamEvent::Error { error, .. } => {
                        if let Some(info) = live.remove(&id) {
                            let shed = matches!(
                                error,
                                crate::coordinator::queue::EngineError::DeadlineExceeded
                            );
                            records.push(SimRecord {
                                id,
                                adapter: info.adapter,
                                priority: info.priority,
                                deadline: info.deadline,
                                submitted_at: info.submitted_at,
                                admitted_at: info.admitted_at,
                                admitted_seq: info.admitted_seq,
                                finished_at: clock.now(),
                                // Only deadline sheds occur on this driver;
                                // anything else counts as a cancellation so
                                // the conservation totals still close.
                                outcome: if shed {
                                    SimOutcome::DeadlineShed
                                } else {
                                    SimOutcome::Cancelled
                                },
                            });
                        }
                    }
                }
            }
        }
        // The engine path runs atomic prefill (chunk 0) and observes no
        // virtual token stamps; the latency columns are sim-only.
        out.push(aggregate_sched(kind.name(), 0, n_requests, &records, 0.0, 0.0, 0.0));
    }
    Ok(out)
}

/// JSON form of the sched study — what the `--sim-clock` acceptance check
/// compares byte-for-byte across runs.
pub fn sched_points_json(points: &[SchedPoint]) -> Json {
    json::arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("policy", json::s(&p.policy)),
                    ("prefill_chunk", json::num(p.prefill_chunk as f64)),
                    ("requests", json::num(p.requests as f64)),
                    ("finished", json::num(p.finished as f64)),
                    ("deadline_shed", json::num(p.shed as f64)),
                    ("deadline_miss_rate", json::num(p.deadline_miss_rate)),
                    ("queue_wait_p50_ms", json::num(p.queue_wait_p50_ms)),
                    ("queue_wait_p99_ms", json::num(p.queue_wait_p99_ms)),
                    ("starvation_ms", json::num(p.starvation_ms)),
                    ("itl_p99_ms", json::num(p.itl_p99_ms)),
                    ("itl_stall_p99_ms", json::num(p.itl_stall_p99_ms)),
                    ("ttft_p99_ms", json::num(p.ttft_p99_ms)),
                    (
                        "per_adapter",
                        json::arr(
                            p.per_adapter
                                .iter()
                                .map(|a| {
                                    json::obj(vec![
                                        ("adapter", json::s(&a.adapter)),
                                        ("requests", json::num(a.requests as f64)),
                                        ("wait_p50_ms", json::num(a.wait_p50_ms)),
                                        ("wait_p99_ms", json::num(a.wait_p99_ms)),
                                        ("wait_max_ms", json::num(a.wait_max_ms)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// Render the sched study: one row per policy, plus the hottest/coldest
/// adapter waits so the fairness story is visible without the JSON.
pub fn render_sched_points(title: &str, points: &[SchedPoint]) -> String {
    let mut t = Table::new(&[
        "policy",
        "chunk",
        "reqs",
        "finished",
        "shed",
        "miss-rate",
        "wait p50(ms)",
        "wait p99(ms)",
        "starvation(ms)",
        "itl p99(ms)",
        "stall p99(ms)",
        "ttft p99(ms)",
        "hot p99(ms)",
        "cold p99(ms)",
    ]);
    for p in points {
        // "Hot" = adapter with the most requests; "cold" = the fewest.
        let hot = p.per_adapter.iter().max_by_key(|a| a.requests);
        let cold = p.per_adapter.iter().min_by_key(|a| a.requests);
        t.row(vec![
            p.policy.clone(),
            p.prefill_chunk.to_string(),
            p.requests.to_string(),
            p.finished.to_string(),
            p.shed.to_string(),
            fmt_f(p.deadline_miss_rate, 3),
            fmt_f(p.queue_wait_p50_ms, 1),
            fmt_f(p.queue_wait_p99_ms, 1),
            fmt_f(p.starvation_ms, 1),
            fmt_f(p.itl_p99_ms, 1),
            fmt_f(p.itl_stall_p99_ms, 1),
            fmt_f(p.ttft_p99_ms, 1),
            fmt_f(hot.map_or(0.0, |a| a.wait_p99_ms), 1),
            fmt_f(cold.map_or(0.0, |a| a.wait_p99_ms), 1),
        ]);
    }
    format!(
        "## {title}\n{}\nedf should minimize miss-rate, priority should favor high tiers, \
         fair should pull cold-adapter waits toward hot-adapter waits, and fcfs is the \
         pre-policy baseline.  `chunk` is the mixed-step prefill budget: 0 rows prefill \
         atomically (long prompts stall every decoding lane — the stall p99), chunked \
         rows bound that stall at the budget.  Full per-adapter percentiles ride in the \
         JSON block below.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Paged-KV study (`road bench-serving --study kvpage`)
// ---------------------------------------------------------------------------

/// One (pool budget, accounting mode) row of the paged-KV study.
#[derive(Clone, Debug)]
pub struct KvPagePoint {
    pub label: String,
    pub paged: bool,
    /// The memory budget: total blocks in the shared pool.
    pub pool_blocks: usize,
    pub block_size: usize,
    pub requests: usize,
    pub finished: usize,
    /// Scheduler iterations to drain the workload (one iteration = one
    /// virtual millisecond — the study's latency unit).
    pub steps: usize,
    /// Most lanes ever concurrently admitted — the batching capacity the
    /// block accounting achieves at this memory budget.
    pub peak_lanes: usize,
    /// Requests admitted over a non-empty cached prefix.
    pub prefix_hits: usize,
    pub block_hits: usize,
    pub block_misses: usize,
    pub block_evictions: usize,
    pub blocks_published: usize,
    pub admission_stalls: usize,
    /// Prompt tokens that went through a prefill executable.
    pub prefill_lane_tokens: usize,
    /// Prompt tokens served from cached prefix blocks instead.
    pub prefill_tokens_saved: usize,
    /// Free-block low-water mark (memory headroom at peak pressure).
    pub blocks_free_min: usize,
    pub shared_refs_peak: usize,
    pub ttft_p50_ms: f64,
    pub ttft_p90_ms: f64,
}

impl KvPagePoint {
    /// Fraction of reserved blocks served from the shared-prefix cache.
    pub fn block_hit_rate(&self) -> f64 {
        let total = self.block_hits + self.block_misses;
        if total == 0 {
            0.0
        } else {
            self.block_hits as f64 / total as f64
        }
    }
}

/// Build a shared-prefix workload: each request draws one of `n_groups`
/// fixed prefixes (Zipf(s) over group rank — a few hot prompt templates
/// dominate, the regime a prefix cache exploits) and appends a random
/// per-request suffix.  Requests of a group always use the same adapter
/// (prefix keys are adapter-salted, so sharing requires both to match).
#[allow(clippy::too_many_arguments)]
pub fn prefix_workload(
    rng: &mut Rng,
    n_requests: usize,
    n_groups: usize,
    distinct_adapters: usize,
    zipf_s: f64,
    prefix_len: usize,
    suffix_len: usize,
    new_tokens: usize,
) -> Vec<Request> {
    let weights = zipf_weights(n_groups.max(1), zipf_s);
    // Each group's prefix is a pure function of its rank, independent of
    // the request mix drawn from `rng`.
    let prefixes: Vec<Vec<i32>> = (0..n_groups.max(1))
        .map(|g| {
            let mut pr = Rng::seed_from(0x9e37 ^ (g as u64).wrapping_mul(0x1000_0000_01b3));
            (0..prefix_len).map(|_| 1 + pr.below(255) as i32).collect()
        })
        .collect();
    (0..n_requests)
        .map(|i| {
            let g = rng.weighted(&weights);
            let mut prompt = prefixes[g].clone();
            prompt.extend((0..suffix_len).map(|_| 1 + rng.below(255) as i32));
            let mut r = Request::new(prompt, new_tokens).with_sampling(SamplingParams {
                temperature: 0.0,
                top_k: 0,
                seed: i as u64,
                stop_token: None,
            });
            if distinct_adapters > 0 {
                r = r.with_adapter(&format!("adapter-{}", g % distinct_adapters));
            }
            r
        })
        .collect()
}

/// The paged-KV study: the same Zipf shared-prefix workload replayed at
/// several pool budgets, each in paged and flat accounting.  Flat mode
/// charges every lane a full `max_seq` footprint (the pre-paging layout),
/// so at a squeezed budget it admits fewer concurrent lanes than paged
/// mode does at the *same* budget — that gap, plus the prefix hit rate and
/// the free-block headroom, is what the rows show.
///
/// Everything runs on a manual clock advanced one virtual millisecond per
/// scheduler iteration, and no request carries a stop token, so every
/// recorded number is a pure function of the seed: two runs emit
/// byte-identical output on any backend (CI holds the `--sim-clock`
/// invocation to that).
pub fn kvpage_study(
    rt: &Rc<Runtime>,
    n_requests: usize,
    new_tokens: usize,
    pool_budgets: &[usize],
    seed: u64,
) -> Result<Vec<KvPagePoint>> {
    let mut out = Vec::new();
    for &pool_blocks in pool_budgets {
        for paged in [true, false] {
            out.push(kvpage_point(rt, paged, pool_blocks, n_requests, new_tokens, seed)?);
        }
    }
    Ok(out)
}

/// One row of [`kvpage_study`]: a fresh tiny-model engine at the given
/// budget/mode, the seed-determined workload submitted up front, drained
/// on the virtual clock.
fn kvpage_point(
    rt: &Rc<Runtime>,
    paged: bool,
    pool_blocks: usize,
    n_requests: usize,
    new_tokens: usize,
    seed: u64,
) -> Result<KvPagePoint> {
    // Block size 4 against the tiny model's 16-token prefill bucket: a
    // 12-token shared prefix spans 3 cacheable blocks and the hit cap
    // (floor((16-1)/4) = 3) still leaves the last prompt block to feed.
    let (block_size, n_groups, distinct, prefix_len, suffix_len) =
        (4usize, 8usize, 2usize, 12usize, 4usize);
    let clock = Clock::manual();
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "road".into(),
        decode_slots: 8,
        queue_capacity: 4096,
        clock: clock.clone(),
        backend: rt.backend,
        paged_kv: paged,
        kv_block_size: block_size,
        kv_pool_blocks: Some(pool_blocks),
        ..Default::default()
    };
    let mut engine = Engine::new(rt.clone(), econf)?;
    register_adapters(&mut engine, distinct, seed)?;
    let mut rng = Rng::seed_from(seed ^ 0x4b9a);
    let reqs = prefix_workload(
        &mut rng, n_requests, n_groups, distinct, 1.1, prefix_len, suffix_len, new_tokens,
    );
    for r in reqs {
        engine.submit(r)?;
    }
    let mut ttfts_ms: Vec<f64> = Vec::new();
    let (mut finished, mut peak_lanes, mut steps) = (0usize, 0usize, 0usize);
    while engine.has_work() {
        for ev in engine.step()? {
            if let StreamEvent::Finished(o) = ev {
                finished += 1;
                ttfts_ms.push(o.ttft * 1e3);
            }
        }
        peak_lanes = peak_lanes.max(engine.n_active());
        steps += 1;
        clock.advance(Duration::from_millis(1));
    }
    // Drained: every lane returned its blocks; only unreferenced cached
    // prefixes may still occupy pool blocks.
    let pool = engine.paged_kv().pool();
    anyhow::ensure!(
        pool.n_private() == 0 && pool.total_refs() == 0,
        "drained engine leaked KV blocks ({} private, {} refs)",
        pool.n_private(),
        pool.total_refs()
    );
    let s = crate::util::stats::summarize(&ttfts_ms);
    let m = &engine.metrics;
    Ok(KvPagePoint {
        label: format!("{}/pool{pool_blocks}", if paged { "paged" } else { "flat" }),
        paged,
        pool_blocks,
        block_size,
        requests: n_requests,
        finished,
        steps,
        peak_lanes,
        prefix_hits: m.kv_prefix_hits,
        block_hits: m.kv_block_hits,
        block_misses: m.kv_block_misses,
        block_evictions: m.kv_block_evictions,
        blocks_published: m.kv_blocks_published,
        admission_stalls: m.kv_admission_stalls,
        prefill_lane_tokens: m.prefill_lane_tokens,
        prefill_tokens_saved: m.kv_prefill_tokens_saved,
        blocks_free_min: m.kv_blocks_free_min,
        shared_refs_peak: m.kv_shared_refs_peak,
        ttft_p50_ms: s.p50,
        ttft_p90_ms: s.p90,
    })
}

/// JSON form of the kvpage study — the `--sim-clock` byte-identity
/// artifact (`results/BENCH_kvpage.json`, committed as `BENCH_kvpage.json`).
pub fn kvpage_points_json(points: &[KvPagePoint]) -> Json {
    json::arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("config", json::s(&p.label)),
                    ("paged", Json::Bool(p.paged)),
                    ("pool_blocks", json::num(p.pool_blocks as f64)),
                    ("block_size", json::num(p.block_size as f64)),
                    ("requests", json::num(p.requests as f64)),
                    ("finished", json::num(p.finished as f64)),
                    ("steps", json::num(p.steps as f64)),
                    ("peak_lanes", json::num(p.peak_lanes as f64)),
                    ("prefix_hits", json::num(p.prefix_hits as f64)),
                    ("block_hits", json::num(p.block_hits as f64)),
                    ("block_misses", json::num(p.block_misses as f64)),
                    ("block_hit_rate", json::num(p.block_hit_rate())),
                    ("block_evictions", json::num(p.block_evictions as f64)),
                    ("blocks_published", json::num(p.blocks_published as f64)),
                    ("admission_stalls", json::num(p.admission_stalls as f64)),
                    ("prefill_lane_tokens", json::num(p.prefill_lane_tokens as f64)),
                    ("prefill_tokens_saved", json::num(p.prefill_tokens_saved as f64)),
                    ("blocks_free_min", json::num(p.blocks_free_min as f64)),
                    ("shared_refs_peak", json::num(p.shared_refs_peak as f64)),
                    ("ttft_p50_ms", json::num(p.ttft_p50_ms)),
                    ("ttft_p90_ms", json::num(p.ttft_p90_ms)),
                ])
            })
            .collect(),
    )
}

/// Render the kvpage study: paged and flat rows interleaved per budget.
/// `peak-lanes` is the admission-capacity comparison; `hit%`/`saved` show
/// the prefix cache working; `free-min` is the memory headroom.
pub fn render_kvpage_points(title: &str, points: &[KvPagePoint]) -> String {
    let mut t = Table::new(&[
        "config",
        "pool",
        "reqs",
        "fin",
        "peak-lanes",
        "prefix-hits",
        "hit%",
        "evict",
        "stalls",
        "prefill-toks",
        "saved",
        "free-min",
        "ttft p50(ms)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.pool_blocks.to_string(),
            p.requests.to_string(),
            p.finished.to_string(),
            p.peak_lanes.to_string(),
            p.prefix_hits.to_string(),
            fmt_f(p.block_hit_rate() * 100.0, 1),
            p.block_evictions.to_string(),
            p.admission_stalls.to_string(),
            p.prefill_lane_tokens.to_string(),
            p.prefill_tokens_saved.to_string(),
            p.blocks_free_min.to_string(),
            fmt_f(p.ttft_p50_ms, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nAt each pool budget the paged row should admit at least as many \
         concurrent lanes as the flat row (strictly more once the budget is below \
         decode_slots x ceil(max_seq/block) — flat charges every lane a full max_seq \
         footprint), with a non-zero prefix hit rate saving prefill tokens.  One \
         scheduler step = one virtual millisecond.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Router study: placement policies over the deterministic fleet sim
// ---------------------------------------------------------------------------

/// One placement policy's row in the router study: fleet-wide paging and
/// prefix-cache traffic plus the per-replica balance axes.
#[derive(Clone, Debug)]
pub struct RouterPoint {
    pub place: String,
    pub replicas: usize,
    pub requests: usize,
    pub finished: usize,
    /// Requests placed per replica, in replica order (the balance axis:
    /// no replica should starve).
    pub placed: Vec<usize>,
    /// Placements that left the adapter's home replica (affinity only).
    pub spills: usize,
    /// Home re-assignments on sustained imbalance (affinity only).
    pub rehomes: usize,
    /// Adapter-bank paging counters summed across replicas — upload bytes
    /// is the study's headline axis (host-to-device traffic placement
    /// avoids by keeping an adapter's pages on its home replica).
    pub bank_hits: usize,
    pub bank_misses: usize,
    pub bank_evictions: usize,
    pub bank_upload_bytes: usize,
    /// Prefix-cache counters summed across replicas.
    pub prefix_hits: usize,
    pub prefix_misses: usize,
    /// Per-replica queue-wait p99 in virtual ms, replica order (the
    /// starvation axis: every entry stays bounded).
    pub queue_p99_ms: Vec<f64>,
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p99_ms: f64,
    /// Fleet steps from first arrival to drained.
    pub steps: usize,
}

impl RouterPoint {
    /// Fraction of prefix-cache lookups served from cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// The worst per-replica queue-wait p99 (bounded = nobody starves).
    pub fn worst_replica_p99_ms(&self) -> f64 {
        self.queue_p99_ms.iter().copied().fold(0.0, f64::max)
    }
}

/// The placement study on the deterministic fleet sim (`--study router
/// --sim-clock`): every [`PlaceKind`] over the same Zipf shared-prefix
/// hetero-adapter workload on an `n_replicas` fleet whose per-replica
/// bank (5 slots) and prefix cache (6 entries) cannot hold the full
/// working set (12 adapters / prompt groups).  Affinity keeps each
/// adapter's bank pages and prefix entries on its home replica; the
/// spread policies re-page the set on every replica.  Arrivals land
/// every 10 virtual ms, steps cost 5 virtual ms, and all state is
/// integer accounting — two runs emit byte-identical output (CI diffs
/// them).
pub fn router_study_sim(
    n_requests: usize,
    n_replicas: usize,
    new_tokens: usize,
    seed: u64,
) -> Vec<RouterPoint> {
    let arrival_gap = Duration::from_millis(10);
    // 12 adapters, one prompt group each, against 5 bank slots per
    // replica: no single replica can keep everything resident, so
    // placement decides the paging bill.
    let (n_groups, distinct) = (12usize, 12usize);
    let mut out = Vec::new();
    for place in PlaceKind::ALL {
        let cfg = FleetSimConfig {
            place,
            n_replicas,
            bank_slots: 5,
            bank_row_bytes: 4096,
            prefix_cache: 6,
            prefix_len: 12,
            ..FleetSimConfig::default()
        };
        let mut fleet = FleetSim::new(&cfg);
        for a in 0..distinct {
            fleet.register(&format!("adapter-{a}"));
        }
        let mut rng = Rng::seed_from(seed ^ 0x40e7);
        let reqs = prefix_workload(
            &mut rng, n_requests, n_groups, distinct, 1.2, cfg.prefix_len, 4, new_tokens,
        );
        let mut pending: VecDeque<(usize, Request)> = reqs.into_iter().enumerate().collect();
        let mut steps = 0usize;
        loop {
            let due = |pending: &VecDeque<(usize, Request)>| {
                pending.front().map(|(i, _)| arrival_gap * (*i as u32))
            };
            while due(&pending).is_some_and(|d| d <= fleet.elapsed()) {
                let (_, req) = pending.pop_front().expect("due arrival checked");
                fleet.submit(req).expect("study fleet always has a ready replica");
            }
            if pending.is_empty() && !fleet.has_work() {
                break;
            }
            // An idle fleet still steps: the lockstep clocks advance
            // toward the next arrival (there is no cross-replica sleep).
            fleet.step();
            steps += 1;
        }
        out.push(aggregate_router(place.name(), n_requests, steps, &fleet));
    }
    out
}

/// Fold one policy's drained [`FleetSim`] into a study row.
fn aggregate_router(place: &str, requests: usize, steps: usize, fleet: &FleetSim) -> RouterPoint {
    let mut all_waits: Vec<f64> = Vec::new();
    let mut queue_p99_ms: Vec<f64> = Vec::new();
    let mut finished = 0usize;
    let (mut bank_hits, mut bank_misses, mut bank_evictions, mut upload) =
        (0usize, 0usize, 0usize, 0usize);
    let (mut prefix_hits, mut prefix_misses) = (0usize, 0usize);
    for sim in fleet.replicas() {
        let waits: Vec<f64> = sim
            .records()
            .iter()
            .map(|r| (r.admitted_at.unwrap_or(r.finished_at) - r.submitted_at).as_secs_f64() * 1e3)
            .collect();
        finished += sim.records().iter().filter(|r| r.outcome == SimOutcome::Finished).count();
        queue_p99_ms.push(crate::util::stats::summarize(&waits).p99);
        all_waits.extend(waits);
        let b = sim.bank_stats();
        bank_hits += b.hits;
        bank_misses += b.misses;
        bank_evictions += b.evictions;
        upload += b.upload_bytes;
        let p = sim.prefix_stats();
        prefix_hits += p.hits;
        prefix_misses += p.misses;
    }
    let s = crate::util::stats::summarize(&all_waits);
    RouterPoint {
        place: place.to_string(),
        replicas: fleet.replicas().len(),
        requests,
        finished,
        placed: fleet.placed.clone(),
        spills: fleet.placer().spills,
        rehomes: fleet.placer().rehomes,
        bank_hits,
        bank_misses,
        bank_evictions,
        bank_upload_bytes: upload,
        prefix_hits,
        prefix_misses,
        queue_p99_ms,
        queue_wait_p50_ms: s.p50,
        queue_wait_p99_ms: s.p99,
        steps,
    }
}

/// JSON form of the router study — the `--sim-clock` byte-identity
/// artifact (`results/BENCH_router.json`, diffed across CI runs).
pub fn router_points_json(points: &[RouterPoint]) -> Json {
    json::arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("place", json::s(&p.place)),
                    ("replicas", json::num(p.replicas as f64)),
                    ("requests", json::num(p.requests as f64)),
                    ("finished", json::num(p.finished as f64)),
                    (
                        "placed",
                        json::arr(p.placed.iter().map(|&n| json::num(n as f64)).collect()),
                    ),
                    ("spills", json::num(p.spills as f64)),
                    ("rehomes", json::num(p.rehomes as f64)),
                    ("bank_hits", json::num(p.bank_hits as f64)),
                    ("bank_misses", json::num(p.bank_misses as f64)),
                    ("bank_evictions", json::num(p.bank_evictions as f64)),
                    ("bank_upload_bytes", json::num(p.bank_upload_bytes as f64)),
                    ("prefix_hits", json::num(p.prefix_hits as f64)),
                    ("prefix_misses", json::num(p.prefix_misses as f64)),
                    ("prefix_hit_rate", json::num(p.prefix_hit_rate())),
                    (
                        "queue_p99_ms",
                        json::arr(p.queue_p99_ms.iter().map(|&w| json::num(w)).collect()),
                    ),
                    ("queue_wait_p50_ms", json::num(p.queue_wait_p50_ms)),
                    ("queue_wait_p99_ms", json::num(p.queue_wait_p99_ms)),
                    ("steps", json::num(p.steps as f64)),
                ])
            })
            .collect(),
    )
}

/// Render the router study: one row per placement policy.  `upload(KB)`
/// and `prefix-hit%` are the placement axes; `placed` and the worst
/// per-replica wait p99 are the balance axes.
pub fn render_router_points(title: &str, points: &[RouterPoint]) -> String {
    let mut t = Table::new(&[
        "place",
        "reqs",
        "fin",
        "placed",
        "spills",
        "rehomes",
        "upload(KB)",
        "evict",
        "prefix-hit%",
        "wait p50(ms)",
        "wait p99(ms)",
        "worst-replica p99(ms)",
    ]);
    for p in points {
        t.row(vec![
            p.place.clone(),
            p.requests.to_string(),
            p.finished.to_string(),
            p.placed.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("/"),
            p.spills.to_string(),
            p.rehomes.to_string(),
            fmt_f(p.bank_upload_bytes as f64 / 1e3, 1),
            p.bank_evictions.to_string(),
            fmt_f(p.prefix_hit_rate() * 100.0, 1),
            fmt_f(p.queue_wait_p50_ms, 1),
            fmt_f(p.queue_wait_p99_ms, 1),
            fmt_f(p.worst_replica_p99_ms(), 1),
        ]);
    }
    format!(
        "## {title}\n{}\nupload(KB) and prefix-hit% are the placement axes: affinity keeps \
         each adapter's bank pages and prefix entries on its home replica, so at the same \
         Zipf load it re-pages less and hits more than the spread policies.  placed and \
         worst-replica p99 are the balance axes — every replica sees work and none starves.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Adapters study: fused hetero-batch epilogue head-to-head (claim 2)
// ---------------------------------------------------------------------------

/// One (mode, batch, distinct) cell of `--study adapters`: the reference
/// engine's token accounting for a heterogeneous-adapter batch plus the
/// closed-form per-step epilogue cost the head-to-head is plotted on.
///
/// The cost model is deliberately *analytic* (flop and gather-byte counts
/// from the config's projection shapes, scaled by fixed virtual rates)
/// rather than wall-clock: the study is committed and byte-diffed by CI,
/// so every recorded number must be bit-identical across runs and hosts.
#[derive(Clone, Debug)]
pub struct AdapterPoint {
    pub mode: String,
    pub batch: usize,
    pub distinct: usize,
    pub requests: usize,
    pub finished: usize,
    /// Decode steps the reference engine ran draining this cell.
    pub decode_steps: usize,
    /// Generated tokens across all finished requests.
    pub tokens: usize,
    /// Adapter-math flops one batch row pays per decode step, summed over
    /// every adapted projection of every layer.
    pub flops_per_row: usize,
    /// Bank bytes gathered per decode step: one row set per *distinct*
    /// adapter in the batch — the slot-grouped gather reads each resident
    /// row once however many lanes share it.
    pub gather_bytes_per_step: usize,
}

impl AdapterPoint {
    /// Modeled per-step epilogue cost in virtual milliseconds: compute at
    /// 1 Gflop/ms plus gathers at 10 GB/ms-equivalent.  `None` when the
    /// cell never decoded — a failed/empty measurement has no step cost
    /// (it is excluded from the JSON artifact, not recorded as 0.0).
    pub fn ms_per_step(&self) -> Option<f64> {
        (self.decode_steps > 0).then(|| {
            self.batch as f64 * self.flops_per_row as f64 / 1e6
                + self.gather_bytes_per_step as f64 / 1e7
        })
    }
}

/// Per-row adapter flops and per-distinct-adapter bank row bytes for
/// `mode` on `cfg`, summed over every adapted projection of every layer.
///
/// road: Eq. 4 costs two fused multiply-adds and two multiplies per output
/// pair (3 flops/element) and gathers `[r1|r2]` rows.  ia3: one multiply
/// per element, one scale row.  lora: the bmm epilogue pays `x·B` then
/// `·A` (2 flops per weight element) and gathers both factor matrices —
/// the rank-independent element-wise modes vs the rank-scaled bmm is
/// exactly the paper's claim-(2) comparison.
fn epilogue_cost(cfg: &ModelConfigInfo, mode: &str) -> (usize, usize) {
    let (mut flops, mut bytes) = (0usize, 0usize);
    for _ in 0..cfg.n_layers {
        for proj in PROJS {
            let (d_in, d_out) = proj_dims(cfg, proj);
            match mode {
                "road" => {
                    flops += 3 * d_out;
                    bytes += 2 * d_out * 4;
                }
                "ia3" => {
                    flops += d_out;
                    bytes += d_out * 4;
                }
                "lora" => {
                    flops += 2 * cfg.lora_rank * (d_in + d_out);
                    bytes += 4 * cfg.lora_rank * (d_in + d_out);
                }
                _ => {}
            }
        }
    }
    (flops, bytes)
}

/// One cell of the adapters study: a fresh reference engine on `model`,
/// `distinct` random adapters of `mode`, and a heterogeneous round-robin
/// workload of `max(batch, distinct)` short requests driven to drain on a
/// manual clock.
fn adapters_point(
    rt: &Rc<Runtime>,
    model: &str,
    mode: &str,
    batch: usize,
    distinct: usize,
    seed: u64,
) -> Result<AdapterPoint> {
    let (prompt_len, new_tokens) = (8usize, 4usize);
    let clock = Clock::manual();
    let econf = EngineConfig {
        model: model.into(),
        mode: mode.into(),
        decode_slots: batch,
        queue_capacity: 4096,
        clock: clock.clone(),
        backend: rt.backend,
        ..Default::default()
    };
    let mut engine = Engine::new(rt.clone(), econf)?;
    register_adapters(&mut engine, distinct, seed)?;
    let n_requests = batch.max(distinct);
    let mut rng = Rng::seed_from(seed ^ 0xada7);
    let reqs = hetero_workload(&mut rng, n_requests, distinct, prompt_len, new_tokens);
    for r in reqs {
        engine.submit(r)?;
    }
    let (mut finished, mut tokens) = (0usize, 0usize);
    while engine.has_work() {
        for ev in engine.step()? {
            if let StreamEvent::Finished(o) = ev {
                finished += 1;
                tokens += o.tokens.len();
            }
        }
        clock.advance(Duration::from_millis(1));
    }
    let (flops_per_row, row_bytes) = epilogue_cost(&engine.cfg, mode);
    Ok(AdapterPoint {
        mode: mode.to_string(),
        batch,
        distinct,
        requests: n_requests,
        finished,
        decode_steps: engine.metrics.decode_steps,
        tokens,
        flops_per_row,
        gather_bytes_per_step: batch.min(distinct) * row_bytes,
    })
}

/// The `--study adapters` sweep: hetero-batch RoAd vs the LoRA-bmm
/// baseline vs ia3 across batch 1/4/8/16 and 1..16 distinct adapters on
/// the reference backend (`results/BENCH_adapters.json`, committed and
/// CI byte-diffed like the sched/kvpage/router studies).
pub fn adapters_study(rt: &Rc<Runtime>, seed: u64) -> Result<Vec<AdapterPoint>> {
    let mut out = Vec::new();
    for mode in ["road", "lora", "ia3"] {
        for batch in [1usize, 4, 8, 16] {
            for distinct in [1usize, 2, 4, 8, 16] {
                out.push(adapters_point(rt, "serve", mode, batch, distinct, seed)?);
            }
        }
    }
    Ok(out)
}

/// JSON form of the adapters study — the byte-identity artifact.  Cells
/// that never decoded are excluded outright: an absent point is honest,
/// a fabricated `0.0` ms/step reads as infinitely fast.
pub fn adapters_points_json(points: &[AdapterPoint]) -> Json {
    json::arr(
        points
            .iter()
            .filter_map(|p| {
                let ms = p.ms_per_step()?;
                Some(json::obj(vec![
                    ("mode", json::s(&p.mode)),
                    ("batch", json::num(p.batch as f64)),
                    ("distinct", json::num(p.distinct as f64)),
                    ("requests", json::num(p.requests as f64)),
                    ("finished", json::num(p.finished as f64)),
                    ("decode_steps", json::num(p.decode_steps as f64)),
                    ("tokens", json::num(p.tokens as f64)),
                    ("flops_per_row", json::num(p.flops_per_row as f64)),
                    ("gather_bytes_per_step", json::num(p.gather_bytes_per_step as f64)),
                    ("ms_per_step", json::num(ms)),
                ]))
            })
            .collect(),
    )
}

/// Render the adapters study: `ms/step` is the head-to-head axis.
pub fn render_adapters_points(title: &str, points: &[AdapterPoint]) -> String {
    let mut t = Table::new(&[
        "mode", "batch", "#adapters", "reqs", "fin", "steps", "flops/row", "gather(KB)",
        "ms/step",
    ]);
    for p in points {
        let ms = match p.ms_per_step() {
            Some(v) => fmt_f(v, 4),
            None => "n/a".to_string(),
        };
        t.row(vec![
            p.mode.clone(),
            p.batch.to_string(),
            p.distinct.to_string(),
            p.requests.to_string(),
            p.finished.to_string(),
            p.decode_steps.to_string(),
            p.flops_per_row.to_string(),
            fmt_f(p.gather_bytes_per_step as f64 / 1e3, 1),
            ms,
        ]);
    }
    format!(
        "## {title}\n{}\nms/step is the modeled per-decode-step adapter-epilogue cost \
         (analytic flop + gather-byte counts at fixed virtual rates, so CI can byte-diff \
         the run).  RoAd and ia3 pay an element-wise epilogue that is independent of rank, \
         so their per-row cost stays flat while the LoRA bmm baseline scales with \
         rank x (d_in + d_out) — the paper's claim-(2) separation, which widens with \
         batch.  The gather column is the banked-row traffic: slot-grouped gathers read \
         each distinct adapter's rows once per step however many lanes share them.\n",
        t.render()
    )
}

/// Figure 4 (Left): merged vs unmerged LoRA.  The merged path is the base
/// model (adapter folded into W, paper §4.2); the unmerged path pays the
/// per-layer bmm epilogue.  Rank is compile-time-fixed in the artifacts,
/// so the sweep axis here is the serving mode; the rank effect is covered
/// by the adapter_ops microbench.
pub fn fig4_left(rt: &Rc<Runtime>, new_tokens: usize, seed: u64) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    // batch 1, single adapter — the paper's configuration.
    let mut merged = measure_serving(rt, "serve", "base", 1, 0, 4, new_tokens, seed)?;
    merged.label = "lora-merged(base)".into();
    out.push(merged);
    let mut unmerged = measure_serving(rt, "serve", "lora", 1, 1, 4, new_tokens, seed)?;
    unmerged.label = "lora-unmerged".into();
    out.push(unmerged);
    let mut road = measure_serving(rt, "serve", "road", 1, 1, 4, new_tokens, seed)?;
    road.label = "road-unmerged".into();
    out.push(road);
    Ok(out)
}

/// Figure 4 (Middle): throughput vs #generated tokens at batch 8, eight
/// distinct adapters (fully heterogeneous).
pub fn fig4_middle(
    rt: &Rc<Runtime>,
    token_counts: &[usize],
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &nt in token_counts {
        for mode in ["road", "lora"] {
            let mut p = measure_serving(rt, "serve", mode, 8, 8, 16, nt, seed)?;
            p.label = format!("{mode}/t{nt}");
            out.push(p);
        }
    }
    Ok(out)
}

/// Figure 4 (Right): throughput vs #distinct adapters at batch 8.
pub fn fig4_right(
    rt: &Rc<Runtime>,
    distinct_counts: &[usize],
    new_tokens: usize,
    seed: u64,
) -> Result<Vec<ServingPoint>> {
    let mut out = Vec::new();
    for &d in distinct_counts {
        for mode in ["road", "lora"] {
            out.push(measure_serving(rt, "serve", mode, 8, d, 16, new_tokens, seed)?);
        }
    }
    Ok(out)
}

/// Render the bank-churn study with its paging counters; the `upload(KB)`
/// column is the comparison the study exists for (paged rows strictly
/// below the whole-bank baseline).
pub fn render_bank_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "reqs", "tok/s", "hits", "misses", "evictions",
        "upload(KB)",
    ]);
    for p in points {
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.requests.to_string(),
            fmt_f(p.tokens_per_sec, 1),
            p.bank_hits.to_string(),
            p.bank_misses.to_string(),
            p.bank_evictions.to_string(),
            fmt_f(p.bank_upload_bytes as f64 / 1e3, 1),
        ]);
    }
    format!(
        "## {title}\n{}\nupload(KB) is the comparison axis (host-to-device bank traffic). \
         On the offline stub, paged wall-time additionally pays the device-side scatter \
         stand-in (see AdapterBank::upload_dirty), so tok/s there favors no side.\n",
        t.render()
    )
}

pub fn render_points(title: &str, points: &[ServingPoint]) -> String {
    let mut t = Table::new(&[
        "config", "batch", "#adapters", "new-toks", "reqs", "wall(s)", "tok/s", "ms/step",
    ]);
    for p in points {
        // A run that never decoded has no step cost — rendering it as 0.0
        // would pass off a failed/empty measurement as infinitely fast.
        let ms_per_step = match p.ms_per_step() {
            Some(v) => fmt_f(v, 3),
            None => "n/a".to_string(),
        };
        t.row(vec![
            p.label.clone(),
            p.batch.to_string(),
            p.distinct_adapters.to_string(),
            p.new_tokens.to_string(),
            p.requests.to_string(),
            fmt_f(p.wall_secs, 2),
            fmt_f(p.tokens_per_sec, 1),
            ms_per_step,
        ]);
    }
    format!("## {title}\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Table D.1: finetuning efficiency (RoAd vs OFT Cayley)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct TrainEfficiency {
    pub method: String,
    pub n_trainable: usize,
    pub iters: usize,
    pub wall_secs: f64,
    pub secs_per_step: f64,
    /// Trainable + AdamW state footprint in bytes (the part that scales
    /// with the method; the paper's "peak GPU memory" analogue on a
    /// host-state basis).
    pub state_bytes: usize,
}

/// Time `iters` optimizer steps of `method` on random LM batches.
pub fn measure_train_efficiency(
    rt: &Rc<Runtime>,
    config: &str,
    method: &str,
    iters: usize,
    seed: u64,
) -> Result<TrainEfficiency> {
    let mut tr = Trainer::new(rt.clone(), config, method)?;
    let (b, l) = (tr.batch, tr.seq_len);
    let mut rng = Rng::seed_from(seed);
    let recipe = Recipe::default().with_steps(iters);

    // Warm-up step excluded from timing (compile/caches).
    let mk = |rng: &mut Rng| -> TrainBatch {
        let tokens: Vec<i32> = (0..b * l).map(|_| 1 + rng.below(255) as i32).collect();
        let mut targets = vec![0i32; b * l];
        for row in 0..b {
            for p in 0..l - 1 {
                targets[row * l + p] = tokens[row * l + p + 1];
            }
        }
        TrainBatch { tokens, targets, mask: vec![1.0; b * l] }
    };
    let warm = mk(&mut rng);
    tr.step(&warm, recipe.lr_at(0))?;

    // roadlint: allow(clock-discipline) -- wall-profiles real optimizer
    // throughput (secs/step); virtual time has no meaning here.
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let batch = mk(&mut rng);
        tr.step(&batch, recipe.lr_at(i))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let state_bytes = tr.n_trainable * 4 * 3; // params + m + v
    Ok(TrainEfficiency {
        method: method.to_string(),
        n_trainable: tr.n_trainable,
        iters,
        wall_secs: wall,
        secs_per_step: wall / iters as f64,
        state_bytes,
    })
}

pub fn render_train_efficiency(rows: &[TrainEfficiency]) -> String {
    let mut t = Table::new(&[
        "method", "#trainable", "iters", "wall(s)", "s/step", "state(KB)",
    ]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            r.n_trainable.to_string(),
            r.iters.to_string(),
            fmt_f(r.wall_secs, 2),
            fmt_f(r.secs_per_step, 4),
            fmt_f(r.state_bytes as f64 / 1024.0, 1),
        ]);
    }
    format!("## Table D.1 analogue: finetuning efficiency\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_round_robins_adapters() {
        let mut rng = Rng::seed_from(1);
        let reqs = hetero_workload(&mut rng, 8, 4, 8, 16);
        assert_eq!(reqs.len(), 8);
        assert_eq!(reqs[0].adapter.as_deref(), Some("adapter-0"));
        assert_eq!(reqs[5].adapter.as_deref(), Some("adapter-1"));
        assert!(reqs.iter().all(|r| r.prompt.len() == 8));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t > 0)));
    }

    #[test]
    fn workload_without_adapters_is_base() {
        let mut rng = Rng::seed_from(2);
        let reqs = hetero_workload(&mut rng, 3, 0, 4, 8);
        assert!(reqs.iter().all(|r| r.adapter.is_none()));
    }

    #[test]
    fn render_points_shows_na_for_zero_step_runs() {
        let p = ServingPoint {
            label: "road/d8".into(),
            batch: 8,
            distinct_adapters: 8,
            new_tokens: 1,
            requests: 16,
            wall_secs: 0.5,
            tokens_per_sec: 32.0,
            // Every request finished at prefill: no decode ever ran, so
            // there is no per-step cost to report.
            decode_steps: 0,
            decode_secs: 0.0,
            bank_hits: 0,
            bank_misses: 0,
            bank_evictions: 0,
            bank_upload_bytes: 0,
        };
        let s = render_points("Fig 4", &[p]);
        assert!(s.contains("n/a"), "zero-step run must render n/a, not 0.0:\n{s}");
        assert!(!s.contains("0.000"), "no fabricated 0.0 ms/step:\n{s}");
    }

    #[test]
    fn adapters_json_excludes_zero_step_points_and_renders_na() {
        let good = AdapterPoint {
            mode: "road".into(),
            batch: 4,
            distinct: 2,
            requests: 4,
            finished: 4,
            decode_steps: 3,
            tokens: 16,
            flops_per_row: 33792,
            gather_bytes_per_step: 180224,
        };
        let empty = AdapterPoint { decode_steps: 0, tokens: 0, finished: 0, ..good.clone() };
        let j = adapters_points_json(&[good.clone(), empty.clone()]);
        assert_eq!(j.as_arr().unwrap().len(), 1, "zero-step point must be excluded");
        let md = render_adapters_points("Adapters", &[good, empty]);
        assert!(md.contains("n/a"), "zero-step row renders n/a:\n{md}");
        assert!(md.contains("ms/step"), "{md}");
    }

    #[test]
    fn adapters_point_is_deterministic_and_counts_steps() {
        let rt = Rc::new(Runtime::reference());
        let a = adapters_point(&rt, "tiny", "road", 2, 2, 7).unwrap();
        let b = adapters_point(&rt, "tiny", "road", 2, 2, 7).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed, same point");
        // 2 requests on 2 lanes, 4 new tokens each: the first token comes
        // from the prefill batch and the remaining three from decode steps,
        // all lanes in lockstep.
        assert_eq!(a.requests, 2);
        assert_eq!(a.finished, 2);
        assert_eq!(a.decode_steps, 3);
        assert_eq!(a.tokens, 8);
        assert!(a.ms_per_step().is_some());
        let j = adapters_points_json(&[a]);
        assert_eq!(j.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn adapters_cost_model_separates_road_from_lora_bmm() {
        let rt = Rc::new(Runtime::reference());
        let cfg = rt.manifest.config("serve").unwrap();
        let (road_flops, road_bytes) = epilogue_cost(cfg, "road");
        let (lora_flops, lora_bytes) = epilogue_cost(cfg, "lora");
        let (ia3_flops, _) = epilogue_cost(cfg, "ia3");
        // Element-wise vs rank-scaled bmm: the separation the study plots.
        assert!(road_flops < lora_flops, "{road_flops} !< {lora_flops}");
        assert!(road_bytes < lora_bytes);
        assert!(ia3_flops < road_flops);
        // The acceptance axis: fused RoAd beats the LoRA bmm baseline at
        // every batch size (the gap only widens with batch).
        for batch in [1usize, 4, 8, 16] {
            let mk = |mode: &str, flops: usize, bytes: usize| AdapterPoint {
                mode: mode.into(),
                batch,
                distinct: batch,
                requests: batch,
                finished: batch,
                decode_steps: 3,
                tokens: 4 * batch,
                flops_per_row: flops,
                gather_bytes_per_step: batch * bytes,
            };
            let road = mk("road", road_flops, road_bytes).ms_per_step().unwrap();
            let lora = mk("lora", lora_flops, lora_bytes).ms_per_step().unwrap();
            assert!(road < lora, "batch {batch}: road {road} !< lora {lora}");
        }
    }

    #[test]
    fn render_produces_rows() {
        let p = ServingPoint {
            label: "road/d8".into(),
            batch: 8,
            distinct_adapters: 8,
            new_tokens: 128,
            requests: 16,
            wall_secs: 1.5,
            tokens_per_sec: 1365.3,
            decode_steps: 256,
            decode_secs: 1.28,
            bank_hits: 12,
            bank_misses: 4,
            bank_evictions: 1,
            bank_upload_bytes: 8192,
        };
        let s = render_points("Fig 4 (Right)", &[p.clone()]);
        assert!(s.contains("road/d8"));
        assert!(s.contains("1365.3"));
        let b = render_bank_points("Bank churn", &[p]);
        assert!(b.contains("hits"), "{b}");
        assert!(b.contains("12"), "{b}");
        assert!(b.contains("8.2"), "upload KB column: {b}");
    }

    #[test]
    fn render_streaming_table_has_reclaim_columns() {
        let p = StreamingPoint {
            label: "stream/cancel-half".into(),
            requests: 16,
            completed: 7,
            cancelled: 8,
            errored: 1,
            tokens_streamed: 512,
            wall_secs: 2.5,
            observed_ttft_p50_ms: 12.5,
            observed_ttft_p90_ms: 31.0,
        };
        let s = render_streaming_points("Streaming", &[p]);
        for needle in ["cancelled", "errored", "tok-streamed", "obs-ttft p50(ms)", "12.5", "512"] {
            assert!(s.contains(needle), "missing {needle:?} in\n{s}");
        }
    }

    #[test]
    fn sched_study_sim_conserves_and_renders() {
        let pts = sched_study_sim(24, 4, 6, 3);
        assert_eq!(pts.len(), PolicyKind::ALL.len());
        for p in &pts {
            // No cancels in the study: every request finishes or is shed.
            assert_eq!(p.finished + p.shed, p.requests, "{}: leaked requests", p.policy);
            assert!(!p.per_adapter.is_empty());
        }
        let md = render_sched_points("Sched", &pts);
        for needle in ["fcfs", "edf", "priority", "fair", "miss-rate", "starvation(ms)"] {
            assert!(md.contains(needle), "missing {needle:?} in\n{md}");
        }
        let j = sched_points_json(&pts).to_string_compact();
        assert!(!j.contains('\n'), "compact JSON is one line");
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 4);
        assert_eq!(back.as_arr().unwrap()[0].get("policy").unwrap().as_str().unwrap(), "fcfs");
    }

    #[test]
    fn sched_workload_decoration_is_deterministic() {
        let (a, b) = (sched_workload(30, 5, 1.2, 8, 11), sched_workload(30, 5, 1.2, 8, 11));
        assert_eq!(a.len(), 30);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.adapter, y.adapter);
            assert_eq!(x.deadline, y.deadline);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.prompt, y.prompt);
        }
        // The decoration actually lands: some deadlines, some tiers.
        assert!(a.iter().any(|r| r.deadline.is_some()));
        assert!(a.iter().any(|r| r.priority > 0));
        assert!(a.iter().any(|r| r.deadline.is_none() && r.priority == 0));
    }

    #[test]
    fn zipf_workload_skews_to_head_adapters() {
        let mut rng = Rng::seed_from(5);
        let n = 64;
        let reqs = zipf_workload(&mut rng, 512, n, 1.1, 8, 16);
        assert_eq!(reqs.len(), 512);
        let mut counts = vec![0usize; n];
        for r in &reqs {
            let name = r.adapter.as_deref().unwrap();
            let k: usize = name.strip_prefix("adapter-").unwrap().parse().unwrap();
            counts[k] += 1;
        }
        let head: usize = counts[..4].iter().sum();
        let tail: usize = counts[n - 4..].iter().sum();
        assert!(head > tail * 4, "zipf head {head} should dominate tail {tail}");
        // Rank 0 is the most popular adapter.
        let max = counts.iter().copied().max().unwrap();
        assert_eq!(counts[0], max, "{counts:?}");
    }

    #[test]
    fn zipf_sample_in_range_and_deterministic() {
        let mut a = Rng::seed_from(9);
        let mut b = Rng::seed_from(9);
        for _ in 0..200 {
            let x = zipf_sample(&mut a, 7, 1.0);
            assert!(x < 7);
            assert_eq!(x, zipf_sample(&mut b, 7, 1.0));
        }
    }

    #[test]
    fn prefix_workload_shares_prefixes_within_groups() {
        let mut rng = Rng::seed_from(11);
        let reqs = prefix_workload(&mut rng, 64, 8, 2, 1.1, 12, 4, 16);
        assert_eq!(reqs.len(), 64);
        // Group a request by its first 12 tokens: same prefix => same adapter,
        // and the hot groups recur (that's what the cache feeds on).
        let mut by_prefix: std::collections::HashMap<Vec<i32>, Vec<&Request>> =
            std::collections::HashMap::new();
        for r in &reqs {
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new_tokens, 16);
            by_prefix.entry(r.prompt[..12].to_vec()).or_default().push(r);
        }
        assert!(by_prefix.len() <= 8, "at most n_groups distinct prefixes");
        for group in by_prefix.values() {
            let adapter = &group[0].adapter;
            assert!(group.iter().all(|r| &r.adapter == adapter));
        }
        assert!(
            by_prefix.values().any(|g| g.len() >= 8),
            "zipf head group should recur often"
        );
        // Same seed replays the same workload.
        let mut rng2 = Rng::seed_from(11);
        let again = prefix_workload(&mut rng2, 64, 8, 2, 1.1, 12, 4, 16);
        for (x, y) in reqs.iter().zip(&again) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.adapter, y.adapter);
        }
    }

    #[test]
    fn kvpage_study_paged_beats_flat_at_tight_budgets() {
        let rt = Rc::new(Runtime::reference());
        let pts = kvpage_study(&rt, 24, 16, &[32, 64], 7).unwrap();
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert_eq!(p.finished, p.requests, "{}: leaked requests", p.label);
        }
        for pair in pts.chunks(2) {
            let (paged, flat) = (&pair[0], &pair[1]);
            assert!(paged.paged && !flat.paged);
            assert_eq!(paged.pool_blocks, flat.pool_blocks);
            // Flat charges ceil(128/4) = 32 blocks per lane, so at these
            // budgets it serializes; paged fits many lanes and shares blocks.
            assert!(
                paged.peak_lanes > flat.peak_lanes,
                "pool {}: paged peak {} vs flat {}",
                paged.pool_blocks,
                paged.peak_lanes,
                flat.peak_lanes
            );
            assert!(paged.prefix_hits > 0, "warm zipf workload should hit");
            assert!(paged.block_hit_rate() > 0.0);
            assert!(paged.prefill_tokens_saved > 0);
            // Flat mode has no prefix cache at all.
            assert_eq!(flat.prefix_hits, 0);
            assert_eq!(flat.block_hits, 0);
            assert_eq!(flat.blocks_published, 0);
        }
        // The study is a pure function of its seed.
        let again = kvpage_study(&rt, 24, 16, &[32, 64], 7).unwrap();
        assert_eq!(
            kvpage_points_json(&pts).to_string_compact(),
            kvpage_points_json(&again).to_string_compact()
        );
        let md = render_kvpage_points("KV", &pts);
        for needle in ["paged/pool32", "flat/pool64", "peak-lanes", "hit%", "free-min"] {
            assert!(md.contains(needle), "missing {needle:?} in\n{md}");
        }
        let back = Json::parse(&kvpage_points_json(&pts).to_string_compact()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 4);
    }

    #[test]
    fn router_study_affinity_beats_spread_on_paging_without_starvation() {
        let pts = router_study_sim(96, 3, 8, 7);
        assert_eq!(pts.len(), PlaceKind::ALL.len());
        for p in &pts {
            assert_eq!(p.finished, p.requests, "{}: leaked requests", p.place);
            assert_eq!(p.placed.iter().sum::<usize>(), p.requests, "{}: placement total", p.place);
            assert!(
                p.placed.iter().all(|&n| n > 0),
                "{}: starved replica in {:?}",
                p.place,
                p.placed
            );
            // Bounded queue waits on every replica: the fleet is
            // under-subscribed (12 lanes vs one arrival / 10 ms), so a
            // placement policy that parks work behind one hot replica
            // would blow far past this.
            assert!(
                p.worst_replica_p99_ms() < 1_000.0,
                "{}: unbounded wait {:?}",
                p.place,
                p.queue_p99_ms
            );
        }
        let by = |name: &str| pts.iter().find(|p| p.place == name).unwrap();
        let (aff, rr) = (by("affinity"), by("round-robin"));
        // The study's claim: at equal Zipf load, affinity pays less bank
        // traffic and hits the prefix cache more than spreading does.
        assert!(
            aff.bank_upload_bytes < rr.bank_upload_bytes,
            "affinity upload {} !< round-robin {}",
            aff.bank_upload_bytes,
            rr.bank_upload_bytes
        );
        assert!(
            aff.prefix_hit_rate() > rr.prefix_hit_rate(),
            "affinity hit rate {:.3} !> round-robin {:.3}",
            aff.prefix_hit_rate(),
            rr.prefix_hit_rate()
        );
        assert!(aff.bank_evictions <= rr.bank_evictions);
        // A pure function of the seed: byte-identical replay.
        let again = router_study_sim(96, 3, 8, 7);
        assert_eq!(
            router_points_json(&pts).to_string_compact(),
            router_points_json(&again).to_string_compact()
        );
        let md = render_router_points("Router", &pts);
        for needle in
            ["affinity", "least-loaded", "round-robin", "upload(KB)", "prefix-hit%", "placed"]
        {
            assert!(md.contains(needle), "missing {needle:?} in\n{md}");
        }
        let back = Json::parse(&router_points_json(&pts).to_string_compact()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 3);
        assert_eq!(back.as_arr().unwrap()[0].get("place").unwrap().as_str().unwrap(), "affinity");
    }
}
