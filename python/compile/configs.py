"""Model configuration presets for the RoAd reproduction.

Every preset is a fully static description of a tiny LLaMA-style
transformer.  The same config object is consumed by model.py (forward
graphs), train.py (training graphs) and aot.py (artifact manifest), and is
serialized into artifacts/manifest.json so the rust side never has to guess
shapes.

CPU-only substitution for the paper's LLaMA-7B/13B and RoBERTa backbones:
the RoAd mechanism is per-linear-layer and architecture-shape independent,
so small widths/depths preserve every behaviour under study (see
DESIGN.md §4).
"""

from dataclasses import dataclass, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    # Number of adapter slots held in the serving-side banks.
    n_adapters: int = 16
    # LoRA rank used for the lora baseline banks / training graphs.
    lora_rank: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


# Adapted projections: every linear layer of a block, as in the paper
# ("RoAd is applied to all linear layers").  (name, in_dim_key, out_dim_key)
PROJS = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")


def proj_dims(cfg: ModelConfig, proj: str) -> tuple[int, int]:
    """(d_in, d_out) of a projection."""
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wq": (D, D),
        "wk": (D, D),
        "wv": (D, D),
        "wo": (D, D),
        "wgate": (D, F),
        "wup": (D, F),
        "wdown": (F, D),
    }[proj]


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# Unit-test scale: fast pytest sweeps.
TINY = ModelConfig(
    name="tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
    d_ff=192, max_seq=128, n_adapters=4, lora_rank=4,
)

# Serving benchmark scale (Figure 4): deep enough that the adapter path is a
# measurable fraction of step time, small enough for CPU decode throughput.
SERVE = ModelConfig(
    name="serve", vocab=256, d_model=256, n_layers=4, n_heads=8,
    d_ff=768, max_seq=288, n_adapters=16, lora_rank=8,
)

# Finetuning-experiment scale (Tables 2-6, Figure 2/5): trained for a few
# hundred steps per method per task on synthetic suites.
TRAIN = ModelConfig(
    name="train", vocab=256, d_model=128, n_layers=3, n_heads=4,
    d_ff=384, max_seq=96, n_adapters=4, lora_rank=8,
)

# Second model preset ("LLaMA2/3 analogue" for Table D.2): different
# width/depth ratio, same interface.
TRAIN2 = ModelConfig(
    name="train2", vocab=256, d_model=96, n_layers=4, n_heads=6,
    d_ff=288, max_seq=96, n_adapters=4, lora_rank=8,
)

PRESETS = {c.name: c for c in (TINY, SERVE, TRAIN, TRAIN2)}


def get(name: str) -> ModelConfig:
    return PRESETS[name]
