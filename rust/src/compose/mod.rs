//! Composability experiment (paper §4.3, Figure 5).
//!
//! RoAd as a distributed interchange intervention: Φ(h) = R h.  Disjoint
//! 2×2 blocks of R are orthogonal subspaces, so two tasks can be trained
//! *simultaneously* into the two halves of R — the upper half on task A
//! ("German completions" analogue), the lower half on task B ("English
//! instruction following" analogue) — by masking the complementary blocks'
//! gradients (the `road1_masked` step graph).  After training, the
//! combined R exhibits both behaviours.
//!
//! The substitution for HellaSwag-de / Ultrafeedback (DESIGN.md §4): two
//! synthetic "languages" over disjoint alphabets — task A answers in the
//! uppercase alphabet, task B in lowercase — trained from English-alphabet
//! prompts.

use std::rc::Rc;

use anyhow::Result;

use crate::adapters::{Adapter, RoadAdapter};
use crate::runtime::Runtime;
use crate::tasks::{lm_batch, Example, Metric, Task};
use crate::trainer::{loop_::BatchSource, Trainer};
use crate::util::rng::Rng;

/// Task A ("German subspace" analogue): prompts in lowercase letters, gold
/// completion = the same word *translated* into the uppercase alphabet
/// (a fixed letter-wise cipher).  The model must learn to respond in the
/// foreign alphabet.
pub struct ForeignEcho;

impl Task for ForeignEcho {
    fn name(&self) -> &'static str {
        "foreign-echo"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = 1 + rng.below(2);
        let word: String = (0..n).map(|_| (b'a' + rng.below(8) as u8) as char).collect();
        let foreign: String = word.chars().map(|c| c.to_ascii_uppercase()).collect();
        Example::gen(&format!("g:{word}>"), &format!("{foreign}."))
    }
}

/// Task B ("instruction following" analogue): reverse the word, answer in
/// the native lowercase alphabet.
pub struct NativeReverse;

impl Task for NativeReverse {
    fn name(&self) -> &'static str {
        "native-reverse"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = 2;
        let word: String = (0..n).map(|_| (b'a' + rng.below(8) as u8) as char).collect();
        let rev: String = word.chars().rev().collect();
        Example::gen(&format!("i:{word}>"), &format!("{rev}."))
    }
}

/// Alternating-task batch source: even batches from A, odd from B — the
/// "both tasks are simultaneously trained" protocol.
pub struct AlternatingSource<'a> {
    pub a: &'a dyn Task,
    pub b: &'a dyn Task,
    pub batch: usize,
    pub seq_len: usize,
    pub tick: usize,
}

impl BatchSource for AlternatingSource<'_> {
    fn next_batch(&mut self, rng: &mut Rng) -> crate::trainer::TrainBatch {
        let t: &dyn Task = if self.tick % 2 == 0 { self.a } else { self.b };
        self.tick += 1;
        let exs: Vec<Example> = (0..self.batch).map(|_| t.sample(rng)).collect();
        lm_batch(&exs, self.batch, self.seq_len)
    }
}

/// Which half of each RoAd block-vector a task owns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Half {
    Upper,
    Lower,
}

/// Positional mask over a length-n trainable: true where the element's
/// block index falls in the task's half.
pub fn half_mask_sized(half: Half, n_blocks: usize) -> impl Fn(usize) -> bool + Copy {
    move |idx: usize| {
        let upper = idx < n_blocks / 2;
        (half == Half::Upper) == upper
    }
}

/// Result of the composability run: the three adapters (A-only half,
/// B-only half, combined) plus training diagnostics.
pub struct ComposeOutcome {
    pub adapter_a: RoadAdapter,
    pub adapter_b: RoadAdapter,
    pub combined: RoadAdapter,
    pub loss_a: f32,
    pub loss_b: f32,
}

/// Train both halves simultaneously (one `road1_masked` trainer whose mask
/// alternates with the task — exactly Fig 5's protocol), then split the
/// result into per-half adapters and the combined adapter.
pub fn train_composed(
    rt: &Rc<Runtime>,
    config: &str,
    steps: usize,
    seed: u64,
) -> Result<ComposeOutcome> {
    let mut tr = Trainer::new(rt.clone(), config, "road1_masked")?;
    let (b, l) = (tr.batch, tr.seq_len);
    let task_a = ForeignEcho;
    let task_b = NativeReverse;
    let peak_lr = 1e-2f32; // RoAd takes large LRs (paper §C.1); Fig 5 used 5e-3

    let mut rng = Rng::seed_from(seed);
    let mut loss_a = f32::NAN;
    let mut loss_b = f32::NAN;
    for step in 0..steps {
        let (task, half): (&dyn Task, Half) = if step % 2 == 0 {
            (&task_a, Half::Upper)
        } else {
            (&task_b, Half::Lower)
        };
        // Mask the complementary half's gradients for this step.
        set_half_mask(&mut tr, half)?;

        let exs: Vec<Example> = (0..b).map(|_| task.sample(&mut rng)).collect();
        let batch = lm_batch(&exs, b, l);
        let lr = peak_lr * warm_frac(step, steps);
        let loss = tr.step(&batch, lr)?;
        if step % 2 == 0 {
            loss_a = loss;
        } else {
            loss_b = loss;
        }
    }

    // Export the combined adapter, then split halves against identity.
    let combined = match tr.export_adapter()? {
        Adapter::Road(a) => a,
        _ => unreachable!(),
    };
    let identity = RoadAdapter::identity(&tr.cfg);
    // adapter_a = upper half of combined + identity lower half.
    let adapter_a = RoadAdapter::compose(&combined, &identity, 0.5)?;
    // adapter_b = identity upper half + lower half of combined.
    let adapter_b = RoadAdapter::compose(&identity, &combined, 0.5)?;
    Ok(ComposeOutcome { adapter_a, adapter_b, combined, loss_a, loss_b })
}

fn warm_frac(step: usize, total: usize) -> f32 {
    let warm = (total as f32 * 0.1).max(1.0);
    ((step as f32 + 1.0) / warm).min(1.0)
}

/// Install the per-tensor half mask on a road1_masked trainer.
pub fn set_half_mask(tr: &mut Trainer, half: Half) -> Result<()> {
    // Capture tensor sizes first: the closure only sees (name, idx).
    let sizes: std::collections::BTreeMap<String, usize> =
        tr.trainable().iter().map(|(n, t)| (n.clone(), t.elem_count())).collect();
    tr.set_grad_mask(move |name, idx| {
        let n = sizes[name];
        let upper = idx < n / 2;
        (half == Half::Upper) == upper
    })
}

/// Exact-match accuracy of `adapter` on `task` through the generative
/// engine path (used to score each subspace and the combination).
pub fn score_adapter(
    engine: &mut crate::coordinator::engine::Engine,
    name: &str,
    adapter: &RoadAdapter,
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Result<f64> {
    engine.register_adapter(name, &Adapter::Road(adapter.clone()))?;
    let eval = crate::tasks::eval_exact_match(engine, Some(name), task, n, seed)?;
    Ok(eval.score)
}

/// Qualitative transcript entry (the Fig 5 presentation format).
pub struct Transcript {
    pub prompt: String,
    pub subspace: String,
    pub response: String,
}

/// Generate qualitative samples with a given adapter (Fig 5's per-subspace
/// responses).
pub fn sample_responses(
    engine: &mut crate::coordinator::engine::Engine,
    adapter_name: &str,
    prompts: &[String],
    max_new: usize,
) -> Result<Vec<Transcript>> {
    let mut reqs = Vec::new();
    for p in prompts.iter() {
        reqs.push(
            crate::coordinator::request::Request::new(crate::tokenizer::encode(p), max_new)
                .with_adapter(adapter_name)
                .with_sampling(crate::coordinator::request::SamplingParams {
                    temperature: 0.0,
                    top_k: 0,
                    seed: 0,
                    stop_token: Some(b'.' as i32),
                }),
        );
    }
    let mut outs = engine.run_all(reqs)?;
    // Engine-issued ids are monotonic in submission order: sort to pair
    // outputs back with their prompts.
    outs.sort_by_key(|o| o.id);
    let mut ts: Vec<Transcript> = outs
        .into_iter()
        .zip(prompts)
        .map(|(o, p)| Transcript {
            prompt: p.clone(),
            subspace: adapter_name.to_string(),
            response: crate::tokenizer::decode(&o.tokens),
        })
        .collect();
    ts.sort_by(|a, b| a.prompt.cmp(&b.prompt));
    Ok(ts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_use_disjoint_answer_alphabets() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..50 {
            let a = ForeignEcho.sample(&mut rng);
            let b = NativeReverse.sample(&mut rng);
            let resp_a = crate::tokenizer::decode(&a.completion);
            let resp_b = crate::tokenizer::decode(&b.completion);
            assert!(resp_a.trim_end_matches('.').chars().all(|c| c.is_ascii_uppercase()));
            assert!(resp_b.trim_end_matches('.').chars().all(|c| c.is_ascii_lowercase()));
        }
    }

    #[test]
    fn foreign_echo_is_cipher_of_prompt() {
        let mut rng = Rng::seed_from(2);
        let ex = ForeignEcho.sample(&mut rng);
        let p = crate::tokenizer::decode(&ex.prompt);
        let word = p.trim_start_matches("g:").trim_end_matches('>');
        let want: String = word.chars().map(|c| c.to_ascii_uppercase()).collect();
        assert_eq!(crate::tokenizer::decode(&ex.completion), format!("{want}."));
    }

    #[test]
    fn half_mask_sized_splits_range() {
        let m = half_mask_sized(Half::Upper, 8);
        assert!(m(0) && m(3));
        assert!(!m(4) && !m(7));
        let m = half_mask_sized(Half::Lower, 8);
        assert!(!m(0) && m(4));
    }
}
