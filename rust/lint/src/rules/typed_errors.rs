//! **typed-error-discipline** — errors cross boundaries as types, and
//! the wire taxonomy may not drift from its documentation.
//!
//! Two checks:
//!
//! 1. No `Result<_, String>` in non-test coordinator code.  PR 3 removed
//!    the last stringly-typed channel payloads; this keeps them out.
//!    (Token-level caveat: the scan is per-line, so a signature split
//!    across lines right at the error type could evade it — rustfmt's
//!    layout of this codebase does not do that.)
//!
//! 2. Every `EngineError::kind()` wire string (the stable `"error"`
//!    field clients switch on) must appear verbatim in docs/DESIGN.md.
//!    Adding a variant without documenting its wire name is protocol
//!    drift — exactly the class of decay a reviewer misses and a tool
//!    does not.  The rule also fails loudly if `fn kind(` moves out of
//!    `coordinator/queue.rs`, so the check can never silently go dead.

use super::{Finding, RepoContext};
use crate::scanner::SourceFile;

pub const NAME: &str = "typed-error-discipline";

/// Where the wire taxonomy lives today.
const KIND_FILE: &str = "rust/src/coordinator/queue.rs";

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();

    for file in &ctx.files {
        if !file.rel.starts_with("rust/src/coordinator/") {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            if has_string_error_result(&line.code) {
                out.push(Finding {
                    rule: NAME,
                    path: file.rel.clone(),
                    line: i + 1,
                    message: "Result<_, String> in coordinator code — use the typed \
                              EngineError taxonomy (docs/DESIGN.md §Error taxonomy)"
                        .into(),
                });
            }
        }
    }

    out.extend(check_wire_drift(ctx));
    out
}

/// Does this line's code contain a `Result<…, String>` type?  Walks the
/// angle brackets so `Result<Vec<T>, String>` matches but
/// `Result<String, EngineError>` does not.
fn has_string_error_result(code: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find("Result<") {
        let start = from + pos + "Result<".len();
        let mut depth = 1u32;
        let mut err_start = None;
        for (off, c) in code[start..].char_indices() {
            match c {
                '<' | '(' | '[' => depth += 1,
                '>' | ')' | ']' => {
                    depth -= 1;
                    if depth == 0 {
                        if let Some(e) = err_start {
                            let err_ty = code[start + e..start + off].trim();
                            if err_ty == "String" {
                                return true;
                            }
                        }
                        break;
                    }
                }
                ',' if depth == 1 => err_start = Some(off + 1),
                _ => {}
            }
        }
        from = start;
    }
    false
}

fn check_wire_drift(ctx: &RepoContext) -> Vec<Finding> {
    let Some(file) = ctx.files.iter().find(|f| f.rel == KIND_FILE) else {
        return vec![Finding {
            rule: NAME,
            path: KIND_FILE.into(),
            line: 0,
            message: format!(
                "{KIND_FILE} not found — if EngineError moved, update KIND_FILE in \
                 rust/lint/src/rules/typed_errors.rs so wire-drift checking stays live"
            ),
        }];
    };
    let Some((body_start, body_end)) = kind_fn_span(file) else {
        return vec![Finding {
            rule: NAME,
            path: KIND_FILE.into(),
            line: 0,
            message: "no `fn kind(` found in queue.rs — the wire-drift check lost its \
                      anchor; update rust/lint/src/rules/typed_errors.rs"
                .into(),
        }];
    };
    let mut out = Vec::new();
    for i in body_start..=body_end {
        for s in &file.lines[i].strings {
            if s.is_empty() {
                continue;
            }
            if !ctx.design_md.contains(s.as_str()) {
                out.push(Finding {
                    rule: NAME,
                    path: KIND_FILE.into(),
                    line: i + 1,
                    message: format!(
                        "wire error kind {s:?} is not documented in docs/DESIGN.md — \
                         clients switch on this string; document it where the taxonomy \
                         lives (§Streaming protocol / §Error taxonomy)"
                    ),
                });
            }
        }
    }
    out
}

/// 0-indexed (start, end) line span of the `fn kind(` body, located by
/// brace matching from the signature line.
fn kind_fn_span(file: &SourceFile) -> Option<(usize, usize)> {
    let start = file.lines.iter().position(|l| l.code.contains("fn kind("))?;
    let mut depth = 0i32;
    let mut opened = false;
    for (i, line) in file.lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some((start, i));
                    }
                }
                _ => {}
            }
        }
    }
    None
}
