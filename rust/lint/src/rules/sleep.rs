//! **no-sleep** — benches and tests pace on the clock, not the thread.
//!
//! `Clock::sleep_until` is how arrival processes wait: a real sleep on
//! the wall clock, an instantaneous jump on a manual one.  A raw
//! `thread::sleep` in `rust/src/bench` or `rust/tests` re-introduces
//! real-time coupling (slow suites, flaky timing assertions) and breaks
//! the `--sim-clock` promise that studies run sleep-free.
//!
//! Scope: all code (test modules included — that is the point) under
//! `rust/src/bench` and `rust/tests`.  `util/clock.rs` itself is out of
//! scope: it is where the one real sleep lives.

use super::{code_matches, Finding, RepoContext};

pub const NAME: &str = "no-sleep";

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &ctx.files {
        if !(file.rel.starts_with("rust/src/bench") || file.rel.starts_with("rust/tests/")) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if !code_matches(&line.code, "thread::sleep").is_empty()
                || !code_matches(&line.code, "sleep_ms").is_empty()
            {
                out.push(Finding {
                    rule: NAME,
                    path: file.rel.clone(),
                    line: i + 1,
                    message: "thread::sleep in a bench/test path — pace on \
                              Clock::sleep_until (virtual on --sim-clock) or advance a \
                              manual clock instead"
                        .into(),
                });
            }
        }
    }
    out
}
