//! Evaluation protocols shared by the experiment drivers: classification
//! argmax, multiple-choice NLL scoring, generative exact match through the
//! serving engine, and the LL-judge win rate.

use anyhow::Result;

use super::{lm_batch, Example, Metric, Task};
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Request, SamplingParams};
use crate::trainer::Trainer;
use crate::util::rng::Rng;
use crate::util::stats;

/// Result of a classification evaluation.
#[derive(Clone, Debug)]
pub struct ClassEval {
    pub task: String,
    pub metric: Metric,
    pub score: f64,
    pub n: usize,
}

/// Classification via `last_logits`: argmax restricted to the task's label
/// tokens, scored with the task's metric (Table 2 / Table 6 protocol).
pub fn eval_classification(
    trainer: &Trainer,
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Result<ClassEval> {
    let labels = task.label_tokens();
    assert!(!labels.is_empty(), "{} is not a classification task", task.name());
    let mut rng = Rng::seed_from(seed);
    let examples: Vec<Example> = (0..n).map(|_| task.sample(&mut rng)).collect();
    let (b, l) = (trainer.batch, trainer.seq_len);

    let mut preds = Vec::with_capacity(n);
    let mut golds = Vec::with_capacity(n);
    for chunk in examples.chunks(b) {
        let mut tokens = vec![0i32; b * l];
        let mut lengths = vec![1i32; b];
        for (row, ex) in chunk.iter().enumerate() {
            let p = &ex.prompt[..ex.prompt.len().min(l)];
            tokens[row * l..row * l + p.len()].copy_from_slice(p);
            lengths[row] = p.len() as i32;
        }
        let logits = trainer.last_logits(&tokens, &lengths)?;
        let vocab = trainer.cfg.vocab;
        for (row, ex) in chunk.iter().enumerate() {
            let lrow = logits.read_f32_range(row * vocab, vocab);
            let pred = labels
                .iter()
                .enumerate()
                .max_by(|(_, &a), (_, &b)| {
                    lrow[a as usize].partial_cmp(&lrow[b as usize]).unwrap()
                })
                .map(|(i, _)| i)
                .unwrap();
            preds.push(pred);
            golds.push(ex.answer);
        }
    }

    let score = match task.metric() {
        Metric::Accuracy | Metric::ExactMatch | Metric::WinRate => {
            stats::accuracy(&preds, &golds)
        }
        Metric::Matthews => stats::matthews(&preds, &golds),
        Metric::Pearson => {
            let p: Vec<f64> = preds.iter().map(|&x| x as f64).collect();
            let g: Vec<f64> = golds.iter().map(|&x| x as f64).collect();
            stats::pearson(&p, &g)
        }
    };
    Ok(ClassEval { task: task.name().to_string(), metric: task.metric(), score, n })
}

/// Multiple-choice via per-candidate NLL (Table 3 protocol): each choice
/// becomes one eval_loss row; the argmin-NLL candidate is the prediction.
pub fn eval_choice_accuracy(
    trainer: &Trainer,
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Result<ClassEval> {
    let mut rng = Rng::seed_from(seed);
    let examples: Vec<Example> = (0..n).map(|_| task.sample(&mut rng)).collect();
    let (b, l) = (trainer.batch, trainer.seq_len);

    // Flatten (example, choice) rows, then score in B-sized chunks.
    let mut rows: Vec<Example> = Vec::new();
    let mut row_of: Vec<(usize, usize)> = Vec::new(); // (example, choice)
    for (ei, ex) in examples.iter().enumerate() {
        assert!(!ex.choices.is_empty(), "{} has no choices", task.name());
        for (ci, cand) in ex.choices.iter().enumerate() {
            rows.push(Example {
                prompt: ex.prompt.clone(),
                completion: cand.clone(),
                choices: Vec::new(),
                answer: 0,
            });
            row_of.push((ei, ci));
        }
    }

    let mut nll = vec![vec![f32::INFINITY; 0]; examples.len()];
    for (ei, ex) in examples.iter().enumerate() {
        nll[ei] = vec![f32::INFINITY; ex.choices.len()];
    }
    for (chunk, ids) in rows.chunks(b).zip(row_of.chunks(b)) {
        let batch = lm_batch(chunk, b, l);
        let (per_ex, _) = trainer.eval_loss(&batch)?;
        for (row, &(ei, ci)) in ids.iter().enumerate() {
            nll[ei][ci] = per_ex[row];
        }
    }

    let mut correct = 0usize;
    for (ei, ex) in examples.iter().enumerate() {
        let pred = nll[ei]
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == ex.answer {
            correct += 1;
        }
    }
    Ok(ClassEval {
        task: task.name().to_string(),
        metric: Metric::Accuracy,
        score: correct as f64 / examples.len() as f64,
        n,
    })
}

/// Generative exact match through the serving engine (Table 4 protocol):
/// greedy decoding, '.' as stop token, compare against the gold digits.
pub fn eval_exact_match(
    engine: &mut Engine,
    adapter: Option<&str>,
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Result<ClassEval> {
    let mut rng = Rng::seed_from(seed);
    let examples: Vec<Example> = (0..n).map(|_| task.sample(&mut rng)).collect();
    let stop = b'.' as i32;

    let mut reqs = Vec::with_capacity(n);
    for ex in examples.iter() {
        let max_new = ex.completion.len() + 3;
        let mut r = Request::new(ex.prompt.clone(), max_new).with_sampling(
            SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_token: Some(stop) },
        );
        if let Some(a) = adapter {
            r = r.with_adapter(a);
        }
        reqs.push(r);
    }
    let mut outs = engine.run_all(reqs)?;
    // Ids are engine-issued in submission order, so sorting by id restores
    // the example order regardless of completion interleaving.
    outs.sort_by_key(|o| o.id);

    let mut correct = 0usize;
    for (out, ex) in outs.iter().zip(&examples) {
        // Gold completion without the '.' terminator (stripped by the
        // engine's stop-token handling).
        let gold = &ex.completion[..ex.completion.len() - 1];
        if out.tokens == gold {
            correct += 1;
        }
    }
    Ok(ClassEval {
        task: task.name().to_string(),
        metric: Metric::ExactMatch,
        score: correct as f64 / examples.len() as f64,
        n,
    })
}

/// LL-judge win rate (Table 5 protocol): on shared held-out examples, win
/// = the finetuned trainer assigns strictly lower NLL to the gold response
/// than the reference trainer; ties split.
pub fn eval_win_rate(
    trained: &Trainer,
    reference: &Trainer,
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Result<ClassEval> {
    let mut rng = Rng::seed_from(seed);
    let examples: Vec<Example> = (0..n).map(|_| task.sample(&mut rng)).collect();
    let (b, l) = (trained.batch, trained.seq_len);
    let mut wins = 0f64;
    let mut total = 0usize;
    for chunk in examples.chunks(b) {
        let batch = lm_batch(chunk, b, l);
        let (nll_t, _) = trained.eval_loss(&batch)?;
        let (nll_r, _) = reference.eval_loss(&batch)?;
        for row in 0..chunk.len() {
            if nll_t[row] < nll_r[row] {
                wins += 1.0;
            } else if nll_t[row] == nll_r[row] {
                wins += 0.5;
            }
            total += 1;
        }
    }
    Ok(ClassEval {
        task: task.name().to_string(),
        metric: Metric::WinRate,
        score: wins / total as f64,
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_eval_is_plain_data() {
        let e = ClassEval {
            task: "t".into(),
            metric: Metric::Accuracy,
            score: 0.5,
            n: 10,
        };
        assert_eq!(e.score, 0.5);
    }
}
