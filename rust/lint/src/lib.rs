//! `roadlint` — repo-invariant static analysis for the road serving
//! stack.
//!
//! The serving stack's headline guarantees (deterministic replay on the
//! virtual clock, panic-free peer-facing paths, the artifact-gate budget,
//! the typed wire-error taxonomy) were enforced by convention plus one
//! shell `grep` in CI.  This crate turns each of them into a named,
//! individually testable rule over a token-level scan of `rust/src` and
//! `rust/tests` — see docs/DESIGN.md §Static analysis for the rule table
//! and the escape-hatch policy.
//!
//! Run it as `cargo run -p roadlint -- check [--json] [--root DIR]`.

pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use rules::{Finding, RepoContext};
use scanner::SourceFile;

/// Load and scan every `.rs` file under `<root>/rust/src` and
/// `<root>/rust/tests`, plus the docs the drift rules cross-check.
pub fn load_repo(root: &Path) -> Result<RepoContext, String> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/tests"] {
        let dir = root.join(sub);
        if !dir.is_dir() {
            continue;
        }
        let mut paths = Vec::new();
        collect_rs(&dir, &mut paths)?;
        for p in paths {
            let src = std::fs::read_to_string(&p)
                .map_err(|e| format!("read {}: {e}", p.display()))?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            files.push(SourceFile::scan(&rel, &src));
        }
    }
    if files.is_empty() {
        return Err(format!(
            "no Rust sources under {}/rust/{{src,tests}} — wrong --root?",
            root.display()
        ));
    }
    // Deterministic finding order regardless of directory iteration order.
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    let design_md = std::fs::read_to_string(root.join("docs/DESIGN.md")).unwrap_or_default();
    Ok(RepoContext { files, design_md })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run every registered rule and apply the escape-hatch filter.  The
/// returned findings are what `check` prints and exits nonzero on.
pub fn check(root: &Path) -> Result<Vec<Finding>, String> {
    let ctx = load_repo(root)?;
    Ok(rules::run_all(&ctx))
}

/// Render findings as a stable JSON array (hand-rolled: this crate is
/// dependency-free by design).
pub fn findings_to_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"rule\":{},\"path\":{},\"line\":{},\"message\":{}}}",
            json_str(f.rule),
            json_str(&f.path),
            f.line,
            json_str(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push('\n');
    }
    out.push(']');
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
