//! Threaded front-end for the engine: clients talk to a dedicated engine
//! thread over mpsc channels (the PJRT client is not Send; and the image
//! carries no tokio — std::thread + channels is the documented
//! substitution, docs/DESIGN.md §Substitutions).
//!
//! The client surface is streaming-first: [`EngineClient::submit`] returns
//! a [`Generation`] handle whose channel yields [`StreamEvent`]s as the
//! engine's lanes advance — `Admitted`, per-token `Token`s (so TTFT is a
//! property the caller *observes*, not just a metric the engine records),
//! and a terminal `Finished`/`Error`.  Cancellation is first-class:
//! [`Generation::cancel`] asks the engine to free the request's decode
//! slot and bank pin immediately, and a dropped handle auto-cancels so a
//! hung-up client can never strand a lane or leak a waiter entry.
//!
//! Every channel payload is typed: errors are [`EngineError`] variants
//! (never strings) and stats cross as a [`MetricsSnapshot`] value.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::adapters::Adapter;

use super::engine::{Engine, EngineConfig};
use super::metrics::MetricsSnapshot;
use super::queue::EngineError;
use super::request::{FinishReason, Request, RequestOutput, StreamEvent};

enum Cmd {
    /// Submit a request: the second sender is the rendezvous for the
    /// engine-issued id (or the typed rejection), the first receives the
    /// event stream.  Every rendezvous sender carries exactly one message,
    /// so `sync_channel(1)` bounds it for free.
    Submit(Request, Sender<StreamEvent>, SyncSender<Result<u64, EngineError>>),
    Cancel(u64),
    Register(String, Box<Adapter>, SyncSender<Result<(), EngineError>>),
    Unregister(String, SyncSender<Result<(), EngineError>>),
    Stats(SyncSender<MetricsSnapshot>),
    Shutdown,
}

/// Handle for submitting work to a running engine thread.
#[derive(Clone)]
pub struct EngineClient {
    tx: Sender<Cmd>,
}

/// A live request's event stream.
///
/// Iterate (or call [`Generation::recv`]) to observe `Admitted`, `Token`,
/// and the terminal `Finished`/`Error` event; [`Generation::wait`] drains
/// to the terminal outcome for one-shot callers.  Dropping the handle
/// before the terminal event cancels the request in the engine — the
/// decode slot is freed, the adapter bank pin released, and the output
/// (nobody is listening) discarded.
pub struct Generation {
    id: u64,
    rx: Receiver<StreamEvent>,
    tx: Sender<Cmd>,
    done: bool,
}

impl Generation {
    /// The engine-issued request id (valid immediately — submission is a
    /// rendezvous with the engine thread).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` after the terminal event.  An
    /// engine that dies mid-stream yields a final
    /// [`EngineError::EngineStopped`] event rather than silence.
    pub fn recv(&mut self) -> Option<StreamEvent> {
        if self.done {
            return None;
        }
        let ev = self.rx.recv().unwrap_or(StreamEvent::Error {
            id: self.id,
            error: EngineError::EngineStopped,
        });
        self.done = ev.is_terminal();
        Some(ev)
    }

    /// Ask the engine to cancel this request (idempotent; a race with
    /// completion resolves as a no-op).  The stream still terminates with
    /// `Finished(FinishReason::Cancelled)` carrying the tokens generated
    /// before the cancel landed.
    pub fn cancel(&self) {
        let _ = self.tx.send(Cmd::Cancel(self.id));
    }

    /// Drain to the terminal outcome: the one-shot convenience over the
    /// stream.  A request cancelled out from under a one-shot caller (via
    /// [`EngineClient::cancel`] or the wire `cancel` op) returns
    /// [`EngineError::Cancelled`] — a one-shot caller wants the full
    /// output or a typed error, never a silent truncation.  Streaming
    /// consumers who want the partial tokens use [`Generation::recv`],
    /// where cancellation is a `Finished` output with
    /// `FinishReason::Cancelled`.
    pub fn wait(mut self) -> Result<RequestOutput, EngineError> {
        while let Some(ev) = self.recv() {
            match ev {
                StreamEvent::Finished(out) if out.finish == FinishReason::Cancelled => {
                    return Err(EngineError::Cancelled)
                }
                StreamEvent::Finished(out) => return Ok(out),
                StreamEvent::Error { error, .. } => return Err(error),
                StreamEvent::Admitted { .. } | StreamEvent::Token { .. } => {}
            }
        }
        Err(EngineError::EngineStopped)
    }
}

impl Iterator for Generation {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        self.recv()
    }
}

impl Drop for Generation {
    fn drop(&mut self) {
        // A handle dropped mid-stream is a hung-up client: cancel so the
        // decode slot and bank pin are reclaimed instead of generating to
        // completion for nobody.
        if !self.done {
            let _ = self.tx.send(Cmd::Cancel(self.id));
        }
    }
}

impl EngineClient {
    /// Test-only: a client whose command channel has no engine thread
    /// behind it (every call answers `EngineStopped`) — lets sibling
    /// modules unit-test handle plumbing without building an engine.
    #[cfg(test)]
    pub(crate) fn disconnected() -> EngineClient {
        let (tx, _rx) = channel();
        EngineClient { tx }
    }

    /// Submit a request and stream its events.  Returns as soon as the
    /// engine has issued an id; typed rejections (`QueueFull`,
    /// `AdapterNotFound`, `Invalid`, `EngineStopped`) surface here rather
    /// than on the stream.
    pub fn submit(&self, req: Request) -> Result<Generation, EngineError> {
        // roadlint: allow(bounded-channels) -- the per-request event stream
        // must never block the engine thread on a slow consumer; the buffer
        // is bounded in practice by max_new_tokens events per request, and
        // a hung-up receiver tears it down via the Generation-drop cancel
        // path.  Per-connection write backpressure is ROADMAP item 4.
        let (ev_tx, ev_rx) = channel();
        let (id_tx, id_rx) = sync_channel(1);
        self.tx
            .send(Cmd::Submit(req, ev_tx, id_tx))
            .map_err(|_| EngineError::EngineStopped)?;
        let id = id_rx.recv().map_err(|_| EngineError::EngineStopped)??;
        Ok(Generation { id, rx: ev_rx, tx: self.tx.clone(), done: false })
    }

    /// Submit and wait for the full response (one-shot convenience over
    /// [`EngineClient::submit`]).
    pub fn generate(&self, req: Request) -> Result<RequestOutput, EngineError> {
        self.submit(req)?.wait()
    }

    /// Cancel a request by id without holding its [`Generation`] (e.g. a
    /// wire-protocol cancel op).  Unknown/finished ids are no-ops.
    pub fn cancel(&self, id: u64) -> Result<(), EngineError> {
        self.tx.send(Cmd::Cancel(id)).map_err(|_| EngineError::EngineStopped)
    }

    /// Register a named adapter into the engine's host store (device
    /// residency is paged in on demand at admission).
    pub fn register_adapter(&self, name: &str, adapter: Adapter) -> Result<(), EngineError> {
        let (tx, rx) = sync_channel(1);
        self.tx
            .send(Cmd::Register(name.to_string(), Box::new(adapter), tx))
            .map_err(|_| EngineError::EngineStopped)?;
        rx.recv().map_err(|_| EngineError::EngineStopped)?
    }

    /// Remove a named adapter (rejected while it has queued or in-flight
    /// requests).
    pub fn unregister_adapter(&self, name: &str) -> Result<(), EngineError> {
        let (tx, rx) = sync_channel(1);
        self.tx
            .send(Cmd::Unregister(name.to_string(), tx))
            .map_err(|_| EngineError::EngineStopped)?;
        rx.recv().map_err(|_| EngineError::EngineStopped)?
    }

    /// Serializable metrics snapshot (render with
    /// [`MetricsSnapshot::report`]/[`MetricsSnapshot::report_table`], or
    /// ship as JSON via [`MetricsSnapshot::to_json`]).
    pub fn stats(&self) -> Result<MetricsSnapshot, EngineError> {
        let (tx, rx) = sync_channel(1);
        self.tx.send(Cmd::Stats(tx)).map_err(|_| EngineError::EngineStopped)?;
        rx.recv().map_err(|_| EngineError::EngineStopped)
    }
}

pub struct EngineServer {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl EngineServer {
    /// Start an engine on its own thread.  `setup` runs on the engine
    /// thread after construction (e.g. to register adapters that are not
    /// Send-friendly to build elsewhere).
    pub fn start(
        econf: EngineConfig,
        artifacts_dir: std::path::PathBuf,
        setup: impl FnOnce(&mut Engine) -> Result<()> + Send + 'static,
    ) -> Result<(EngineServer, EngineClient)> {
        EngineServer::start_named(econf, artifacts_dir, "road-engine".into(), setup)
    }

    /// [`EngineServer::start`] with an explicit engine-thread name — the
    /// multi-replica [`super::router::Fleet`] labels each replica's thread
    /// (`road-engine-0`, `road-engine-1`, ...) so stack dumps attribute
    /// work to a replica.
    pub fn start_named(
        econf: EngineConfig,
        artifacts_dir: std::path::PathBuf,
        thread_name: String,
        setup: impl FnOnce(&mut Engine) -> Result<()> + Send + 'static,
    ) -> Result<(EngineServer, EngineClient)> {
        // roadlint: allow(bounded-channels) -- the command plane: senders
        // are rendezvous-style clients whose payloads are already bounded
        // by queue-capacity backpressure inside the engine; blocking a
        // client on a full command channel would deadlock the cancel path
        // that unblocks it.
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = sync_channel::<Result<(), EngineError>>(1);
        let handle = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || engine_thread(econf, artifacts_dir, rx, ready_tx, setup))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow::anyhow!("engine init failed: {e}")),
            Err(_) => return Err(anyhow::anyhow!("engine thread died during init")),
        }
        Ok((EngineServer { tx: tx.clone(), handle: Some(handle) }, EngineClient { tx }))
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_thread(
    econf: EngineConfig,
    artifacts_dir: std::path::PathBuf,
    rx: Receiver<Cmd>,
    ready: SyncSender<Result<(), EngineError>>,
    setup: impl FnOnce(&mut Engine) -> Result<()>,
) -> Result<()> {
    let init = (|| -> Result<Engine> {
        // Backend selection (EngineConfig::backend): the reference backend
        // is artifact-free and ignores `artifacts_dir`; PJRT loads the
        // manifest from it.
        let rt = std::rc::Rc::new(crate::runtime::Runtime::for_backend(
            econf.backend,
            &artifacts_dir,
        )?);
        let mut engine = Engine::new(rt, econf)?;
        setup(&mut engine)?;
        Ok(engine)
    })();
    let mut engine = match init {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(EngineError::Invalid { reason: format!("{e:#}") }));
            return Err(e);
        }
    };

    // id -> live event stream.  Entries leave on the terminal event, on
    // cancel, or when a send fails (receiver dropped → auto-cancel); no
    // path leaks a waiter.
    let mut waiters: std::collections::HashMap<u64, Sender<StreamEvent>> = Default::default();
    let mut shutting_down = false;

    loop {
        // Drain commands: block when idle, poll when there is work.
        loop {
            let cmd = if engine.has_work() || shutting_down {
                match rx.try_recv() {
                    Ok(c) => Some(c),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        None
                    }
                }
            } else {
                match rx.recv() {
                    Ok(c) => Some(c),
                    Err(_) => return Ok(()), // all clients gone, idle
                }
            };
            let Some(cmd) = cmd else { break };
            match cmd {
                Cmd::Submit(req, events, id_resp) => {
                    let result = if shutting_down {
                        Err(EngineError::EngineStopped)
                    } else {
                        engine.submit(req)
                    };
                    if let Ok(id) = &result {
                        waiters.insert(*id, events);
                    }
                    let _ = id_resp.send(result);
                }
                Cmd::Cancel(id) => {
                    // The reclaim happens in the engine regardless of
                    // whether anyone still listens for the terminal event.
                    if let Some(out) = engine.cancel(id) {
                        if let Some(w) = waiters.remove(&id) {
                            let _ = w.send(StreamEvent::Finished(out));
                        }
                    }
                }
                Cmd::Register(name, adapter, resp) => {
                    let _ = resp.send(
                        engine
                            .register_adapter(&name, &adapter)
                            .map_err(|e| EngineError::Invalid { reason: format!("{e:#}") }),
                    );
                }
                Cmd::Unregister(name, resp) => {
                    let _ = resp.send(
                        engine
                            .unregister_adapter(&name)
                            .map_err(|e| EngineError::Invalid { reason: format!("{e:#}") }),
                    );
                }
                Cmd::Stats(resp) => {
                    let _ = resp.send(engine.metrics.snapshot());
                }
                Cmd::Shutdown => shutting_down = true,
            }
        }

        if engine.has_work() {
            for ev in engine.step()? {
                let id = ev.id();
                let terminal = ev.is_terminal();
                let hung_up = match waiters.get(&id) {
                    Some(w) => w.send(ev).is_err(),
                    // Already cancelled/terminated; drop stragglers.
                    None => false,
                };
                if hung_up {
                    // Receiver dropped without the Cancel command having
                    // landed yet: reclaim the lane now and forget the
                    // waiter so nothing leaks.
                    waiters.remove(&id);
                    let _ = engine.cancel(id);
                } else if terminal {
                    waiters.remove(&id);
                }
            }
        } else if shutting_down {
            // No work left; any stragglers (nothing should remain — work
            // implies waiters) get a typed goodbye rather than a hangup.
            for (id, w) in waiters.drain() {
                let _ = w.send(StreamEvent::Error { id, error: EngineError::EngineStopped });
            }
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::util::clock::Clock;
    use std::sync::mpsc::Receiver;
    use std::time::Duration;

    /// A `Generation` wired to bare channels — the client half of the
    /// protocol without an engine thread behind it, so the handle's
    /// lifecycle (drop-cancel, terminal latching, hangup behavior) is
    /// testable in isolation.
    fn bare_generation(id: u64) -> (Generation, Receiver<Cmd>, Sender<StreamEvent>) {
        let (cmd_tx, cmd_rx) = channel();
        let (ev_tx, ev_rx) = channel();
        (Generation { id, rx: ev_rx, tx: cmd_tx, done: false }, cmd_rx, ev_tx)
    }

    fn finished(id: u64) -> StreamEvent {
        StreamEvent::Finished(RequestOutput {
            id,
            adapter: None,
            tokens: vec![1, 2],
            finish: FinishReason::MaxTokens,
            ttft: 0.0,
            e2e: 0.0,
        })
    }

    #[test]
    fn dropped_generation_sends_cancel_for_its_id() {
        let (generation, cmd_rx, _ev_tx) = bare_generation(9);
        assert_eq!(generation.id(), 9);
        drop(generation);
        match cmd_rx.try_recv() {
            Ok(Cmd::Cancel(id)) => assert_eq!(id, 9),
            _ => panic!("dropping a live Generation must send Cancel(id)"),
        }
        assert!(cmd_rx.try_recv().is_err(), "exactly one cancel");
    }

    #[test]
    fn terminated_generation_does_not_cancel_on_drop() {
        let (mut generation, cmd_rx, ev_tx) = bare_generation(3);
        ev_tx.send(StreamEvent::Token { id: 3, token: 7, pos: 0, ttft_hint: Some(0.01) }).unwrap();
        ev_tx.send(finished(3)).unwrap();
        assert!(matches!(generation.recv(), Some(StreamEvent::Token { .. })));
        assert!(generation.recv().is_some_and(|ev| ev.is_terminal()));
        assert!(generation.recv().is_none(), "stream is closed after the terminal event");
        drop(generation);
        assert!(
            cmd_rx.try_recv().is_err(),
            "a finished stream must not cancel on drop (the id may be reused)"
        );
    }

    #[test]
    fn engine_hangup_mid_stream_yields_typed_engine_stopped() {
        let (mut generation, _cmd_rx, ev_tx) = bare_generation(5);
        drop(ev_tx); // engine thread died before the terminal event
        match generation.recv() {
            Some(StreamEvent::Error { id: 5, error: EngineError::EngineStopped }) => {}
            other => panic!("expected EngineStopped, got {other:?}"),
        }
        assert!(generation.recv().is_none(), "the synthesized error is terminal");
    }

    #[test]
    fn wait_maps_cancelled_finish_to_typed_error() {
        let (generation, _cmd_rx, ev_tx) = bare_generation(4);
        ev_tx
            .send(StreamEvent::Finished(RequestOutput {
                id: 4,
                adapter: None,
                tokens: vec![1],
                finish: FinishReason::Cancelled,
                ttft: 0.0,
                e2e: 0.0,
            }))
            .unwrap();
        assert!(matches!(generation.wait(), Err(EngineError::Cancelled)));
        let (generation, _cmd_rx, ev_tx) = bare_generation(6);
        ev_tx.send(finished(6)).unwrap();
        let out = generation.wait().expect("normal finish passes through wait");
        assert_eq!(out.tokens, vec![1, 2]);
    }

    #[test]
    fn engine_error_round_trips_the_channel_with_stable_kind() {
        // The exact payload shape the engine thread sends for a rejected
        // submit: Result<u64, EngineError> through an mpsc channel.  Each
        // variant must come back equal, with its wire name intact.
        let variants = [
            EngineError::QueueFull { waiting: 3 },
            EngineError::AdapterNotFound { name: "alice".into() },
            EngineError::DeadlineExceeded,
            EngineError::Cancelled,
            EngineError::EngineStopped,
            EngineError::Invalid { reason: "bad prompt".into() },
        ];
        let (tx, rx) = channel::<Result<u64, EngineError>>();
        for e in variants {
            let kind = e.kind();
            tx.send(Err(e.clone())).unwrap();
            let back = rx.recv().unwrap().unwrap_err();
            assert_eq!(back, e, "variant must survive the channel unchanged");
            assert_eq!(back.kind(), kind, "wire name stable across the boundary");
        }
    }

    #[test]
    fn stats_snapshot_under_manual_clock_is_exact_and_reproducible() {
        let run = || {
            let clock = Clock::manual();
            let mut m = Metrics::with_clock(clock.clone());
            m.start();
            clock.advance(Duration::from_millis(250));
            m.requests_completed = 2;
            m.tokens_generated = 16;
            m.ttft.record(Duration::from_millis(3));
            m.stop();
            m.snapshot()
        };
        let (a, b) = (run(), run());
        assert!((a.wall_secs - 0.25).abs() < 1e-12, "virtual wall is exact: {}", a.wall_secs);
        assert!((a.throughput - 64.0).abs() < 1e-9, "throughput from virtual wall");
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact(),
            "identical virtual runs serialize byte-identically"
        );
    }
}
