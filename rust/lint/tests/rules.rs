//! Fixture-based positive/negative coverage for every rule, plus the
//! self-check that the repo itself is lint-clean.
//!
//! Each seeded-violation fixture under `tests/fixtures/` must fail with
//! the seeded rule (and only at the seeded sites); the `clean` fixture
//! must pass every rule.  The fixtures are data, not compiled code.

use std::path::PathBuf;

use roadlint::rules::Finding;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..").canonicalize().unwrap()
}

fn check(name: &str) -> Vec<Finding> {
    roadlint::check(&fixture(name)).unwrap()
}

fn of_rule<'a>(findings: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.rule == rule).collect()
}

#[test]
fn clean_fixture_passes_every_rule() {
    let findings = check("clean");
    assert!(findings.is_empty(), "clean fixture must be clean, got: {findings:?}");
}

#[test]
fn clock_violation_fixture_fails() {
    let findings = check("clock_violation");
    let hits = of_rule(&findings, "clock-discipline");
    assert_eq!(hits.len(), 2, "Instant::now + SystemTime::now, not the test module: {hits:?}");
    assert_eq!((hits[0].path.as_str(), hits[0].line), ("rust/src/foo.rs", 2));
    assert_eq!(hits[1].line, 6);
}

#[test]
fn sleep_violation_fixture_fails_in_bench_and_tests() {
    let findings = check("sleep_violation");
    let hits = of_rule(&findings, "no-sleep");
    assert_eq!(hits.len(), 2, "{hits:?}");
    assert!(hits.iter().any(|f| f.path == "rust/src/bench/mod.rs"));
    assert!(hits.iter().any(|f| f.path == "rust/tests/slow.rs"));
}

#[test]
fn budget_violation_fixture_fails_only_past_the_budget() {
    let findings = check("budget_violation");
    let hits = of_rule(&findings, "artifact-gate-budget");
    assert_eq!(hits.len(), 1, "18 sites, budget 17 -> exactly one over: {hits:?}");
    assert!(hits[0].message.contains("18"));
    assert!(hits[0].message.contains("budget of 17"));
}

#[test]
fn panic_violation_fixture_fails_but_lock_poisoning_is_allowed() {
    let findings = check("panic_violation");
    let hits = of_rule(&findings, "no-panic-hot-path");
    assert_eq!(hits.len(), 3, "unwrap + expect + panic!, not .lock().unwrap(): {hits:?}");
    let lines: Vec<usize> = hits.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![2, 6, 10]);
}

/// The block-pool allocator is coordinator hot-path code: its alloc /
/// ref / release / invariant paths must stay panic-free (a panic there
/// strands every lane's KV blocks).  Seeded violations in a pool-shaped
/// fixture pin the rule to that module; the lock idiom and test code
/// stay allowed.
#[test]
fn pool_panic_violation_fixture_fails_on_hot_paths() {
    let findings = check("pool_panic_violation");
    let hits = of_rule(&findings, "no-panic-hot-path");
    assert_eq!(hits.len(), 4, "unwrap + expect + panic! + unreachable!: {hits:?}");
    assert!(hits.iter().all(|f| f.path == "rust/src/coordinator/pool.rs"), "{hits:?}");
    let lines: Vec<usize> = hits.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![2, 6, 11, 17]);
    // Neither the poisoning-propagation idiom nor the test module fires.
    assert!(findings.iter().all(|f| f.line < 21), "{findings:?}");
}

/// The mixed-step planner (`step.rs`: decode assembly + chunk-prefill
/// budgeting) runs inside every scheduler iteration — a panic there
/// freezes all decode lanes mid-step.  Seeded violations in a
/// step-planner-shaped fixture pin the no-panic rule to the module; the
/// lock idiom and test code stay allowed.
#[test]
fn step_panic_violation_fixture_fails_on_planner_paths() {
    let findings = check("step_panic_violation");
    let hits = of_rule(&findings, "no-panic-hot-path");
    assert_eq!(hits.len(), 4, "unwrap + expect + panic! + unreachable!: {hits:?}");
    assert!(hits.iter().all(|f| f.path == "rust/src/coordinator/step.rs"), "{hits:?}");
    let lines: Vec<usize> = hits.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![2, 6, 11, 17]);
    // Neither the poisoning-propagation idiom nor the test module fires.
    assert!(findings.iter().all(|f| f.line < 21), "{findings:?}");
}

/// The fleet data plane (router placement + replica lifecycle) is
/// coordinator hot-path code like the pool: a panic in `place` or a
/// lifecycle transition takes down the front door for every replica.
/// Seeded violations in both modules pin the rule to the new files; the
/// same-line lock idiom and test code stay allowed.
#[test]
fn router_panic_violation_fixture_fails_on_both_fleet_modules() {
    let findings = check("router_panic_violation");
    let hits = of_rule(&findings, "no-panic-hot-path");
    assert_eq!(hits.len(), 3, "unwrap + expect in router, panic! in replica: {hits:?}");
    let lines = |file: &str| -> Vec<usize> {
        hits.iter().filter(|f| f.path.ends_with(file)).map(|f| f.line).collect()
    };
    // Exactly the seeded sites: the `.lock().unwrap()` poisoning idiom
    // (router.rs:10) and the `#[cfg(test)]` module (replica.rs) stay
    // allowed, so no further lines fire.
    assert_eq!(lines("coordinator/router.rs"), vec![2, 6], "{hits:?}");
    assert_eq!(lines("coordinator/replica.rs"), vec![3], "{hits:?}");
}

/// The adapter-epilogue kernels (`runtime/epilogue.rs`) run inside every
/// decode step of the engine thread, so the no-panic rule extends beyond
/// `coordinator/` to that one runtime file.  Seeded violations in an
/// epilogue-shaped fixture pin the rule there; the lock idiom and test
/// code stay allowed.
#[test]
fn epilogue_panic_violation_fixture_fails_on_kernel_paths() {
    let findings = check("epilogue_panic_violation");
    let hits = of_rule(&findings, "no-panic-hot-path");
    assert_eq!(hits.len(), 4, "unwrap + expect + panic! + unreachable!: {hits:?}");
    assert!(hits.iter().all(|f| f.path == "rust/src/runtime/epilogue.rs"), "{hits:?}");
    let lines: Vec<usize> = hits.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![2, 6, 11, 18]);
    // Neither the poisoning-propagation idiom nor the test module fires.
    assert!(findings.iter().all(|f| f.line < 22), "{findings:?}");
}

#[test]
fn typed_error_fixture_fails_on_string_results_and_wire_drift() {
    let findings = check("typed_error_violation");
    let hits = of_rule(&findings, "typed-error-discipline");
    assert_eq!(hits.len(), 2, "{hits:?}");
    assert!(hits.iter().any(|f| f.path.ends_with("server.rs") && f.message.contains("Result")));
    assert!(hits.iter().any(|f| f.path.ends_with("queue.rs")
        && f.message.contains("mystery_kind")));
    assert!(
        !hits.iter().any(|f| f.message.contains("queue_full")),
        "documented kinds must not be flagged: {hits:?}"
    );
}

#[test]
fn channel_violation_fixture_fails_for_both_construction_forms() {
    let findings = check("channel_violation");
    let hits = of_rule(&findings, "bounded-channels");
    assert_eq!(hits.len(), 2, "channel() and channel::<T>(): {hits:?}");
}

#[test]
fn bare_allow_directive_is_itself_a_finding() {
    let findings = check("allow_missing_justification");
    let hits = of_rule(&findings, "clock-discipline");
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert!(hits[0].message.contains("justification"), "{}", hits[0].message);
}

/// The backstop the whole crate exists for: the repo itself is clean.
/// Any new violation anywhere in rust/src or rust/tests fails this test
/// (and the CI roadlint job) with the exact site.
#[test]
fn repo_self_check_is_clean() {
    let findings = roadlint::check(&repo_root()).unwrap();
    assert!(
        findings.is_empty(),
        "the repo must be roadlint-clean; findings:\n{}",
        findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.path, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The CLI contract CI relies on: nonzero + findings on a seeded
/// violation, zero + clean report on the repo, and `--json` output that
/// round-trips through a parser.
#[test]
fn cli_exit_codes_and_json_shape() {
    let bin = env!("CARGO_BIN_EXE_roadlint");

    let bad = std::process::Command::new(bin)
        .args(["check", "--json", "--root"])
        .arg(fixture("clock_violation"))
        .output()
        .unwrap();
    assert_eq!(bad.status.code(), Some(1), "violation fixture must exit 1");
    let json = String::from_utf8(bad.stdout).unwrap();
    assert!(json.trim_start().starts_with('['), "{json}");
    assert!(json.contains("\"rule\":\"clock-discipline\""), "{json}");
    assert!(json.contains("\"line\":2"), "{json}");

    let good = std::process::Command::new(bin)
        .args(["check", "--root"])
        .arg(repo_root())
        .output()
        .unwrap();
    assert_eq!(
        good.status.code(),
        Some(0),
        "repo must be clean; output:\n{}",
        String::from_utf8_lossy(&good.stdout)
    );

    let usage = std::process::Command::new(bin).arg("--frobnicate").output().unwrap();
    assert_eq!(usage.status.code(), Some(2), "usage errors exit 2");
}
