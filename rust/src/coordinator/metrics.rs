//! Serving metrics: throughput, TTFT, per-token and end-to-end latency,
//! queueing delay/depth, step-time accounting split by phase, and KV-cache
//! transfer counters.
//!
//! Latency clocks start at `Engine::submit` (the request's
//! `submitted_at` stamp), so TTFT and e2e include time spent waiting in
//! the admission queue — what a client actually observes — not just
//! compute after admission.

use std::time::{Duration, Instant};

use crate::util::stats::{LatencyRecorder, Summary};

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    pub prefill_batches: usize,
    pub decode_steps: usize,
    /// Submit → first generated token (queue wait included).
    pub ttft: LatencyRecorder,
    /// Submit → request finished (queue wait included).
    pub e2e: LatencyRecorder,
    /// Submit → admission into a prefill batch (the queueing component of
    /// ttft/e2e, recorded separately so saturation is visible).
    pub queue_wait: LatencyRecorder,
    /// Admission-queue depth sampled at each scheduler step (a depth
    /// histogram, not a latency — samples are request counts).
    pub queue_depth: LatencyRecorder,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// Full K/V cache device→host transfers.  Device-resident decode:
    /// admission-time materializations only (tracks prefill batches, not
    /// decode steps).  `kv_host_roundtrip` baseline: one per decode step.
    pub kv_host_syncs: usize,
    /// Full K/V cache host→device transfers (mirror of `kv_host_syncs`:
    /// re-uploads after materialization, or per-step in baseline mode).
    pub kv_uploads: usize,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => (f - s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per second of wall time — Figure 4's y-axis.
    pub fn throughput(&self) -> f64 {
        let w = self.wall();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttft.summary()
    }

    pub fn e2e_summary(&self) -> Summary {
        self.e2e.summary()
    }

    pub fn queue_wait_summary(&self) -> Summary {
        self.queue_wait.summary()
    }

    pub fn queue_depth_summary(&self) -> Summary {
        self.queue_depth.summary()
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        let qw = self.queue_wait_summary();
        let qd = self.queue_depth_summary();
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s \
             prefill_batches={} decode_steps={} \
             ttft(p50/p90)={:.1}/{:.1}ms e2e(p50/p90)={:.1}/{:.1}ms \
             queue_wait(p50/p90)={:.1}/{:.1}ms queue_depth(p50/max)={:.0}/{:.0} \
             prefill={:.2}s decode={:.2}s kv_dl/ul={}/{}",
            self.requests_completed,
            self.tokens_generated,
            self.wall(),
            self.throughput(),
            self.prefill_batches,
            self.decode_steps,
            t.p50 / 1e3,
            t.p90 / 1e3,
            e.p50 / 1e3,
            e.p90 / 1e3,
            qw.p50 / 1e3,
            qw.p90 / 1e3,
            qd.p50,
            qd.max,
            self.prefill_time.as_secs_f64(),
            self.decode_time.as_secs_f64(),
            self.kv_host_syncs,
            self.kv_uploads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_includes_queue_and_kv_fields() {
        let mut m = Metrics::default();
        m.queue_wait.record(Duration::from_millis(4));
        m.queue_depth.record_value(3.0);
        m.queue_depth.record_value(7.0);
        m.kv_host_syncs = 2;
        m.kv_uploads = 2;
        let r = m.report();
        assert!(r.contains("queue_wait"), "{r}");
        assert!(r.contains("queue_depth(p50/max)"), "{r}");
        assert!(r.contains("kv_dl/ul=2/2"), "{r}");
        assert!((m.queue_wait_summary().p50 - 4000.0).abs() < 1e-6);
        assert_eq!(m.queue_depth_summary().max, 7.0);
    }
}
