#[test]
fn waits_in_real_time() {
    std::thread::sleep(std::time::Duration::from_millis(5));
}
