//! The fleet front door: N engine replicas behind one [`Router`] doing
//! adapter-affinity placement (docs/DESIGN.md §Data plane).
//!
//! One engine, one device bank, and one listener cannot carry the paper's
//! hetero-adapter serving claim to heavy traffic: a popular adapter on a
//! single bank thrashes every other adapter's pages (the Zipf churn
//! `--study bank` measures).  The scaling move is *placement* — keep a hot
//! adapter's bank pages and KV prefix blocks resident on a home replica
//! and route its requests there, spilling over only on load or health.
//!
//! Three layers:
//!
//! * [`Placer`] — the pure placement registry: `BTreeMap` of adapter →
//!   [`Placement`] (home replica + spillover candidates) plus a pluggable
//!   [`PlaceKind`] policy (`affinity` / `least-loaded` / `round-robin`),
//!   re-homing on sustained imbalance.  No I/O, no clocks, no locks — the
//!   same struct drives the live router and the deterministic [`FleetSim`],
//!   and is what the placement proptests pin down.
//! * [`Router`] / [`Fleet`] — the live data plane: [`Fleet::start`] brings
//!   up N [`super::server::EngineServer`] replicas (each with its own
//!   `Runtime`, `AdapterBank`, and `BlockPool`, on its own named thread),
//!   and the cloneable [`Router`] places submissions, fans out
//!   `register`/`unregister`/`stats`, and routes cancels by id arithmetic
//!   (replica `r` issues ids `r+1, r+1+n, …` via
//!   `EngineConfig::request_id_base/stride`, so `(id-1) % n` recovers the
//!   home replica with no shared id state).
//! * [`FleetSim`] — `SchedSim`'s multi-replica mode: N per-replica sims
//!   stepped in lockstep on manual clocks behind one `Placer`, with the
//!   bank/prefix cache models ([`SchedSim::with_bank`],
//!   [`SchedSim::with_prefix_cache`]) standing in for device state — so
//!   `road bench-serving --study router --sim-clock` compares placement
//!   policies byte-identically before any real traffic.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::adapters::Adapter;
use crate::util::json::{self, Json};
use crate::util::table::Table;

use super::engine::{Engine, EngineConfig};
use super::metrics::MetricsSnapshot;
use super::queue::EngineError;
use super::replica::{LoadGuard, Replica, ReplicaHealth, ReplicaState};
use super::request::{Request, RequestOutput, StreamEvent};
use super::sched::{PolicyKind, SchedSim};
use super::server::{EngineServer, Generation};

// ---------------------------------------------------------------------------
// Placement policy + registry (pure; shared by the live router and the sim)
// ---------------------------------------------------------------------------

/// Which placement policy the router runs; `road serve --place <name>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaceKind {
    /// Adapter-affinity: route to the adapter's home replica while it is
    /// ready and under the overload threshold; spill to the least-loaded
    /// candidate otherwise, re-homing after a sustained spill streak.
    /// Unregistered adapters and base-model requests take the default
    /// round-robin route.
    Affinity,
    /// Ignore homes: always the least-loaded ready replica (ties break to
    /// the lowest id).
    LeastLoaded,
    /// Ignore homes and load: ready replicas in rotation.
    RoundRobin,
}

impl PlaceKind {
    /// Every shipped policy, in the order the router study sweeps them.
    pub const ALL: [PlaceKind; 3] =
        [PlaceKind::Affinity, PlaceKind::LeastLoaded, PlaceKind::RoundRobin];

    /// Stable CLI / report name.
    pub fn name(self) -> &'static str {
        match self {
            PlaceKind::Affinity => "affinity",
            PlaceKind::LeastLoaded => "least-loaded",
            PlaceKind::RoundRobin => "round-robin",
        }
    }

    /// Parse a `--place` flag value.
    pub fn from_name(name: &str) -> Result<PlaceKind> {
        Ok(match name {
            "affinity" => PlaceKind::Affinity,
            "least-loaded" | "least_loaded" => PlaceKind::LeastLoaded,
            "round-robin" | "round_robin" | "rr" => PlaceKind::RoundRobin,
            other => {
                bail!("unknown placement policy {other:?} (affinity|least-loaded|round-robin)")
            }
        })
    }
}

/// What the placer knows about one replica at decision time.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaView {
    pub id: usize,
    /// Routable: lifecycle state is exactly `Ready`.
    pub ready: bool,
    /// Outstanding routed requests.
    pub load: usize,
}

/// One adapter's placement: its home replica plus the spillover
/// candidates (every other replica that was ready when the placement was
/// made or re-homed; liveness is re-checked at routing time).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    pub home: usize,
    pub spill: Vec<usize>,
}

/// The placement registry + policy.  Pure and deterministic: decisions
/// are functions of the registry, the policy's cursor/streak state, and
/// the `ReplicaView`s passed in — no clocks, no locks, no I/O — so the
/// live [`Router`] and the [`FleetSim`] share it and the proptests can
/// drive it with arbitrary op sequences.
#[derive(Debug)]
pub struct Placer {
    policy: PlaceKind,
    registry: BTreeMap<String, Placement>,
    /// Registered homes per replica — `register` balances new homes.
    homes: BTreeMap<usize, usize>,
    /// Default-route rotation cursor (round-robin policy and affinity's
    /// unregistered/base-model route).
    rr: usize,
    /// Per-adapter (last spill target, consecutive spills) — the
    /// sustained-imbalance detector behind re-homing.
    streaks: BTreeMap<String, (usize, usize)>,
    /// Outstanding-load bound above which an affinity home spills over.
    overload: usize,
    /// Consecutive spills to one target that trigger a re-home.
    rehome_after: usize,
    /// Lifetime placements that left the home replica (affinity only).
    pub spills: usize,
    /// Lifetime re-homes on sustained imbalance.
    pub rehomes: usize,
}

impl Placer {
    /// `overload`: outstanding requests a home replica may hold before
    /// affinity spills over (the live fleet uses `2 * decode_slots`).
    pub fn new(policy: PlaceKind, overload: usize) -> Placer {
        Placer {
            policy,
            registry: BTreeMap::new(),
            homes: BTreeMap::new(),
            rr: 0,
            streaks: BTreeMap::new(),
            overload: overload.max(1),
            rehome_after: 8,
            spills: 0,
            rehomes: 0,
        }
    }

    pub fn policy(&self) -> PlaceKind {
        self.policy
    }

    /// The adapter → placement registry (read-only; the invariant the
    /// placement proptests check).
    pub fn registry(&self) -> &BTreeMap<String, Placement> {
        &self.registry
    }

    /// Record a placement for a newly registered adapter: home = the ready
    /// replica with the fewest registered homes (ties to the lowest id),
    /// spill = every other ready replica.  Idempotent for known adapters.
    /// Returns the home, or `None` when no replica is ready (the adapter
    /// stays unplaced and routes through the default route until a later
    /// `register`).
    pub fn register(&mut self, name: &str, views: &[ReplicaView]) -> Option<usize> {
        if let Some(p) = self.registry.get(name) {
            return Some(p.home);
        }
        let home = views
            .iter()
            .filter(|v| v.ready)
            .min_by_key(|v| (self.homes.get(&v.id).copied().unwrap_or(0), v.id))?
            .id;
        let spill: Vec<usize> =
            views.iter().filter(|v| v.ready && v.id != home).map(|v| v.id).collect();
        self.registry.insert(name.to_string(), Placement { home, spill });
        *self.homes.entry(home).or_insert(0) += 1;
        Some(home)
    }

    /// Drop an adapter's placement (no-op for unknown names).
    pub fn unregister(&mut self, name: &str) {
        if let Some(p) = self.registry.remove(name) {
            if let Some(n) = self.homes.get_mut(&p.home) {
                *n = n.saturating_sub(1);
            }
        }
        self.streaks.remove(name);
    }

    /// Choose a replica for one request.  Returns `None` only when no
    /// replica is ready (the fleet is draining/stopped); never returns a
    /// non-ready replica — draining replicas receive no new admissions.
    pub fn place(&mut self, adapter: Option<&str>, views: &[ReplicaView]) -> Option<usize> {
        let ready: Vec<ReplicaView> = views.iter().filter(|v| v.ready).copied().collect();
        if ready.is_empty() {
            return None;
        }
        match self.policy {
            PlaceKind::RoundRobin => self.default_route(&ready),
            PlaceKind::LeastLoaded => least_loaded(&ready),
            PlaceKind::Affinity => {
                let Some(name) = adapter else { return self.default_route(&ready) };
                let Some(p) = self.registry.get(name).cloned() else {
                    return self.default_route(&ready);
                };
                if let Some(home) = ready.iter().find(|v| v.id == p.home) {
                    if home.load < self.overload {
                        self.streaks.remove(name);
                        return Some(home.id);
                    }
                }
                // Home is overloaded or not ready: spill to the
                // least-loaded live candidate (fall back to any ready
                // replica when every recorded candidate is gone).
                let candidates: Vec<ReplicaView> =
                    ready.iter().filter(|v| p.spill.contains(&v.id)).copied().collect();
                let target = least_loaded(if candidates.is_empty() { &ready } else { &candidates })?;
                self.spills += 1;
                let streak = match self.streaks.get(name) {
                    Some(&(t, n)) if t == target => (target, n + 1),
                    _ => (target, 1),
                };
                if streak.1 >= self.rehome_after {
                    self.rehome(name, target, &ready);
                } else {
                    self.streaks.insert(name.to_string(), streak);
                }
                Some(target)
            }
        }
    }

    /// Sustained imbalance: make the spill target the new home and
    /// recompute the spill set from the currently ready replicas.
    fn rehome(&mut self, name: &str, new_home: usize, ready: &[ReplicaView]) {
        let Some(p) = self.registry.get_mut(name) else { return };
        if let Some(n) = self.homes.get_mut(&p.home) {
            *n = n.saturating_sub(1);
        }
        p.home = new_home;
        p.spill = ready.iter().filter(|v| v.id != new_home).map(|v| v.id).collect();
        *self.homes.entry(new_home).or_insert(0) += 1;
        self.streaks.remove(name);
        self.rehomes += 1;
    }

    /// Rotation over the ready replicas (ascending id order, stable
    /// cursor) — round-robin's route and affinity's default route.
    fn default_route(&mut self, ready: &[ReplicaView]) -> Option<usize> {
        let mut ids: Vec<usize> = ready.iter().map(|v| v.id).collect();
        ids.sort_unstable();
        let pick = ids.get(self.rr % ids.len()).copied();
        self.rr = self.rr.wrapping_add(1);
        pick
    }
}

/// Least outstanding load, ties to the lowest id.
fn least_loaded(views: &[ReplicaView]) -> Option<usize> {
    views.iter().min_by_key(|v| (v.load, v.id)).map(|v| v.id)
}

// ---------------------------------------------------------------------------
// The live fleet: Router + Fleet
// ---------------------------------------------------------------------------

struct RouterInner {
    replicas: Vec<Replica>,
    placer: Mutex<Placer>,
}

/// Cloneable front door over the fleet's replicas: places submissions,
/// fans out adapter registration and stats, routes cancels by id.
/// Clones share the placement registry and the replicas' lifecycle/load
/// cells — the NDJSON listener hands one clone to every connection.
#[derive(Clone)]
pub struct Router {
    inner: Arc<RouterInner>,
}

impl Router {
    pub fn n_replicas(&self) -> usize {
        self.inner.replicas.len()
    }

    /// Current placement views (lifecycle + load) — what the placer sees.
    fn views(&self) -> Vec<ReplicaView> {
        self.inner
            .replicas
            .iter()
            .map(|r| ReplicaView { id: r.id(), ready: r.is_ready(), load: r.load() })
            .collect()
    }

    /// Which replica issued a wire id (`(id-1) % n`, the id-stride
    /// arithmetic from [`EngineConfig::request_id_stride`]).
    fn replica_of(&self, id: u64) -> usize {
        let n = self.inner.replicas.len().max(1) as u64;
        (id.wrapping_sub(1) % n) as usize
    }

    /// Place and submit one request; returns the streaming handle bound to
    /// the chosen replica.  `EngineStopped` when no replica is ready.
    pub fn submit(&self, req: Request) -> std::result::Result<FleetGeneration, EngineError> {
        let views = self.views();
        let mut placer = self.inner.placer.lock().unwrap();
        let target =
            placer.place(req.adapter.as_deref(), &views).ok_or(EngineError::EngineStopped)?;
        drop(placer);
        let replica = self.inner.replicas.get(target).ok_or(EngineError::EngineStopped)?;
        let guard = replica.load_guard();
        let gen = replica.client().submit(req)?;
        Ok(FleetGeneration { gen, replica: target, _guard: guard })
    }

    /// Submit and wait for the full response (one-shot convenience).
    pub fn generate(&self, req: Request) -> std::result::Result<RequestOutput, EngineError> {
        self.submit(req)?.wait()
    }

    /// Cancel by wire id without holding the generation handle: the id
    /// encodes its replica, so this is one O(1) forward, not a fan-out.
    pub fn cancel(&self, id: u64) -> std::result::Result<(), EngineError> {
        let r = self.replica_of(id);
        match self.inner.replicas.get(r) {
            Some(replica) => replica.client().cancel(id),
            None => Err(EngineError::EngineStopped),
        }
    }

    /// Register an adapter on every live replica (any replica may serve a
    /// spillover request for it), then record its home placement.  The
    /// first replica error aborts and is returned.
    pub fn register_adapter(
        &self,
        name: &str,
        adapter: Adapter,
    ) -> std::result::Result<(), EngineError> {
        let mut any = false;
        for r in &self.inner.replicas {
            if r.state() == ReplicaState::Stopped {
                continue;
            }
            r.client().register_adapter(name, adapter.clone())?;
            any = true;
        }
        if !any {
            return Err(EngineError::EngineStopped);
        }
        let views = self.views();
        self.inner.placer.lock().unwrap().register(name, &views);
        Ok(())
    }

    /// Record a home placement for an adapter that is already registered
    /// on every replica (e.g. by the fleet's per-replica setup closure,
    /// which bypasses the router).  Idempotent, like [`Placer::register`].
    pub fn place_adapter(&self, name: &str) {
        let views = self.views();
        self.inner.placer.lock().unwrap().register(name, &views);
    }

    /// Unregister an adapter everywhere and drop its placement.  Fails
    /// with the first replica rejection (e.g. queued work still references
    /// it there) — the placement stays until every replica lets go.
    pub fn unregister_adapter(&self, name: &str) -> std::result::Result<(), EngineError> {
        for r in &self.inner.replicas {
            if r.state() == ReplicaState::Stopped {
                continue;
            }
            r.client().unregister_adapter(name)?;
        }
        self.inner.placer.lock().unwrap().unregister(name);
        Ok(())
    }

    /// Fan out to every replica and merge: the fleet `stats` op.  Stopped
    /// (or mid-shutdown) replicas report an empty snapshot rather than an
    /// error — health is part of the answer, not a failure of it.
    pub fn stats(&self) -> FleetStats {
        let replicas: Vec<ReplicaStats> = self
            .inner
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                health: r.health(),
                stats: r.client().stats().unwrap_or_default(),
            })
            .collect();
        let merged =
            MetricsSnapshot::merge(&replicas.iter().map(|r| r.stats.clone()).collect::<Vec<_>>());
        FleetStats { merged, replicas }
    }

    /// Every replica's lifecycle + load row.
    pub fn health(&self) -> Vec<ReplicaHealth> {
        self.inner.replicas.iter().map(|r| r.health()).collect()
    }

    /// Move a replica to `Draining`: it finishes in-flight work but the
    /// placer routes no new admissions to it.  No-op for unknown ids.
    pub fn drain(&self, replica: usize) {
        if let Some(r) = self.inner.replicas.get(replica) {
            r.advance_to(ReplicaState::Draining);
        }
    }
}

/// A live request's event stream plus its fleet bookkeeping: which
/// replica serves it, and the RAII load token that releases the replica's
/// gauge on any terminal path (finish, cancel, or handle drop).
pub struct FleetGeneration {
    gen: Generation,
    replica: usize,
    _guard: LoadGuard,
}

impl FleetGeneration {
    /// The engine-issued wire id (globally unique across the fleet).
    pub fn id(&self) -> u64 {
        self.gen.id()
    }

    /// The replica this request was placed on.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Next event; `None` after the terminal event ([`Generation::recv`]).
    pub fn recv(&mut self) -> Option<StreamEvent> {
        self.gen.recv()
    }

    /// Ask the serving replica to cancel this request (idempotent).
    pub fn cancel(&self) {
        self.gen.cancel()
    }

    /// Drain to the terminal outcome ([`Generation::wait`]).
    pub fn wait(self) -> std::result::Result<RequestOutput, EngineError> {
        self.gen.wait()
    }
}

impl Iterator for FleetGeneration {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        self.recv()
    }
}

/// Per-replica slice of [`FleetStats`]: health row + metrics snapshot.
#[derive(Clone, Debug)]
pub struct ReplicaStats {
    pub health: ReplicaHealth,
    pub stats: MetricsSnapshot,
}

/// The fleet `stats` answer: the merged aggregate plus every replica's
/// labeled snapshot (docs/DESIGN.md §Data plane describes the wire form).
#[derive(Clone, Debug)]
pub struct FleetStats {
    pub merged: MetricsSnapshot,
    pub replicas: Vec<ReplicaStats>,
}

impl FleetStats {
    /// JSON form: `{"merged": {...}, "replicas": [{"replica": 0, "state":
    /// "ready", "load": n, "stats": {...}}, ...]}`.  The NDJSON `stats`
    /// event embeds `merged` under its legacy `stats` key so single-engine
    /// clients keep parsing.
    pub fn to_json(&self) -> Json {
        json::obj(vec![("merged", self.merged.to_json()), ("replicas", self.replicas_json())])
    }

    /// Just the per-replica rows — the NDJSON `stats` event splices these
    /// next to its legacy top-level fields.
    pub fn replicas_json(&self) -> Json {
        json::arr(
            self.replicas
                .iter()
                .map(|r| {
                    json::obj(vec![
                        ("replica", json::num(r.health.id as f64)),
                        ("state", json::s(r.health.state.as_str())),
                        ("load", json::num(r.health.load as f64)),
                        ("stats", r.stats.to_json()),
                    ])
                })
                .collect(),
        )
    }

    /// The merged two-column report followed by a compact per-replica
    /// table (`road serve --stats` in fleet mode).
    pub fn report_table(&self) -> String {
        let mut t = Table::new(&[
            "replica",
            "state",
            "load",
            "reqs",
            "tokens",
            "queue p50/p99 (ms)",
            "bank h/m/e",
            "upload B",
            "kv prefix hits",
        ]);
        for r in &self.replicas {
            let s = &r.stats;
            t.row(vec![
                r.health.id.to_string(),
                r.health.state.as_str().to_string(),
                r.health.load.to_string(),
                s.requests_completed.to_string(),
                s.tokens_generated.to_string(),
                format!("{:.1} / {:.1}", s.queue_wait.p50 / 1e3, s.queue_wait.p99 / 1e3),
                format!("{}/{}/{}", s.bank_hits, s.bank_misses, s.bank_evictions),
                s.bank_upload_bytes.to_string(),
                s.kv_prefix_hits.to_string(),
            ]);
        }
        format!("{}\n{}", self.merged.report_table(), t.render())
    }
}

/// The running fleet: owns the replica engine servers.  Keep it alive for
/// the serving lifetime; [`Fleet::shutdown`] stops every replica cleanly
/// (in-flight streams get typed terminal events).
pub struct Fleet {
    servers: Vec<EngineServer>,
    router: Router,
}

impl Fleet {
    /// Start `n_replicas` engines, each on its own named thread
    /// (`road-engine-<r>`) with its own `Runtime`, `AdapterBank`, and
    /// `BlockPool`, and an id namespace carved by base/stride so wire ids
    /// are fleet-unique.  `setup` runs on every replica's engine thread
    /// (hence `Clone`); `place` selects the router's placement policy.
    pub fn start(
        econf: EngineConfig,
        artifacts_dir: std::path::PathBuf,
        n_replicas: usize,
        place: PlaceKind,
        setup: impl Fn(&mut Engine) -> Result<()> + Send + Clone + 'static,
    ) -> Result<(Fleet, Router)> {
        if n_replicas == 0 {
            bail!("a fleet needs at least one replica");
        }
        let mut servers = Vec::with_capacity(n_replicas);
        let mut replicas = Vec::with_capacity(n_replicas);
        for r in 0..n_replicas {
            let mut rconf = econf.clone();
            rconf.request_id_base = r as u64 + 1;
            rconf.request_id_stride = n_replicas as u64;
            let (server, client) = EngineServer::start_named(
                rconf,
                artifacts_dir.clone(),
                format!("road-engine-{r}"),
                setup.clone(),
            )?;
            let replica = Replica::new(r, client);
            replica.advance_to(ReplicaState::Ready);
            servers.push(server);
            replicas.push(replica);
        }
        // A home replica may hold up to twice its decode slots in
        // outstanding work before affinity spills over.
        let overload = econf.decode_slots.saturating_mul(2).max(1);
        let router = Router {
            inner: Arc::new(RouterInner {
                replicas,
                placer: Mutex::new(Placer::new(place, overload)),
            }),
        };
        Ok((Fleet { servers, router: router.clone() }, router))
    }

    /// Another handle to the shared router.
    pub fn router(&self) -> Router {
        self.router.clone()
    }

    /// Stop every replica: mark `Stopped` (placement sends nothing new),
    /// then shut the engine threads down in replica order.
    pub fn shutdown(self) -> Result<()> {
        for r in &self.router.inner.replicas {
            r.advance_to(ReplicaState::Stopped);
        }
        for server in self.servers {
            server.shutdown()?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FleetSim: SchedSim's multi-replica mode
// ---------------------------------------------------------------------------

/// Knobs for [`FleetSim`]: the per-replica sim parameters plus the
/// placement policy and its thresholds.
#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    /// Per-replica admission policy (the engine-level scheduler).
    pub policy: PolicyKind,
    /// Fleet-level placement policy.
    pub place: PlaceKind,
    pub n_replicas: usize,
    pub decode_slots: usize,
    pub queue_capacity: usize,
    pub step_cost: Duration,
    /// Adapter-bank model slots per replica (0 = no bank model).
    pub bank_slots: usize,
    /// Bytes uploaded per bank page-in.
    pub bank_row_bytes: usize,
    /// Prefix-cache model entries per replica (0 = no prefix model).
    pub prefix_cache: usize,
    /// Leading prompt tokens forming a prefix-cache key.
    pub prefix_len: usize,
    /// Affinity overload threshold (outstanding requests per home).
    pub overload: usize,
}

impl Default for FleetSimConfig {
    fn default() -> FleetSimConfig {
        FleetSimConfig {
            policy: PolicyKind::Fcfs,
            place: PlaceKind::Affinity,
            n_replicas: 3,
            decode_slots: 4,
            queue_capacity: 4096,
            step_cost: Duration::from_millis(5),
            bank_slots: 0,
            bank_row_bytes: 0,
            prefix_cache: 0,
            prefix_len: 0,
            overload: 8,
        }
    }
}

/// Deterministic multi-replica serving sim: one [`SchedSim`] per replica
/// (each with the optional bank/prefix models), stepped in lockstep on
/// manual clocks, behind the same [`Placer`] the live router runs.  All
/// state is integer accounting on virtual time, so two runs of the same
/// workload are byte-identical — the router study's foundation.
pub struct FleetSim {
    replicas: Vec<SchedSim>,
    draining: Vec<bool>,
    placer: Placer,
    step_cost: Duration,
    /// Virtual time elapsed (steps × step cost) — the fleet-level clock
    /// the arrival loop compares against.
    elapsed: Duration,
    /// Requests submitted per replica, in placement order.
    pub placed: Vec<usize>,
    /// Submissions refused because no replica was ready.
    pub unplaced: usize,
}

impl FleetSim {
    pub fn new(cfg: &FleetSimConfig) -> FleetSim {
        let n = cfg.n_replicas.max(1);
        let replicas = (0..n)
            .map(|_| {
                let mut sim =
                    SchedSim::new(cfg.policy, cfg.decode_slots, cfg.queue_capacity, cfg.step_cost);
                if cfg.bank_slots > 0 {
                    sim = sim.with_bank(cfg.bank_slots, cfg.bank_row_bytes);
                }
                if cfg.prefix_cache > 0 {
                    sim = sim.with_prefix_cache(cfg.prefix_cache, cfg.prefix_len);
                }
                sim
            })
            .collect();
        FleetSim {
            replicas,
            draining: vec![false; n],
            placer: Placer::new(cfg.place, cfg.overload),
            step_cost: cfg.step_cost,
            elapsed: Duration::ZERO,
            placed: vec![0; n],
            unplaced: 0,
        }
    }

    fn views(&self) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(id, sim)| ReplicaView {
                id,
                ready: !self.draining.get(id).copied().unwrap_or(true),
                load: sim.queue.len() + sim.n_active(),
            })
            .collect()
    }

    /// Record an adapter's home placement (mirrors the live fan-out
    /// registration; the sim replicas need no registry).
    pub fn register(&mut self, adapter: &str) {
        let views = self.views();
        self.placer.register(adapter, &views);
    }

    /// Place and submit: returns `(replica, sim-issued id)`.
    /// `EngineStopped` when every replica is draining.
    pub fn submit(&mut self, req: Request) -> std::result::Result<(usize, u64), EngineError> {
        let views = self.views();
        let target = match self.placer.place(req.adapter.as_deref(), &views) {
            Some(t) => t,
            None => {
                self.unplaced += 1;
                return Err(EngineError::EngineStopped);
            }
        };
        let sim = self.replicas.get_mut(target).ok_or(EngineError::EngineStopped)?;
        let id = sim.submit(req)?;
        if let Some(n) = self.placed.get_mut(target) {
            *n += 1;
        }
        Ok((target, id))
    }

    /// One fleet step: every replica steps (idle replicas advance their
    /// clock only), keeping all virtual clocks in lockstep.
    pub fn step(&mut self) {
        for sim in &mut self.replicas {
            sim.step();
        }
        self.elapsed += self.step_cost;
    }

    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|s| s.has_work())
    }

    /// Step until every replica is idle (capped at `max_steps`).
    pub fn run_until_idle(&mut self, max_steps: usize) -> usize {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step();
            steps += 1;
        }
        steps
    }

    /// Virtual time elapsed since construction.
    pub fn elapsed(&self) -> Duration {
        self.elapsed
    }

    /// Mark a replica draining: it finishes its queue/lanes but the placer
    /// routes no new work to it.
    pub fn drain(&mut self, replica: usize) {
        if let Some(d) = self.draining.get_mut(replica) {
            *d = true;
        }
    }

    /// The per-replica sims (records, bank/prefix stats) for aggregation.
    pub fn replicas(&self) -> &[SchedSim] {
        &self.replicas
    }

    /// The placement registry + counters (spills, rehomes).
    pub fn placer(&self) -> &Placer {
        &self.placer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::SimOutcome;

    fn views(ready_load: &[(bool, usize)]) -> Vec<ReplicaView> {
        ready_load
            .iter()
            .enumerate()
            .map(|(id, &(ready, load))| ReplicaView { id, ready, load })
            .collect()
    }

    #[test]
    fn place_names_round_trip() {
        for kind in PlaceKind::ALL {
            assert_eq!(PlaceKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(PlaceKind::from_name("rr").unwrap(), PlaceKind::RoundRobin);
        assert!(PlaceKind::from_name("sticky").is_err());
    }

    #[test]
    fn register_balances_homes_and_spill_excludes_home() {
        let mut p = Placer::new(PlaceKind::Affinity, 8);
        let v = views(&[(true, 0), (true, 0), (true, 0)]);
        let homes: Vec<usize> = ["a", "b", "c", "d", "e", "f"]
            .iter()
            .map(|n| p.register(n, &v).unwrap())
            .collect();
        assert_eq!(homes, vec![0, 1, 2, 0, 1, 2], "homes round-robin by count");
        for (name, pl) in p.registry() {
            assert!(!pl.spill.contains(&pl.home), "{name}: spill excludes home");
            assert_eq!(pl.spill.len(), 2, "{name}: every other ready replica spills");
        }
        // Idempotent.
        assert_eq!(p.register("a", &v), Some(0));
        assert_eq!(p.registry().len(), 6);
    }

    #[test]
    fn affinity_routes_home_until_overload_then_spills_least_loaded() {
        let mut p = Placer::new(PlaceKind::Affinity, 4);
        let v = views(&[(true, 0), (true, 0), (true, 0)]);
        p.register("a", &v);
        assert_eq!(p.place(Some("a"), &v), Some(0), "home while underloaded");
        let hot = views(&[(true, 4), (true, 3), (true, 1)]);
        assert_eq!(p.place(Some("a"), &hot), Some(2), "overloaded home spills least-loaded");
        assert_eq!(p.spills, 1);
        // Home recovers: route returns home and the streak resets.
        let cool = views(&[(true, 1), (true, 3), (true, 1)]);
        assert_eq!(p.place(Some("a"), &cool), Some(0));
    }

    #[test]
    fn affinity_rehomes_after_sustained_spill_streak() {
        let mut p = Placer::new(PlaceKind::Affinity, 2);
        let v = views(&[(true, 0), (true, 0)]);
        p.register("a", &v);
        assert_eq!(p.registry()["a"].home, 0);
        let overloaded = views(&[(true, 5), (true, 0)]);
        for _ in 0..8 {
            assert_eq!(p.place(Some("a"), &overloaded), Some(1));
        }
        assert_eq!(p.rehomes, 1, "8 consecutive spills to one target re-home");
        assert_eq!(p.registry()["a"].home, 1);
        assert_eq!(p.registry()["a"].spill, vec![0]);
        assert_eq!(p.place(Some("a"), &views(&[(true, 0), (true, 0)])), Some(1));
    }

    #[test]
    fn draining_replicas_receive_no_placements() {
        let mut p = Placer::new(PlaceKind::Affinity, 8);
        let v = views(&[(true, 0), (true, 0)]);
        p.register("a", &v);
        // Home (0) drains: every placement goes elsewhere.
        let drained = views(&[(false, 0), (true, 9)]);
        for _ in 0..4 {
            assert_eq!(p.place(Some("a"), &drained), Some(1), "never the drained home");
        }
        assert_eq!(p.place(None, &drained), Some(1), "default route skips it too");
        assert_eq!(p.place(Some("a"), &views(&[(false, 0), (false, 0)])), None, "none ready");
    }

    #[test]
    fn round_robin_rotates_and_least_loaded_picks_minimum() {
        let mut rr = Placer::new(PlaceKind::RoundRobin, 8);
        let v = views(&[(true, 9), (true, 0), (true, 5)]);
        let picks: Vec<Option<usize>> = (0..6).map(|_| rr.place(None, &v)).collect();
        assert_eq!(picks, vec![Some(0), Some(1), Some(2), Some(0), Some(1), Some(2)]);
        let mut ll = Placer::new(PlaceKind::LeastLoaded, 8);
        assert_eq!(ll.place(Some("x"), &v), Some(1));
        let tie = views(&[(true, 2), (true, 2)]);
        assert_eq!(ll.place(None, &tie), Some(0), "ties break to the lowest id");
    }

    #[test]
    fn fleet_sim_conserves_requests_across_replicas() {
        let cfg = FleetSimConfig {
            n_replicas: 3,
            decode_slots: 2,
            place: PlaceKind::RoundRobin,
            ..FleetSimConfig::default()
        };
        let mut fleet = FleetSim::new(&cfg);
        for i in 0..12 {
            let adapter = format!("adapter-{}", i % 4);
            fleet.register(&adapter);
            fleet.submit(Request::new(vec![1; 4], 2).with_adapter(&adapter)).unwrap();
        }
        let steps = fleet.run_until_idle(256);
        assert!(steps > 0 && !fleet.has_work());
        let total: usize = fleet.replicas().iter().map(|s| s.records().len()).sum();
        assert_eq!(total, 12, "every submission lands exactly one terminal record");
        assert_eq!(fleet.placed.iter().sum::<usize>(), 12);
        assert_eq!(fleet.unplaced, 0);
        assert!(
            fleet
                .replicas()
                .iter()
                .flat_map(|s| s.records())
                .all(|r| r.outcome == SimOutcome::Finished),
        );
        // Round-robin spread: every replica saw work.
        assert!(fleet.placed.iter().all(|&n| n > 0), "{:?}", fleet.placed);
    }

    #[test]
    fn fleet_sim_drained_replica_gets_no_new_work_and_finishes_in_flight() {
        let cfg = FleetSimConfig {
            n_replicas: 2,
            decode_slots: 1,
            place: PlaceKind::RoundRobin,
            ..FleetSimConfig::default()
        };
        let mut fleet = FleetSim::new(&cfg);
        let (r0, _) = fleet.submit(Request::new(vec![1; 4], 4)).unwrap();
        assert_eq!(r0, 0);
        fleet.drain(0);
        for _ in 0..4 {
            let (r, _) = fleet.submit(Request::new(vec![1; 4], 1)).unwrap();
            assert_eq!(r, 1, "drained replica receives no new admissions");
        }
        fleet.run_until_idle(128);
        assert_eq!(fleet.replicas()[0].records().len(), 1, "in-flight work drains to completion");
        assert_eq!(fleet.replicas()[1].records().len(), 4);
        fleet.drain(1);
        assert!(fleet.submit(Request::new(vec![1; 2], 1)).is_err(), "whole fleet draining");
        assert_eq!(fleet.unplaced, 1);
    }
}
