"""Pure-jnp reference oracles for every Layer-1 kernel.

These are the ground truth the Pallas kernels are tested against (pytest +
hypothesis in python/tests/), and they double as the documentation of the
math:

  Eq. 2   R = diag(R_1..R_{d/2}),  R_i = [[cos t, -sin t], [sin t, cos t]]
  Eq. 3   general block  [[a11 cos t11, -a12 sin t12],
                          [a21 sin t21,  a22 cos t22]]
  Eq. 4   z = R1 (*) h + R2 (*) h_hat,  h_hat = (-h2, h1, -h4, h3, ...)

All RoAd variants (RoAd_1/2/4) share the *serving-time* representation of
two effective vectors (R1, R2) per adapted projection; only the trainable
parameterization differs (see road_vectors_*).
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Pair-swap rearrangement (the h_hat of Eq. 4)
# ---------------------------------------------------------------------------

def pairswap(h: jnp.ndarray) -> jnp.ndarray:
    """h_hat: (-h2, h1, -h4, h3, ...) along the last axis."""
    *lead, d = h.shape
    assert d % 2 == 0, "RoAd needs an even feature dimension"
    hp = h.reshape(*lead, d // 2, 2)
    swapped = jnp.stack([-hp[..., 1], hp[..., 0]], axis=-1)
    return swapped.reshape(*lead, d)


# ---------------------------------------------------------------------------
# RoAd variant parameterizations -> effective (R1, R2) vectors
# ---------------------------------------------------------------------------

def road_vectors_1(theta: jnp.ndarray, alpha: jnp.ndarray):
    """RoAd_1: theta, alpha of shape [d/2]; all four cells share them.

    R1 = interleave(a cos t, a cos t), R2 = interleave(a sin t, a sin t).
    """
    c = alpha * jnp.cos(theta)
    s = alpha * jnp.sin(theta)
    r1 = jnp.stack([c, c], axis=-1).reshape(-1)
    r2 = jnp.stack([s, s], axis=-1).reshape(-1)
    return r1, r2


def road_vectors_2(theta: jnp.ndarray, alpha: jnp.ndarray):
    """RoAd_2: theta, alpha of shape [d/2, 2] (row-wise sharing).

    Row 1 of each block uses (a[...,0], t[...,0]), row 2 uses (a[...,1],
    t[...,1]).  #trainable = 2*d.
    """
    c1 = alpha[..., 0] * jnp.cos(theta[..., 0])  # row-1 cos cell
    s1 = alpha[..., 0] * jnp.sin(theta[..., 0])  # row-1 sin cell (on -h2)
    s2 = alpha[..., 1] * jnp.sin(theta[..., 1])  # row-2 sin cell (on h1)
    c2 = alpha[..., 1] * jnp.cos(theta[..., 1])  # row-2 cos cell
    r1 = jnp.stack([c1, c2], axis=-1).reshape(-1)
    r2 = jnp.stack([s1, s2], axis=-1).reshape(-1)
    return r1, r2


def road_vectors_4(theta: jnp.ndarray, alpha: jnp.ndarray):
    """RoAd_4: theta, alpha of shape [d/2, 4] = (t11, t12, t21, t22).

    All four cells distinct.  #trainable = 4*d.
    """
    c1 = alpha[..., 0] * jnp.cos(theta[..., 0])
    s1 = alpha[..., 1] * jnp.sin(theta[..., 1])
    s2 = alpha[..., 2] * jnp.sin(theta[..., 2])
    c2 = alpha[..., 3] * jnp.cos(theta[..., 3])
    r1 = jnp.stack([c1, c2], axis=-1).reshape(-1)
    r2 = jnp.stack([s1, s2], axis=-1).reshape(-1)
    return r1, r2


ROAD_VECTOR_FNS = {1: road_vectors_1, 2: road_vectors_2, 4: road_vectors_4}


def road_dense_matrix(r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """Materialize the block-diagonal R in R^{d x d} (test/merge oracle).

    Block i (rows/cols 2i, 2i+1):
        [[ r1[2i],   -r2[2i]  ],
         [ r2[2i+1],  r1[2i+1]]]
    so that R @ h == r1*h + r2*pairswap(h).
    """
    d = r1.shape[0]
    m = jnp.zeros((d, d), dtype=r1.dtype)
    idx = jnp.arange(d // 2)
    m = m.at[2 * idx, 2 * idx].set(r1[2 * idx])
    m = m.at[2 * idx, 2 * idx + 1].set(-r2[2 * idx])
    m = m.at[2 * idx + 1, 2 * idx].set(r2[2 * idx + 1])
    m = m.at[2 * idx + 1, 2 * idx + 1].set(r1[2 * idx + 1])
    return m


# ---------------------------------------------------------------------------
# Adapter application oracles
# ---------------------------------------------------------------------------

def road_apply(h: jnp.ndarray, r1: jnp.ndarray, r2: jnp.ndarray):
    """Single-adapter RoAd apply (Eq. 4).  h [..., d]; r1, r2 [d]."""
    return r1 * h + r2 * pairswap(h)


def road_batched_apply(h, r1_bank, r2_bank, ids):
    """Heterogeneous-batch RoAd apply.

    h [B, L, d]; banks [n_adapters, d]; ids [B] int32 selecting the adapter
    of each request.  This is the paper's Eq. 4 reformulation: adapter
    selection is a gather of two vectors, application is element-wise.
    """
    r1 = r1_bank[ids][:, None, :]  # [B,1,d]
    r2 = r2_bank[ids][:, None, :]
    return r1 * h + r2 * pairswap(h)


def lora_batched_apply(h, lb_bank, la_bank, ids):
    """Heterogeneous-batch LoRA delta (the paper's §2.2 baseline).

    h [B, L, d1]; lb_bank [n, d1, r]; la_bank [n, r, d2]; returns the
    *delta* (x B_i) A_i per request — a batched matmul (bmm) chain, which is
    exactly the overhead RoAd eliminates.
    """
    lb = lb_bank[ids]                     # [B, d1, r]
    la = la_bank[ids]                     # [B, r, d2]
    mid = jnp.einsum("bld,bdr->blr", h, lb)
    return jnp.einsum("blr,brd->bld", mid, la)


def ia3_batched_apply(h, s_bank, ids):
    """Heterogeneous-batch (IA)^3: pure per-request element-wise scaling."""
    return s_bank[ids][:, None, :] * h


# ---------------------------------------------------------------------------
# Merge oracles (fold adapters into pretrained weights; paper §3.2)
# ---------------------------------------------------------------------------

def road_merge(w0: jnp.ndarray, r1: jnp.ndarray, r2: jnp.ndarray):
    """W = W0 R^T so that x @ W == road_apply(x @ W0, r1, r2).

    w0 [d_in, d_out] (inputs-right convention used by model.py).
    """
    r = road_dense_matrix(r1, r2)
    return w0 @ r.T


def lora_merge(w0: jnp.ndarray, lb: jnp.ndarray, la: jnp.ndarray):
    """W = W0 + B A (LoRA weight merging)."""
    return w0 + lb @ la


# ---------------------------------------------------------------------------
# OFT (Cayley) oracle — the paper's §2.1/§D.1 comparison baseline
# ---------------------------------------------------------------------------

def _gauss_jordan_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """Batched matrix inverse via pivot-free Gauss-Jordan, [n, w, w].

    Written with plain jnp ops (no LAPACK custom-calls) so the graph is
    loadable by the rust PJRT runtime (xla_extension 0.5.1 rejects jax's
    lapack_*_ffi custom-call targets).  Pivot-free is safe here: (I - Q)
    with Q skew-symmetric has symmetric part I, so it is well-conditioned
    with nonzero leading minors.
    """
    n, w, _ = a.shape
    aug = jnp.concatenate(
        [a, jnp.broadcast_to(jnp.eye(w, dtype=a.dtype), (n, w, w))], axis=-1)

    def body(i, aug):
        pivot = aug[:, i, :] / aug[:, i, i][:, None]        # [n, 2w]
        factors = aug[:, :, i]                               # [n, w]
        elim = aug - factors[:, :, None] * pivot[:, None, :]
        # restore the pivot row itself
        row_mask = (jnp.arange(w) == i)[None, :, None]
        return jnp.where(row_mask, pivot[:, None, :], elim)

    aug = jax.lax.fori_loop(0, w, body, aug)
    return aug[:, :, w:]


def oft_cayley_blocks(q: jnp.ndarray) -> jnp.ndarray:
    """Cayley parameterization R_i = (I + Q_i)(I - Q_i)^{-1} per block.

    q [n_blocks, w, w] raw parameters; Q = q - q^T is skew-symmetric.  The
    matrix inversion per block is exactly the extra cost RoAd avoids
    (Tab D.1).
    """
    w = q.shape[-1]
    skew = q - jnp.swapaxes(q, -1, -2)
    eye = jnp.eye(w, dtype=q.dtype)
    if w == 2:
        # Closed form: Q = [[0, b], [-b, 0]]; (I-Q)^{-1} = (I+Q)/(1+b^2).
        b = skew[..., 0, 1]
        det = 1.0 + b * b
        r00 = (1.0 - b * b) / det
        r01 = 2.0 * b / det
        return jnp.stack(
            [jnp.stack([r00, r01], axis=-1),
             jnp.stack([-r01, r00], axis=-1)], axis=-2)
    inv = _gauss_jordan_inverse(eye - skew)
    return jnp.einsum("nij,njk->nik", eye + skew, inv)


def oft_apply(h: jnp.ndarray, q: jnp.ndarray):
    """Apply block-diagonal Cayley-orthogonal R to h [..., d]."""
    *lead, d = h.shape
    n, w, _ = q.shape
    assert n * w == d
    r = oft_cayley_blocks(q)                       # [n, w, w]
    hb = h.reshape(*lead, n, w)
    zb = jnp.einsum("...nw,nvw->...nv", hb, r)     # z = R h per block
    return zb.reshape(*lead, d)


# ---------------------------------------------------------------------------
# DII (distributed interchange intervention, Eq. 1) oracle
# ---------------------------------------------------------------------------

def dii(b, s, r):
    """DII(b, s, R) = b + R^T (R s - R b).  r [k, d] with orthonormal rows."""
    return b + (s @ r.T - b @ r.T) @ r
