//! Multimodal suite (Table 6, LLaVA analogue): a synthetic continuous-
//! perception stand-in.  "Images" are short prefixes of feature tokens
//! drawn from a disjoint high-byte alphabet (128..=247); each scene's
//! answer is determined by a fixed random mapping from feature pairs to
//! answers ("visual knowledge").  The mapping must be memorized during
//! finetuning, which makes the suite knowledge-intensive — the property
//! that forces LoRA to 4.61% params in the paper and motivates the
//! RoAd₁+LoRA combination.

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

/// Feature alphabet base (disjoint from all text tasks' bytes).
const FEAT_BASE: i32 = 128;
const N_FEATURES: usize = 24;

fn feat_tok(f: usize) -> i32 {
    FEAT_BASE + f as i32
}

/// Deterministic "visual world" fact: class of a feature pair under a
/// task-specific seed.
fn world_fact(seed: u64, f1: usize, f2: usize, n_classes: usize) -> usize {
    let mut r = Rng::seed_from(seed ^ ((f1 * N_FEATURES + f2) as u64).wrapping_mul(0x9e37));
    r.below(n_classes)
}

/// A multimodal QA task: scene = [f1, f2, f3] feature tokens; the question
/// kind decides which pair's fact is asked.
pub struct MmTask {
    pub task_name: &'static str,
    pub seed: u64,
    pub n_classes: usize,
}

impl Task for MmTask {
    fn name(&self) -> &'static str {
        self.task_name
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        (0..self.n_classes).map(|i| (b'0' + i as u8) as i32).collect()
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let f1 = rng.below(N_FEATURES);
        let f2 = rng.below(N_FEATURES);
        let f3 = rng.below(N_FEATURES);
        let answer = world_fact(self.seed, f1, f2, self.n_classes);
        // prompt = scene features + textual question marker.
        let mut prompt = vec![feat_tok(f1), feat_tok(f2), feat_tok(f3)];
        prompt.extend(crate::tokenizer::encode("?"));
        Example {
            prompt,
            completion: vec![(b'0' + answer as u8) as i32],
            choices: Vec::new(),
            answer,
        }
    }
}

/// POPE analogue: binary object-presence probing — is feature `q` present
/// in the scene?  (The paper's hallucination benchmark.)
pub struct PopeX;

impl Task for PopeX {
    fn name(&self) -> &'static str {
        "pope-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        vec![b'0' as i32, b'1' as i32]
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let scene: Vec<usize> = (0..4).map(|_| rng.below(N_FEATURES)).collect();
        let (q, present) = if rng.chance(0.5) {
            (scene[rng.below(4)], true)
        } else {
            loop {
                let f = rng.below(N_FEATURES);
                if !scene.contains(&f) {
                    break (f, false);
                }
            }
        };
        let mut prompt: Vec<i32> = scene.iter().map(|&f| feat_tok(f)).collect();
        prompt.push(feat_tok(q));
        prompt.extend(crate::tokenizer::encode("?"));
        let answer = usize::from(present);
        Example {
            prompt,
            completion: vec![(b'0' + answer as u8) as i32],
            choices: Vec::new(),
            answer,
        }
    }
}

/// The four Table-6 columns: GQA / SQA / VQA-T analogues (pair-fact QA
/// with different worlds and class counts) + POPE (presence probing).
pub fn all() -> Vec<Box<dyn Task>> {
    vec![
        Box::new(MmTask { task_name: "gqa-x", seed: 0x6a41, n_classes: 4 }),
        Box::new(MmTask { task_name: "sqa-x", seed: 0x5a61, n_classes: 3 }),
        Box::new(MmTask { task_name: "vqat-x", seed: 0x7a17, n_classes: 4 }),
        Box::new(PopeX),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facts_are_stable_and_nontrivial() {
        assert_eq!(world_fact(1, 3, 5, 4), world_fact(1, 3, 5, 4));
        let classes: std::collections::BTreeSet<usize> = (0..N_FEATURES)
            .flat_map(|i| (0..N_FEATURES).map(move |j| world_fact(1, i, j, 4)))
            .collect();
        assert_eq!(classes.len(), 4);
    }

    #[test]
    fn feature_tokens_disjoint_from_text() {
        let mut rng = Rng::seed_from(91);
        for t in all() {
            let ex = t.sample(&mut rng);
            // feature tokens sit in 128.., the question mark below.
            assert!(ex.prompt.iter().filter(|&&t| t >= FEAT_BASE).count() >= 3);
            assert!(ex.completion[0] < FEAT_BASE);
        }
    }

    #[test]
    fn pope_label_matches_presence() {
        let mut rng = Rng::seed_from(92);
        for _ in 0..100 {
            let ex = PopeX.sample(&mut rng);
            let scene = &ex.prompt[..4];
            let q = ex.prompt[4];
            assert_eq!(scene.contains(&q), ex.answer == 1);
        }
    }
}
