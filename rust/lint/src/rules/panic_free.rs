//! **no-panic-hot-path** — a serving thread must not be killable.
//!
//! Everything under `rust/src/coordinator/` sits on a path a peer talks
//! to: the NDJSON front door, the engine thread, the admission queue.
//! A panic there takes down a thread holding decode slots, bank pins,
//! and client channels — the failure a typed error taxonomy exists to
//! prevent.  So non-test coordinator code may not `unwrap`/`expect`/
//! `panic!` (nor `unreachable!`/`todo!`/`unimplemented!`).
//!
//! `rust/src/runtime/epilogue.rs` is held to the same bar: its adapter
//! kernels run inside every decode step of the engine thread, so a
//! slice panic there (an out-of-range bank slot, a ragged plane) kills
//! the same thread — shape trouble must surface as typed errors.
//!
//! Allowlisted idiom: `.lock().unwrap()` / `.lock().expect(…)` (and the
//! RwLock `read`/`write` forms).  Lock poisoning means a *different*
//! thread already panicked while holding the lock; propagating is the
//! std-sanctioned idiom and strictly better than silently touching state
//! a dead thread left half-updated.
//!
//! Genuine can't-happen invariants (e.g. "prefill always pushes one
//! token before a slot activates") may carry a justified
//! `// roadlint: allow(no-panic-hot-path)` escape — the justification is
//! the reviewer-facing proof obligation.

use super::{code_matches, Finding, RepoContext};

pub const NAME: &str = "no-panic-hot-path";

const PATTERNS: [&str; 6] =
    [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Poisoning-propagation receivers allowed directly before `.unwrap()` /
/// `.expect(`.
const LOCK_RECEIVERS: [&str; 3] = [".lock()", ".read()", ".write()"];

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &ctx.files {
        let hot = file.rel.starts_with("rust/src/coordinator/")
            || file.rel == "rust/src/runtime/epilogue.rs";
        if !hot {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for pat in PATTERNS {
                for at in code_matches(&line.code, pat) {
                    if is_lock_poisoning(&line.code, at) {
                        continue;
                    }
                    out.push(Finding {
                        rule: NAME,
                        path: file.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "{} in non-test hot-path code — return a typed \
                             EngineError / restructure with let-else, or justify a \
                             roadlint allow for a proven invariant",
                            pat.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
    }
    out
}

fn is_lock_poisoning(code: &str, at: usize) -> bool {
    LOCK_RECEIVERS.iter().any(|r| code[..at].ends_with(r))
}
