//! Adapter epilogues for the reference backend: the per-row bank math
//! that runs after `z = x W + b` (paper Eq. 4 for RoAd, the bmm chain for
//! LoRA, the per-channel scale for (IA)³).
//!
//! # Layout contract
//!
//! Bank tensors arrive as the stacked `[n_slots, ...]` device-bank shapes
//! ([`crate::adapters::AdapterBank`]): one contiguous row per slot, RoAd's
//! `r1`/`r2` as two parallel `[n_slots, d_out]` planes.  [`BankView`]
//! wraps one plane and is the *only* way the kernels read it — every row
//! access is bounds-checked (`slot * row .. (slot + 1) * row`) and an
//! out-of-range slot is a typed shape error, never a slice panic in the
//! decode hot path.
//!
//! Batch rows are processed grouped by ascending bank slot
//! ([`slot_order`]): all rows sharing an adapter read its bank rows
//! back-to-back, so the gather over the bank is one forward linear walk
//! instead of a random walk per batch row.  Rows are independent, so the
//! visit order cannot change any output bit.
//!
//! # Fused vs scalar
//!
//! Each epilogue has two drivers over the *same* per-element primitives
//! ([`rot2`], [`axpy1`], plain `*`): a scalar oracle (one pair/element at
//! a time, natural order — `--fused-epilogue=false`) and a fused kernel
//! that walks `chunks_exact(8)` blocks so the autovectorizer can keep
//! eight lanes busy (no nightly `std::simd`).  Because both paths execute
//! identical arithmetic per element — `mul_add` lowers to the IEEE-754
//! correctly-rounded fused multiply-add — fused output is bitwise equal
//! to scalar output for road/ia3 and for this lora accumulation order
//! (pinned by the `prop_fused_epilogue_matches_scalar` property test).
//!
//! The kernels are total: they process whole pairs and never index past
//! any slice (roadlint's `no-panic-hot-path` covers this module).  Odd
//! rotation dims are rejected earlier, at bank/entry construction.

use anyhow::{bail, Result};

/// Bounds-checked view over one stacked `[n_slots, row]` bank plane.
pub struct BankView<'a> {
    key: &'a str,
    data: &'a [f32],
    row: usize,
    n_slots: usize,
}

impl<'a> BankView<'a> {
    /// Wrap `data` as `n_slots = data.len() / row` contiguous slot rows.
    /// A plane that is not a whole number of rows is a shape error.
    pub fn new(key: &'a str, data: &'a [f32], row: usize) -> Result<BankView<'a>> {
        if row == 0 {
            bail!("adapter bank {key}: zero-length slot rows");
        }
        if data.len() % row != 0 {
            bail!(
                "adapter bank {key}: {} elements is not a whole number of {row}-element rows",
                data.len()
            );
        }
        Ok(BankView { key, data, row, n_slots: data.len() / row })
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Slot `s`'s row, `s * row .. (s + 1) * row`.  A slot at or past the
    /// bank end is a typed out-of-range error, not a slice panic.
    pub fn row(&self, s: usize) -> Result<&'a [f32]> {
        match self.data.get(s * self.row..(s + 1) * self.row) {
            Some(r) => Ok(r),
            None => bail!(
                "adapter bank {}: slot {s} out of range ({} slots)",
                self.key,
                self.n_slots
            ),
        }
    }
}

/// Batch visit order grouped by ascending bank slot (stable within a
/// slot), making the bank gather a single linear walk.
fn slot_order(slots: &[usize]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..slots.len()).collect();
    order.sort_by_key(|&r| slots[r]);
    order
}

/// Shape check shared by the batched entry points: `z` must be exactly
/// one `d_out` row per batch slot.
fn check_rows(what: &str, z_len: usize, slots: &[usize], d_out: usize) -> Result<()> {
    if d_out == 0 || z_len != slots.len() * d_out {
        bail!(
            "{what} epilogue: {z_len} output elements for {} rows of {d_out}",
            slots.len()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Per-element primitives — the single definition both drivers execute
// ---------------------------------------------------------------------------

/// One 2-element rotation (Eq. 4): `z_e = r1_e·h_e − r2_e·h_o`,
/// `z_o = r2_o·h_e + r1_o·h_o`, each with one fused rounding.
#[inline(always)]
fn rot2(r1e: f32, r2e: f32, r1o: f32, r2o: f32, he: f32, ho: f32) -> (f32, f32) {
    (r2e.mul_add(-ho, r1e * he), r2o.mul_add(he, r1o * ho))
}

/// One fused accumulate: `z += a·b`.
#[inline(always)]
fn axpy1(z: f32, a: f32, b: f32) -> f32 {
    a.mul_add(b, z)
}

// ---------------------------------------------------------------------------
// Row kernels: scalar oracle + chunked fused driver per epilogue
// ---------------------------------------------------------------------------

/// Scalar rotation oracle: one pair at a time in natural order.  Total —
/// processes the whole pairs the three slices share; a trailing odd
/// element (rejected upstream) is left untouched rather than panicked on.
pub fn rotate_row_scalar(z: &mut [f32], r1: &[f32], r2: &[f32]) {
    let pairs = (z.len().min(r1.len()).min(r2.len())) / 2;
    for k in 0..pairs {
        let (e, o) = (2 * k, 2 * k + 1);
        let (ze, zo) = rot2(r1[e], r2[e], r1[o], r2[o], z[e], z[o]);
        z[e] = ze;
        z[o] = zo;
    }
}

/// Fused rotation: four pairs per 8-lane chunk (`chunks_exact(8)` +
/// `mul_add`, autovectorizer-friendly), remainder through the scalar
/// oracle.  Same [`rot2`] per pair, so bitwise equal to the scalar path.
pub fn rotate_row_fused(z: &mut [f32], r1: &[f32], r2: &[f32]) {
    let n = (z.len().min(r1.len()).min(r2.len()) / 2) * 2;
    let (z, _odd_tail) = z.split_at_mut(n);
    let mut zc = z.chunks_exact_mut(8);
    let mut ac = r1[..n].chunks_exact(8);
    let mut bc = r2[..n].chunks_exact(8);
    for ((zv, av), bv) in (&mut zc).zip(&mut ac).zip(&mut bc) {
        for k in 0..4 {
            let (e, o) = (2 * k, 2 * k + 1);
            let (ze, zo) = rot2(av[e], bv[e], av[o], bv[o], zv[e], zv[o]);
            zv[e] = ze;
            zv[o] = zo;
        }
    }
    rotate_row_scalar(zc.into_remainder(), ac.remainder(), bc.remainder());
}

fn scale_row_scalar(z: &mut [f32], s: &[f32]) {
    for (zv, &sv) in z.iter_mut().zip(s) {
        *zv *= sv;
    }
}

fn scale_row_fused(z: &mut [f32], s: &[f32]) {
    let n = z.len().min(s.len());
    let mut zc = z[..n].chunks_exact_mut(8);
    let mut sc = s[..n].chunks_exact(8);
    for (zv, sv) in (&mut zc).zip(&mut sc) {
        for k in 0..8 {
            zv[k] *= sv[k];
        }
    }
    scale_row_scalar(zc.into_remainder(), sc.remainder());
}

fn axpy_row_scalar(z: &mut [f32], a: f32, b: &[f32]) {
    for (zv, &bv) in z.iter_mut().zip(b) {
        *zv = axpy1(*zv, a, bv);
    }
}

fn axpy_row_fused(z: &mut [f32], a: f32, b: &[f32]) {
    let n = z.len().min(b.len());
    let mut zc = z[..n].chunks_exact_mut(8);
    let mut bc = b[..n].chunks_exact(8);
    for (zv, bv) in (&mut zc).zip(&mut bc) {
        for k in 0..8 {
            zv[k] = axpy1(zv[k], a, bv[k]);
        }
    }
    axpy_row_scalar(zc.into_remainder(), a, bc.remainder());
}

// ---------------------------------------------------------------------------
// Batched entry points (one call per adapted projection)
// ---------------------------------------------------------------------------

/// RoAd epilogue over a batch: rotate each `d_out` row of `z` by its
/// slot's `(r1, r2)` bank rows (Eq. 4, slot-grouped gather).
pub fn road(
    z: &mut [f32],
    d_out: usize,
    slots: &[usize],
    r1: &BankView,
    r2: &BankView,
    fused: bool,
) -> Result<()> {
    check_rows("road", z.len(), slots, d_out)?;
    if d_out % 2 != 0 {
        bail!("road epilogue: odd rotation dim {d_out} (rejected at construction)");
    }
    for r in slot_order(slots) {
        let (r1s, r2s) = (r1.row(slots[r])?, r2.row(slots[r])?);
        let zr = &mut z[r * d_out..(r + 1) * d_out];
        if fused {
            rotate_row_fused(zr, r1s, r2s);
        } else {
            rotate_row_scalar(zr, r1s, r2s);
        }
    }
    Ok(())
}

/// (IA)³ epilogue over a batch: scale each row of `z` by its slot's `s`
/// bank row.
pub fn ia3(
    z: &mut [f32],
    d_out: usize,
    slots: &[usize],
    s: &BankView,
    fused: bool,
) -> Result<()> {
    check_rows("ia3", z.len(), slots, d_out)?;
    for r in slot_order(slots) {
        let ss = s.row(slots[r])?;
        let zr = &mut z[r * d_out..(r + 1) * d_out];
        if fused {
            scale_row_fused(zr, ss);
        } else {
            scale_row_scalar(zr, ss);
        }
    }
    Ok(())
}

/// LoRA epilogue over a batch: `z += (x B) A` per row with the slot's
/// `[d_in, rank]` / `[rank, d_out]` bank rows — the bmm-chain baseline.
/// The rank-vector `mid = x B` accumulates identically on both paths;
/// only the `z += mid A` drive differs in iteration shape.
#[allow(clippy::too_many_arguments)]
pub fn lora(
    z: &mut [f32],
    x: &[f32],
    d_in: usize,
    d_out: usize,
    rank: usize,
    slots: &[usize],
    lb: &BankView,
    la: &BankView,
    fused: bool,
) -> Result<()> {
    check_rows("lora", z.len(), slots, d_out)?;
    if rank == 0 || x.len() != slots.len() * d_in {
        bail!(
            "lora epilogue: {} input elements for {} rows of {d_in} at rank {rank}",
            x.len(),
            slots.len()
        );
    }
    let mut mid = vec![0f32; rank];
    for r in slot_order(slots) {
        let (lbs, las) = (lb.row(slots[r])?, la.row(slots[r])?);
        if lbs.len() < d_in * rank || las.len() < rank * d_out {
            bail!("lora epilogue: bank rows shorter than [{d_in},{rank}]x[{rank},{d_out}]");
        }
        let xr = &x[r * d_in..(r + 1) * d_in];
        mid.fill(0.0);
        for (i, &xv) in xr.iter().enumerate() {
            let lrow = &lbs[i * rank..(i + 1) * rank];
            for (m, &bv) in mid.iter_mut().zip(lrow) {
                *m = axpy1(*m, xv, bv);
            }
        }
        let zr = &mut z[r * d_out..(r + 1) * d_out];
        for (t, &mv) in mid.iter().enumerate() {
            let arow = &las[t * d_out..(t + 1) * d_out];
            if fused {
                axpy_row_fused(zr, mv, arow);
            } else {
                axpy_row_scalar(zr, mv, arow);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bank(rng: &mut Rng, n_slots: usize, row: usize) -> Vec<f32> {
        rng.normal_vec(n_slots * row, 0.5)
    }

    #[test]
    fn bank_view_bounds() {
        let data = vec![0f32; 12];
        let v = BankView::new("t.r1", &data, 4).unwrap();
        assert_eq!(v.n_slots(), 3);
        assert_eq!(v.row(2).unwrap().len(), 4);
        let err = v.row(3).unwrap_err().to_string();
        assert!(err.contains("slot 3 out of range"), "{err}");
        assert!(err.contains("t.r1"), "error names the bank key: {err}");
        // Ragged planes and zero-length rows are shape errors up front.
        assert!(BankView::new("t", &data, 5).is_err());
        assert!(BankView::new("t", &data, 0).is_err());
    }

    #[test]
    fn out_of_range_slot_is_a_typed_error_not_a_panic() {
        let mut rng = Rng::seed_from(3);
        let (d, n_slots) = (8usize, 2usize);
        let r1 = bank(&mut rng, n_slots, d);
        let r2 = bank(&mut rng, n_slots, d);
        let mut z = rng.normal_vec(2 * d, 1.0);
        let r1v = BankView::new("p.r1", &r1, d).unwrap();
        let r2v = BankView::new("p.r2", &r2, d).unwrap();
        for fused in [false, true] {
            let err = road(&mut z, d, &[0, 99], &r1v, &r2v, fused).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{err}");
        }
        let sv = BankView::new("p.s", &r1, d).unwrap();
        assert!(ia3(&mut z, d, &[99, 0], &sv, true).is_err());
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let data = vec![0f32; 16];
        let v = BankView::new("t", &data, 8).unwrap();
        let mut z = vec![0f32; 8];
        // One row of 8 against two slots' worth of z: shape error.
        assert!(road(&mut z, 8, &[0, 0], &v, &v, true).is_err());
        // Odd d_out is a typed error here too (and rejected at
        // construction before any serving path reaches this).
        let v3 = BankView::new("t", &data[..6], 3).unwrap();
        let mut z3 = vec![0f32; 3];
        assert!(road(&mut z3, 3, &[0], &v3, &v3, true).is_err());
    }

    #[test]
    fn fused_matches_scalar_bitwise_across_remainders() {
        let mut rng = Rng::seed_from(11);
        // 8k and 8k+2 widths: full chunks and a 2-element remainder.
        for d in [2usize, 6, 8, 10, 16, 18, 24, 26] {
            let r1 = bank(&mut rng, 3, d);
            let r2 = bank(&mut rng, 3, d);
            let slots = [2usize, 0, 1, 1];
            let z0 = rng.normal_vec(slots.len() * d, 1.0);
            let r1v = BankView::new("k.r1", &r1, d).unwrap();
            let r2v = BankView::new("k.r2", &r2, d).unwrap();
            let (mut zs, mut zf) = (z0.clone(), z0.clone());
            road(&mut zs, d, &slots, &r1v, &r2v, false).unwrap();
            road(&mut zf, d, &slots, &r1v, &r2v, true).unwrap();
            assert_eq!(
                zs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                zf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "road d={d}"
            );
            let sv = BankView::new("k.s", &r1, d).unwrap();
            let (mut zs, mut zf) = (z0.clone(), z0.clone());
            ia3(&mut zs, d, &slots, &sv, false).unwrap();
            ia3(&mut zf, d, &slots, &sv, true).unwrap();
            assert_eq!(zs, zf, "ia3 d={d}");
        }
    }

    #[test]
    fn rotation_matches_naive_expansion() {
        // Against the hand-written Eq. 4 (separate roundings) the kernel
        // agrees to fp tolerance; identity/quarter-turn are exact.
        let mut z = vec![1.0f32, 2.0, 3.0, 4.0];
        rotate_row_fused(&mut z, &[1.0; 4], &[0.0; 4]);
        assert_eq!(z, vec![1.0, 2.0, 3.0, 4.0]);
        rotate_row_scalar(&mut z, &[0.0; 4], &[1.0; 4]);
        assert_eq!(z, vec![-2.0, 1.0, -4.0, 3.0]);
        let mut rng = Rng::seed_from(7);
        let d = 10usize;
        let (r1, r2) = (bank(&mut rng, 1, d), bank(&mut rng, 1, d));
        let h = rng.normal_vec(d, 1.0);
        let mut z = h.clone();
        rotate_row_fused(&mut z, &r1, &r2);
        for k in 0..d / 2 {
            let (e, o) = (2 * k, 2 * k + 1);
            let ze = r1[e] * h[e] - r2[e] * h[o];
            let zo = r2[o] * h[e] + r1[o] * h[o];
            assert!((z[e] - ze).abs() < 1e-5 && (z[o] - zo).abs() < 1e-5);
        }
    }

    #[test]
    fn lora_fused_within_ulp_of_scalar() {
        let mut rng = Rng::seed_from(23);
        let (d_in, d_out, rank) = (6usize, 10usize, 3usize);
        let lb = bank(&mut rng, 2, d_in * rank);
        let la = bank(&mut rng, 2, rank * d_out);
        let x = rng.normal_vec(3 * d_in, 1.0);
        let z0 = rng.normal_vec(3 * d_out, 1.0);
        let slots = [1usize, 0, 1];
        let lbv = BankView::new("k.lb", &lb, d_in * rank).unwrap();
        let lav = BankView::new("k.la", &la, rank * d_out).unwrap();
        let (mut zs, mut zf) = (z0.clone(), z0);
        lora(&mut zs, &x, d_in, d_out, rank, &slots, &lbv, &lav, false).unwrap();
        lora(&mut zf, &x, d_in, d_out, rank, &slots, &lbv, &lav, true).unwrap();
        for (a, b) in zs.iter().zip(&zf) {
            let ulps = (a.to_bits() as i64 - b.to_bits() as i64).abs();
            assert!(ulps <= 1, "{a} vs {b}: {ulps} ulps");
        }
    }

    #[test]
    fn nan_and_zero_weights_propagate() {
        // 0 · NaN must stay NaN through every path (no sparsity skips).
        let r1 = vec![0.0f32, 0.0];
        let r2 = vec![f32::NAN, f32::NAN];
        let mut z = vec![0.0f32, 0.0];
        rotate_row_fused(&mut z, &r1, &r2);
        assert!(z.iter().all(|v| v.is_nan()), "{z:?}");
        let mut z = vec![1.0f32, 2.0, 3.0, 4.0];
        axpy_row_scalar(&mut z, 0.0, &[f32::NAN, 1.0, f32::NAN, 1.0]);
        assert!(z[0].is_nan() && z[2].is_nan(), "{z:?}");
        assert_eq!((z[1], z[3]), (2.0, 4.0));
    }
}
