"""AOT pipeline: manifest integrity + HLO round-trip through xla_client."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model, train

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

needs_artifacts = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestEntryRegistry:
    def test_all_entries_unique_names(self):
        entries = aot.build_all_entries()
        names = [e.name for e in entries]
        assert len(names) == len(set(names))

    def test_signature_consistency(self):
        """Every input spec has a concrete shape and a known dtype."""
        for e in aot.build_all_entries():
            for s in e.inputs:
                assert s["dtype"] in ("f32", "i32"), e.name
                assert all(isinstance(d, int) and d > 0 for d in s["shape"]) \
                    or s["shape"] == [], (e.name, s)

    def test_entry_fn_runs(self):
        """Spot-check that a decode entry executes with zero inputs."""
        entries = {e.name: e for e in aot.build_all_entries()}
        e = entries["decode_base_tiny_b2"]
        args = []
        for s in e.inputs:
            dt = jnp.float32 if s["dtype"] == "f32" else jnp.int32
            args.append(jnp.zeros(s["shape"], dtype=dt))
        out = e.fn(*args)
        assert out[0].shape == (2, configs.TINY.vocab)


@needs_artifacts
class TestManifest:
    def test_configs_recorded(self, manifest):
        for name in ("tiny", "serve", "train", "train2"):
            assert name in manifest["configs"]
            assert manifest["configs"][name]["d_model"] % 2 == 0

    def test_entry_files_exist(self, manifest):
        for name, meta in manifest["entries"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name

    def test_params_bin_sizes(self, manifest):
        for cname, fname in manifest["params_files"].items():
            cfg = configs.get(cname)
            n = sum(int(np.prod(s)) for _, s in model.param_specs(cfg))
            size = os.path.getsize(os.path.join(ART, fname))
            assert size == 4 * n, cname

    def test_trainable_bin_sizes(self, manifest):
        for key, fname in manifest["trainable_files"].items():
            cname, method = key.split("/")
            cfg = configs.get(cname)
            n = sum(int(np.prod(s))
                    for _, s in train.trainable_specs(cfg, method))
            size = os.path.getsize(os.path.join(ART, fname))
            assert size == 4 * n, key

    def test_input_bytes_match_golden(self, manifest):
        for name, g in manifest["golden"].items():
            meta = manifest["entries"][name]
            n_in = sum(4 * int(max(np.prod(s["shape"]), 1))
                       for s in meta["inputs"])
            assert os.path.getsize(os.path.join(ART, g["in"])) == n_in, name
            n_out = sum(4 * int(max(np.prod(s["shape"]), 1))
                        for s in g["outputs"])
            assert os.path.getsize(os.path.join(ART, g["out"])) == n_out, name


@needs_artifacts
class TestHloRoundTrip:
    def test_hlo_text_parses_and_executes(self, manifest):
        """Load a lowered entry back through xla_client and execute it —
        the exact path the rust runtime takes (text -> parse -> compile)."""
        from jax._src.lib import xla_client as xc
        name = "decode_base_tiny_b2"
        meta = manifest["entries"][name]
        with open(os.path.join(ART, meta["file"])) as f:
            txt = f.read()
        assert "ENTRY" in txt
        # golden record replay in python (rust does the same in its tests)
        g = manifest["golden"].get("decode_road_tiny_b2")
        assert g is not None

    def test_golden_replay(self, manifest):
        """Recompute golden outputs from the .in.bin and compare .out.bin."""
        entries = {e.name: e for e in aot.build_all_entries()}
        name = "decode_road_tiny_b2"
        e = entries[name]
        meta = manifest["entries"][name]
        raw = open(os.path.join(ART, manifest["golden"][name]["in"]),
                   "rb").read()
        args, off = [], 0
        for s in meta["inputs"]:
            n = int(max(np.prod(s["shape"]), 1))
            dt = np.float32 if s["dtype"] == "f32" else np.int32
            a = np.frombuffer(raw, dtype=dt, count=n,
                              offset=off).reshape(s["shape"])
            off += 4 * n
            args.append(jnp.asarray(a))
        outs = e.fn(*args)
        raw_out = open(os.path.join(ART, manifest["golden"][name]["out"]),
                       "rb").read()
        off = 0
        for o, s in zip(outs, manifest["golden"][name]["outputs"]):
            n = int(max(np.prod(s["shape"]), 1))
            exp = np.frombuffer(raw_out, dtype=np.float32, count=n,
                                offset=off).reshape(s["shape"])
            off += 4 * n
            np.testing.assert_allclose(np.asarray(o), exp, rtol=1e-4,
                                       atol=1e-5)
