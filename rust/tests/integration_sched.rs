//! Per-policy scheduler invariant suite on the deterministic harness
//! ([`road::coordinator::sched::SchedSim`]): EDF ordering, priority
//! preemption of the queue, fair-share no-starvation, FCFS equivalence
//! with the pre-policy FIFO pop, determinism, and exact-virtual-time
//! deadline shedding.
//!
//! Everything here runs on the manual clock with zero sleeps and needs
//! no AOT artifacts — this suite is CI's "no hidden sleeps" canary (it
//! runs under a hard 30-second budget).  The harness invariants are
//! cross-checked against the *real* engine on the pure-Rust reference
//! backend ([`real_engine_on_reference_backend_matches_sim_ordering`]),
//! so the policies are exercised where they actually run, not only in
//! simulation.

use std::time::Duration;

use road::coordinator::queue::AdmissionQueue;
use road::coordinator::request::Request;
use road::coordinator::sched::{PolicyKind, SchedSim, SimOutcome};
use road::util::rng::Rng;

fn sim(kind: PolicyKind, slots: usize) -> SchedSim {
    SchedSim::new(kind, slots, 256, Duration::from_millis(5))
}

fn req(plen: usize, new_tokens: usize) -> Request {
    Request::new(vec![1; plen], new_tokens)
}

/// Ids in the order they reached a decode lane.  Uses the harness's
/// global admission ordinal, which is unambiguous even when several
/// lanes share one virtual `admitted_at` instant.
fn admission_order(sim: &SchedSim) -> Vec<u64> {
    let mut admitted: Vec<_> = sim
        .records()
        .iter()
        .filter_map(|r| r.admitted_seq.map(|s| (s, r.id)))
        .collect();
    admitted.sort_by_key(|&(s, _)| s);
    admitted.into_iter().map(|(_, id)| id).collect()
}

#[test]
fn edf_admits_tightest_deadline_first() {
    let mut sim = sim(PolicyKind::Edf, 1);
    // Occupy the single lane so the contenders genuinely queue.
    let busy = sim.submit(req(4, 3)).unwrap();
    sim.step();
    // FIFO arrival order: loose, none, tight — EDF must invert it.
    let loose = sim.submit(req(4, 1).with_deadline(Duration::from_secs(5))).unwrap();
    let none = sim.submit(req(4, 1)).unwrap();
    let tight = sim.submit(req(4, 1).with_deadline(Duration::from_millis(500))).unwrap();
    sim.run_until_idle(64);
    assert_eq!(admission_order(&sim), vec![busy, tight, loose, none]);
    assert!(sim.records().iter().all(|r| r.outcome == SimOutcome::Finished));
}

#[test]
fn priority_tiers_preempt_queue_order() {
    let mut sim = sim(PolicyKind::Priority, 1);
    let busy = sim.submit(req(4, 2)).unwrap();
    sim.step();
    let low_first = sim.submit(req(4, 1)).unwrap();
    let high_later = sim.submit(req(4, 1).with_priority(7)).unwrap();
    let mid = sim.submit(req(4, 1).with_priority(3)).unwrap();
    let high_last = sim.submit(req(4, 1).with_priority(7)).unwrap();
    sim.run_until_idle(64);
    assert_eq!(
        admission_order(&sim),
        vec![busy, high_later, high_last, mid, low_first],
        "tiers descend; FIFO within the tied tier; tier 0 goes last"
    );
}

#[test]
fn fair_share_keeps_a_cold_adapter_from_starving() {
    // 16 hot-adapter requests queued ahead of 2 cold ones, 2 lanes.
    let run = |kind: PolicyKind| {
        let mut s = sim(kind, 2);
        let mut hot_ids = Vec::new();
        for _ in 0..16 {
            hot_ids.push(s.submit(req(4, 4).with_adapter("hot")).unwrap());
        }
        let cold: Vec<u64> =
            (0..2).map(|_| s.submit(req(4, 4).with_adapter("cold")).unwrap()).collect();
        s.run_until_idle(512);
        (s, cold)
    };

    let (fair, cold_ids) = run(PolicyKind::FairShare);
    let (fcfs, _) = run(PolicyKind::Fcfs);
    let cold_wait = |s: &SchedSim| {
        s.records()
            .iter()
            .filter(|r| r.adapter.as_deref() == Some("cold"))
            .map(|r| r.queue_wait().expect("cold requests are admitted"))
            .max()
            .expect("cold requests recorded")
    };
    let (fair_wait, fcfs_wait) = (cold_wait(&fair), cold_wait(&fcfs));
    assert!(
        fair_wait < fcfs_wait,
        "fair-share must bound the cold adapter's wait: fair {fair_wait:?} vs fcfs {fcfs_wait:?}"
    );
    // Stronger: under fair-share the cold requests are among the first
    // four admissions after the opening pair — the hot flood cannot push
    // them to the back.
    let order = admission_order(&fair);
    for id in &cold_ids {
        let pos = order.iter().position(|x| x == id).unwrap();
        assert!(pos < 4, "cold request {id} admitted at position {pos} under fair-share");
    }
}

/// The FCFS policy must reproduce the pre-policy FIFO admission byte for
/// byte: `pop_scheduled` with the identity ranking selects exactly what
/// the old FIFO-scan `pop_admissible` algorithm selected, and leaves the
/// queue in the same residual order.
#[test]
fn fcfs_selection_matches_pre_policy_fifo_pop() {
    // The pre-policy algorithm, reimplemented literally as the reference.
    fn reference_pop(
        q: &mut Vec<Request>,
        n: usize,
        max_len: usize,
        admit: &mut impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        let mut taken = Vec::new();
        let mut keep = Vec::new();
        for r in q.drain(..) {
            if taken.len() < n && r.prompt.len() <= max_len && admit(&r) {
                taken.push(r);
            } else {
                keep.push(r);
            }
        }
        *q = keep;
        taken
    }

    let mut rng = Rng::seed_from(42);
    for _case in 0..100 {
        let n_items = rng.below(24);
        let mut queue = AdmissionQueue::new(256);
        let mut reference: Vec<Request> = Vec::new();
        for i in 0..n_items {
            let mut r = req(1 + rng.below(20), 4);
            r.id = i as u64 + 1;
            reference.push(r.clone());
            queue.push(r).unwrap();
        }
        let n = rng.below(8);
        let max_len = 1 + rng.below(20);
        let modulus = 2 + rng.below(4) as u64;
        let admit = |r: &Request| r.id % modulus != 0;

        let order: Vec<usize> = (0..queue.len()).collect(); // the FCFS ranking
        let got: Vec<u64> =
            queue.pop_scheduled(&order, n, max_len, admit).iter().map(|r| r.id).collect();
        let mut admit_again = admit; // captures are Copy
        let want: Vec<u64> = reference_pop(&mut reference, n, max_len, &mut admit_again)
            .iter()
            .map(|r| r.id)
            .collect();
        assert_eq!(got, want, "selection diverged (n={n}, max_len={max_len})");
        let rest_got: Vec<u64> = std::iter::from_fn(|| queue.pop()).map(|r| r.id).collect();
        let rest_want: Vec<u64> = reference.iter().map(|r| r.id).collect();
        assert_eq!(rest_got, rest_want, "residual queue diverged");
    }
}

/// Deadline sheds happen exactly when the virtual clock says so — never
/// early, never at exactly the budget (the spec is strictly past it),
/// and always once the budget is exceeded and a step runs.
#[test]
fn deadline_shed_is_exact_on_the_virtual_clock() {
    for kind in PolicyKind::ALL {
        let mut sim = sim(kind, 1);
        // Occupy the single lane first (4 tokens = 4 steps), THEN submit
        // the doomed request, so no policy — EDF included — can admit it
        // before its 10 ms budget runs out waiting.
        let busy = sim.submit(req(4, 4)).unwrap();
        sim.step(); // t=0: busy admitted, token 1. clock -> 5ms
        let doomed = sim.submit(req(4, 1).with_deadline(Duration::from_millis(10))).unwrap();
        sim.step(); // t=5ms: doomed elapsed 0.  busy token 2. clock -> 10ms
        sim.step(); // t=10ms: elapsed 5 <= 10.  busy token 3. clock -> 15ms
        assert!(
            sim.records().iter().all(|r| r.id != doomed),
            "[{kind:?}] shed before the budget elapsed"
        );
        sim.step(); // t=15ms: elapsed 10 > 10 is false — still not expired
        assert!(
            sim.records().iter().all(|r| r.id != doomed),
            "[{kind:?}] shed at exactly the budget (spec: strictly past it)"
        );
        sim.step(); // t=20ms: elapsed 15 > 10 — shed now
        let rec = sim
            .records()
            .iter()
            .find(|r| r.id == doomed)
            .unwrap_or_else(|| panic!("[{kind:?}] expired request not shed"));
        assert_eq!(rec.outcome, SimOutcome::DeadlineShed);
        assert_eq!(rec.e2e(), Duration::from_millis(15), "shed timestamp is exact");
        assert!(rec.admitted_at.is_none(), "shed from the queue, never admitted");
        sim.run_until_idle(64);
        assert!(sim.records().iter().any(|r| r.id == busy && r.outcome == SimOutcome::Finished));
    }
}

/// Two identical runs produce identical terminal records — the harness
/// (and therefore every policy on it) is deterministic.
#[test]
fn identical_runs_produce_identical_records() {
    let run = |kind: PolicyKind| {
        let mut s = sim(kind, 3);
        let mut rng = Rng::seed_from(1234);
        for i in 0..40 {
            let mut r = req(1 + rng.below(8), 1 + rng.below(6));
            if i % 3 == 0 {
                r = r.with_deadline(Duration::from_millis(20 + rng.below(60) as u64));
            }
            if i % 4 == 0 {
                r = r.with_priority(rng.below(4) as u8);
            }
            if i % 2 == 0 {
                r = r.with_adapter(&format!("a{}", rng.below(3)));
            }
            s.submit(r).unwrap();
            if i % 5 == 0 {
                s.step();
            }
        }
        s.run_until_idle(2048);
        // Project onto clock-base-independent values (Instants differ
        // between runs; Durations do not).
        s.records()
            .iter()
            .map(|r| (r.id, r.adapter.clone(), r.priority, r.outcome, r.queue_wait(), r.e2e()))
            .collect::<Vec<_>>()
    };
    for kind in PolicyKind::ALL {
        assert_eq!(run(kind), run(kind), "[{kind:?}] nondeterministic records");
    }
}

/// The real engine on the reference backend honors the same priority
/// ordering the harness promises: tiers descend, FIFO within a tier —
/// verified through actual prefill/decode execution, no artifacts, no
/// sleeps (virtual time never advances, so nothing can expire).
#[test]
fn real_engine_on_reference_backend_matches_sim_ordering() {
    use road::coordinator::engine::{Engine, EngineConfig};
    use road::coordinator::request::{SamplingParams, StreamEvent};
    use road::util::clock::Clock;

    let rt = std::rc::Rc::new(road::runtime::Runtime::reference());
    let clock = Clock::manual();
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "base".into(),
        decode_slots: 1,
        queue_capacity: 64,
        policy: PolicyKind::Priority,
        clock: clock.clone(),
        ..Default::default()
    };
    let mut eng = Engine::new(rt, econf).unwrap();
    let greedy = |p: i32, n: usize| {
        Request::new(vec![p, p + 1], n).with_sampling(SamplingParams {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stop_token: None,
        })
    };
    // Occupy the single lane so the contenders genuinely queue.
    let busy = eng.submit(greedy(1, 3)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 1);
    let low_first = eng.submit(greedy(2, 1)).unwrap();
    let high_later = eng.submit(greedy(3, 1).with_priority(7)).unwrap();
    let mid = eng.submit(greedy(4, 1).with_priority(3)).unwrap();
    let high_last = eng.submit(greedy(5, 1).with_priority(7)).unwrap();
    let mut admitted = Vec::new();
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Admitted { id } = ev {
                admitted.push(id);
            }
        }
    }
    assert_eq!(
        admitted,
        vec![high_later, high_last, mid, low_first],
        "engine admission order must match the harness's priority semantics (busy={busy})"
    );
}

/// Regression: bucket selection must follow the policy ranking, not the
/// other way around.  Pre-fix, `maybe_prefill` picked the prefill bucket
/// from the queue's *minimum* prompt length before asking the policy, so
/// a short low-urgency prompt at the queue head forced a 16-token bucket
/// and the tight-deadline 20-token request EDF ranked first silently
/// failed the bucket's length filter — admission order inverted the
/// policy, which the padded buckets were hiding.
#[test]
fn edf_engine_admits_long_tight_deadline_prompt_over_short_loose_ones() {
    use road::coordinator::engine::{Engine, EngineConfig};
    use road::coordinator::request::{SamplingParams, StreamEvent};
    use road::util::clock::Clock;

    let rt = std::rc::Rc::new(road::runtime::Runtime::reference());
    let econf = EngineConfig {
        model: "tiny".into(),
        mode: "base".into(),
        decode_slots: 1,
        queue_capacity: 64,
        policy: PolicyKind::Edf,
        clock: Clock::manual(),
        ..Default::default()
    };
    let mut eng = Engine::new(rt, econf).unwrap();
    let greedy = |prompt: Vec<i32>, n: usize, deadline: Duration| {
        Request::new(prompt, n).with_deadline(deadline).with_sampling(SamplingParams {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stop_token: None,
        })
    };
    // Three short, loose-deadline prompts arrive first...
    let shorts: Vec<u64> = (0..3)
        .map(|i| eng.submit(greedy(vec![10 + i; 4], 1, Duration::from_secs(60))).unwrap())
        .collect();
    // ...then a 20-token prompt with the tightest deadline.  Only the
    // (2, 32) bucket fits it, while the queue's minimum prompt length (4)
    // elects a 16-token bucket.
    let long = eng.submit(greedy((1..21).collect(), 1, Duration::from_secs(5))).unwrap();
    let mut admitted = Vec::new();
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Admitted { id } = ev {
                admitted.push(id);
            }
        }
    }
    assert_eq!(
        admitted.first().copied(),
        Some(long),
        "EDF must admit the tight-deadline request first even though its \
         prompt needs a larger bucket than the queue head's (shorts={shorts:?})"
    );
    assert_eq!(admitted.len(), 4, "everyone is eventually admitted");
}

/// The sched study itself is byte-reproducible: the acceptance criterion
/// `road bench-serving --study sched --sim-clock` relies on this.  Each
/// policy contributes an atomic-prefill row (chunk 0) and a chunked row,
/// and chunking must strictly lower the ITL-stall p99 under the
/// long-prompt-injected workload.
#[test]
fn sched_study_sim_is_byte_identical_across_runs() {
    let render = || {
        let pts = road::bench::sched_study_sim(48, 6, 8, 9);
        // 4 policies x {atomic, chunked}.
        assert_eq!(pts.len(), PolicyKind::ALL.len() * 2);
        road::bench::sched_points_json(&pts).to_string_pretty()
    };
    let (a, b) = (render(), render());
    assert_eq!(a, b, "sched study JSON must be byte-identical across runs");
    // And it is real JSON naming every policy twice (chunk 0, then 16).
    let parsed = road::util::json::Json::parse(&a).unwrap();
    let arr = parsed.as_arr().unwrap();
    let names: Vec<&str> =
        arr.iter().map(|p| p.get("policy").unwrap().as_str().unwrap()).collect();
    assert_eq!(
        names,
        vec!["fcfs", "fcfs", "edf", "edf", "priority", "priority", "fair", "fair"]
    );
    for pair in arr.chunks(2) {
        let (atomic, chunked) = (&pair[0], &pair[1]);
        assert_eq!(atomic.get("prefill_chunk").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(chunked.get("prefill_chunk").unwrap().as_f64().unwrap(), 16.0);
        let stall_a = atomic.get("itl_stall_p99_ms").unwrap().as_f64().unwrap();
        let stall_c = chunked.get("itl_stall_p99_ms").unwrap().as_f64().unwrap();
        assert!(
            stall_c < stall_a,
            "chunked prefill must strictly lower the ITL-stall p99: \
             atomic {stall_a} vs chunked {stall_c} ({})",
            atomic.get("policy").unwrap().as_str().unwrap()
        );
        for p in pair {
            assert!(p.get("per_adapter").unwrap().as_arr().unwrap().len() > 1);
        }
    }
}
