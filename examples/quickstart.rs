//! Quickstart: load the AOT artifacts, start the multi-adapter serving
//! engine, register two RoAd adapters, and serve a heterogeneous batch.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use anyhow::Result;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::{Engine, EngineConfig};
use road::coordinator::request::Request;
use road::runtime::Runtime;
use road::util::rng::Rng;

fn main() -> Result<()> {
    // 1. The runtime loads HLO-text artifacts through PJRT (CPU) — python
    //    ran once at `make artifacts` and never again.
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    println!("loaded manifest with {} entries", rt.manifest.entries.len());

    // 2. An engine = one compiled decode executable + prefill buckets +
    //    device-resident params + an adapter bank.
    let mut engine = Engine::new(
        rt,
        EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 4,
            queue_capacity: 64,
            ..Default::default()
        },
    )?;

    // 3. Register per-user adapters (normally loaded from a finetuning
    //    checkpoint; random rotations suffice for the demo).
    let mut rng = Rng::seed_from(1);
    engine.register_adapter("alice", &Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.2)))?;
    engine.register_adapter("bob", &Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.2)))?;

    // 4. Serve a batch where every request wants a different adapter —
    //    the paper's heterogeneous-batching scenario, handled by the
    //    element-wise Eq.-4 path in a single decode executable.
    let reqs = vec![
        Request::new(road::tokenizer::encode("hello"), 12).with_adapter("alice"),
        Request::new(road::tokenizer::encode("hello"), 12).with_adapter("bob"),
        Request::new(road::tokenizer::encode("hello"), 12), // base model
    ];
    let outs = engine.run_all(reqs)?;
    for o in &outs {
        println!(
            "req {} (adapter {:?}): {} tokens, ttft {:.1}ms",
            o.id,
            o.adapter,
            o.tokens.len(),
            1e3 * o.ttft
        );
    }
    println!("{}", engine.metrics.report());
    Ok(())
}
