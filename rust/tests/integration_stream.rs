//! Streaming-lifecycle integration tests on the tiny config: event
//! grammar, stream↔one-shot token identity, cancellation reclaim (slot +
//! bank pin), deadline shedding (queued and in-flight, driven by a manual
//! clock — no sleeps), dropped-handle auto-cancel, and the
//! NDJSON-over-TCP front door.
//!
//! Every test runs unconditionally: on the pure-Rust reference backend
//! when no artifacts are built (no native XLA needed — the real engine +
//! threaded server + TCP front door execute end to end on every
//! `cargo test`), and on the PJRT backend when artifacts exist,
//! preserving the pre-backend coverage.  `ROAD_TEST_BACKEND=ref|pjrt`
//! overrides the choice.

use std::rc::Rc;
use std::time::Duration;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::{Engine, EngineConfig};
use road::coordinator::queue::EngineError;
use road::coordinator::request::{FinishReason, Request, SamplingParams, StreamEvent};
use road::coordinator::server::EngineServer;
use road::runtime::Runtime;
use road::util::clock::Clock;
use road::util::rng::Rng;

/// Suite backend ([`road::runtime::BackendKind::auto`]):
/// `ROAD_TEST_BACKEND` (ref|pjrt) wins; otherwise PJRT when artifacts are
/// built (the pre-backend behavior), reference when they are not (so the
/// suite executes instead of skipping).
fn test_backend() -> road::runtime::BackendKind {
    road::runtime::BackendKind::auto()
}

fn rt() -> Rc<Runtime> {
    let rt = Runtime::for_backend(test_backend(), road::Manifest::default_dir())
        .expect("run `make artifacts` first");
    Rc::new(rt)
}

fn tiny_econf(mode: &str) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        mode: mode.into(),
        decode_slots: 2,
        queue_capacity: 64,
        backend: test_backend(),
        ..Default::default()
    }
}

/// Engine config on a shared manual clock: the test advances `clock` to
/// drive deadline enforcement deterministically instead of sleeping.
fn tiny_econf_clocked(mode: &str, clock: Clock) -> EngineConfig {
    EngineConfig { clock, ..tiny_econf(mode) }
}

fn greedy(prompt: &[i32], max_new: usize) -> Request {
    Request::new(prompt.to_vec(), max_new).with_sampling(SamplingParams {
        temperature: 0.0,
        top_k: 0,
        seed: 0,
        stop_token: None,
    })
}

/// Deterministic adapter shared between the one-shot and streaming engines.
fn tiny_adapter(rt: &Rc<Runtime>, seed: u64) -> Adapter {
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::seed_from(seed);
    Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3))
}

/// The redesign's equivalence guarantee: per-token streaming is a pure
/// observability change — the concatenated `Token` events equal the
/// terminal output, which equals the pre-redesign one-shot (`run_all`)
/// result token for token.
#[test]
fn streamed_tokens_concatenate_to_one_shot_output() {
    let rt = rt();
    let adapter = tiny_adapter(&rt, 17);
    let mk_reqs = || {
        vec![
            greedy(&[10, 20, 30], 8).with_adapter("x"),
            greedy(&[5, 6], 6),
            greedy(&[9, 8, 7, 6], 7).with_adapter("x"),
        ]
    };

    // One-shot reference path: direct engine, run_all.
    let mut eng = Engine::new(rt.clone(), tiny_econf("road")).unwrap();
    eng.register_adapter("x", &adapter).unwrap();
    let mut one_shot = eng.run_all(mk_reqs()).unwrap();
    one_shot.sort_by_key(|o| o.id);

    // Streaming path: threaded server, same config and adapter.
    let dir = road::Manifest::default_dir();
    let (server, client) = EngineServer::start(tiny_econf("road"), dir, move |eng| {
        eng.register_adapter("x", &adapter)?;
        Ok(())
    })
    .unwrap();
    let generations: Vec<_> =
        mk_reqs().into_iter().map(|r| client.submit(r).unwrap()).collect();
    let mut streamed = Vec::new();
    for generation in generations {
        let id = generation.id();
        let events: Vec<StreamEvent> = generation.collect();
        assert!(
            matches!(events.first(), Some(StreamEvent::Admitted { id: a }) if *a == id),
            "stream must open with Admitted: {events:?}"
        );
        let tokens: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                StreamEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        // ttft_hint rides on the first token only; positions are dense.
        for (i, ev) in events
            .iter()
            .filter(|e| matches!(e, StreamEvent::Token { .. }))
            .enumerate()
        {
            let StreamEvent::Token { pos, ttft_hint, .. } = ev else { unreachable!() };
            assert_eq!(*pos, i, "token positions must be dense");
            assert_eq!(ttft_hint.is_some(), i == 0, "ttft hint on first token only");
        }
        let Some(StreamEvent::Finished(out)) = events.last() else {
            panic!("stream must end with Finished: {events:?}");
        };
        assert_eq!(out.finish, FinishReason::MaxTokens);
        assert_eq!(tokens, out.tokens, "streamed tokens must concatenate to the output");
        streamed.push(out.clone());
    }
    streamed.sort_by_key(|o| o.id);
    assert_eq!(streamed.len(), one_shot.len());
    for (s, o) in streamed.iter().zip(&one_shot) {
        assert_eq!(s.tokens, o.tokens, "streaming changed request {} output", s.id);
    }
    server.shutdown().unwrap();
}

/// Cancellation mid-decode reclaims everything: the decode slot frees, the
/// adapter's bank slot unpins (evictable again), metrics count the
/// cancellation, and the freed lane serves new work.
#[test]
fn cancel_mid_decode_frees_slot_and_unpins_bank() {
    let rt = rt();
    let adapter = tiny_adapter(&rt, 4);
    let mut eng = Engine::new(rt.clone(), tiny_econf("road")).unwrap();
    eng.register_adapter("a", &adapter).unwrap();

    let id = eng.submit(greedy(&[1, 2, 3], 32).with_adapter("a")).unwrap();
    // Admit + decode a few tokens.
    let mut tokens_seen = 0usize;
    for _ in 0..3 {
        for ev in eng.step().unwrap() {
            if matches!(ev, StreamEvent::Token { .. }) {
                tokens_seen += 1;
            }
        }
    }
    assert!(tokens_seen >= 2, "request should be mid-decode");
    assert_eq!(eng.n_active(), 1);
    let bank_slot = eng.registry.slot_of("a").expect("adapter resident while in flight");
    assert!(eng.registry.is_pinned(bank_slot), "in-flight lane pins its bank slot");

    let out = eng.cancel(id).expect("in-flight request is cancellable");
    assert_eq!(out.finish, FinishReason::Cancelled);
    assert_eq!(out.tokens.len(), tokens_seen, "partial output carries streamed tokens");
    assert_eq!(eng.n_active(), 0, "decode slot freed");
    assert!(!eng.registry.is_pinned(bank_slot), "bank pin released");
    assert_eq!(eng.metrics.requests_cancelled, 1);
    assert!(eng.cancel(id).is_none(), "second cancel is a no-op");

    // The reclaimed lane serves new work.
    let outs = eng.run_all(vec![greedy(&[4, 5], 3)]).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::MaxTokens);
}

/// Cancelling a still-queued request never touches a slot and yields an
/// empty Cancelled output.
#[test]
fn cancel_queued_request_before_admission() {
    let rt = rt();
    let mut eng = Engine::new(rt.clone(), tiny_econf("base")).unwrap();
    // Fill both slots, then queue a third.
    eng.submit(greedy(&[1, 2], 16)).unwrap();
    eng.submit(greedy(&[3, 4], 16)).unwrap();
    let queued = eng.submit(greedy(&[5, 6], 16)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 2);
    let out = eng.cancel(queued).expect("queued request is cancellable");
    assert_eq!(out.finish, FinishReason::Cancelled);
    assert!(out.tokens.is_empty());
    assert_eq!(eng.metrics.requests_cancelled, 1);
    // The two in-flight requests are unaffected.
    let mut finished = 0;
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if matches!(ev, StreamEvent::Finished(_)) {
                finished += 1;
            }
        }
    }
    assert_eq!(finished, 2);
}

/// Deadline enforcement at admission: expired queued work is shed with a
/// typed `DeadlineExceeded` before it ever occupies a decode slot.  The
/// engine runs on a manual clock, so "waiting past the budget" is an
/// exact virtual jump, not a sleep.
#[test]
fn expired_queued_requests_are_shed() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = Engine::new(rt.clone(), tiny_econf_clocked("base", clock.clone())).unwrap();
    // Two long-running requests occupy both slots…
    eng.submit(greedy(&[1, 2], 12)).unwrap();
    eng.submit(greedy(&[3, 4], 12)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 2);
    // …so this deadline-bearing request waits in the queue past its budget.
    let doomed = eng
        .submit(greedy(&[5, 6], 4).with_deadline(Duration::from_millis(1)))
        .unwrap();
    clock.advance(Duration::from_millis(5));
    let events = eng.step().unwrap();
    assert!(
        events.iter().any(|e| matches!(
            e,
            StreamEvent::Error { id, error: EngineError::DeadlineExceeded } if *id == doomed
        )),
        "expected DeadlineExceeded for {doomed}: {events:?}"
    );
    assert_eq!(eng.metrics.deadline_shed, 1);
    // The shed request never became active; the survivors finish.
    let mut finished = 0;
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            assert!(ev.id() != doomed, "shed request must not produce further events");
            if matches!(ev, StreamEvent::Finished(_)) {
                finished += 1;
            }
        }
    }
    assert_eq!(finished, 2);
}

/// Deadline enforcement per decode step: an admitted request whose budget
/// runs out mid-generation is reaped — slot freed, typed error emitted.
#[test]
fn expired_inflight_request_is_reaped() {
    let rt = rt();
    let clock = Clock::manual();
    let mut eng = Engine::new(rt.clone(), tiny_econf_clocked("base", clock.clone())).unwrap();
    let id = eng
        .submit(greedy(&[1, 2, 3], 64).with_deadline(Duration::from_millis(25)))
        .unwrap();
    // Virtual time stands still through the first step, so admission is
    // trivially inside the budget; deadlines are only enforced between
    // steps, so jumping the clock past the budget forces the reap on the
    // next step — exactly, with no sleep and no timing slack.
    let events = eng.step().unwrap();
    assert!(
        events.iter().any(|e| matches!(e, StreamEvent::Admitted { .. })),
        "request admitted before its deadline: {events:?}"
    );
    assert_eq!(eng.n_active(), 1);
    clock.advance(Duration::from_millis(100));
    let events = eng.step().unwrap();
    assert!(
        events.iter().any(|e| matches!(
            e,
            StreamEvent::Error { id: i, error: EngineError::DeadlineExceeded } if *i == id
        )),
        "expected in-flight reap: {events:?}"
    );
    assert_eq!(eng.n_active(), 0, "reaped lane is freed");
    assert_eq!(eng.metrics.deadline_shed, 1);
    assert!(!eng.has_work());
}

/// Engine admission is policy-driven: with `policy = edf`, the tightest
/// queued deadline admits first regardless of FIFO order.  Virtual time
/// never advances here, so the deadlines order admission without any
/// risk of actually expiring.
#[test]
fn engine_respects_edf_admission_order() {
    let rt = rt();
    let clock = Clock::manual();
    let mut econf = tiny_econf_clocked("base", clock.clone());
    econf.policy = road::coordinator::sched::PolicyKind::Edf;
    let mut eng = Engine::new(rt.clone(), econf).unwrap();
    // Fill both lanes so the contenders genuinely queue.
    eng.submit(greedy(&[1, 2], 2)).unwrap();
    eng.submit(greedy(&[3, 4], 2)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.n_active(), 2);
    // FIFO arrival order: loose deadline, no deadline, tight deadline.
    let loose = eng.submit(greedy(&[1, 1], 1).with_deadline(Duration::from_secs(50))).unwrap();
    let none = eng.submit(greedy(&[2, 2], 1)).unwrap();
    let tight = eng.submit(greedy(&[3, 3], 1).with_deadline(Duration::from_secs(5))).unwrap();
    let mut admitted = Vec::new();
    while eng.has_work() {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Admitted { id } = ev {
                if id == loose || id == none || id == tight {
                    admitted.push(id);
                }
            }
        }
    }
    assert_eq!(admitted, vec![tight, loose, none], "EDF admission order, FIFO broken");
}

/// A dropped `Generation` handle is a hung-up client: the engine cancels
/// the request (slot + pin reclaimed, `requests_cancelled` counted) and
/// the waiter entry does not leak — the engine goes fully idle and keeps
/// serving.
#[test]
fn dropped_generation_cancels_and_does_not_leak() {
    let dir = road::Manifest::default_dir();
    let (server, client) = EngineServer::start(tiny_econf("base"), dir, |_| Ok(())).unwrap();

    let mut generation = client.submit(greedy(&[7, 8, 9], 120)).unwrap();
    // Wait until it is decoding so the drop exercises the mid-flight path.
    loop {
        match generation.recv().expect("stream ended before first token") {
            StreamEvent::Token { .. } => break,
            StreamEvent::Finished(_) | StreamEvent::Error { .. } => {
                panic!("120-token request finished before cancel")
            }
            StreamEvent::Admitted { .. } => {}
        }
    }
    drop(generation);

    // The cancel lands asynchronously; poll stats (yielding, not
    // sleeping) until it shows up.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let stats = client.stats().unwrap();
        if stats.requests_cancelled == 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "engine never recorded the drop-cancel: {}",
            stats.report()
        );
        std::thread::yield_now();
    }
    // Engine is healthy and the lane is reusable.
    let out = client.generate(greedy(&[1, 2], 4)).unwrap();
    assert_eq!(out.tokens.len(), 4);
    server.shutdown().unwrap();
}

/// Explicit `Generation::cancel` terminates the stream with a
/// `Finished(Cancelled)` carrying the tokens observed so far.
#[test]
fn explicit_cancel_yields_cancelled_finish() {
    let dir = road::Manifest::default_dir();
    let (server, client) = EngineServer::start(tiny_econf("base"), dir, |_| Ok(())).unwrap();
    let mut generation = client.submit(greedy(&[3, 1, 4], 120)).unwrap();
    let mut seen = 0usize;
    let out = loop {
        match generation.recv().expect("engine died mid-stream") {
            StreamEvent::Token { .. } => {
                seen += 1;
                if seen == 2 {
                    generation.cancel();
                }
            }
            StreamEvent::Finished(out) => break out,
            StreamEvent::Error { error, .. } => panic!("unexpected error: {error}"),
            StreamEvent::Admitted { .. } => {}
        }
    };
    assert_eq!(out.finish, FinishReason::Cancelled);
    assert!(
        out.tokens.len() >= 2 && out.tokens.len() < 120,
        "cancel should land mid-generation ({} tokens)",
        out.tokens.len()
    );
    let stats = client.stats().unwrap();
    assert_eq!(stats.requests_cancelled, 1);

    // The one-shot path sees the same outcome as a typed error: a caller
    // using wait()/generate() gets EngineError::Cancelled, never a
    // silently truncated Ok.
    let generation = client.submit(greedy(&[2, 7, 1], 120)).unwrap();
    client.cancel(generation.id()).unwrap();
    assert!(matches!(generation.wait(), Err(EngineError::Cancelled)));
    server.shutdown().unwrap();
}

/// The NDJSON front door end to end over loopback: one request line in,
/// streamed event lines out (admitted → token* → finished), tag echoed,
/// stats op answered — the CI smoke test's in-process twin.
#[test]
fn ndjson_loopback_round_trip() {
    use road::util::json::Json;
    use std::io::{BufRead, BufReader, Write};

    let adapter = {
        let rt = rt();
        tiny_adapter(&rt, 6)
    };
    let dir = road::Manifest::default_dir();
    // The listener now fronts a fleet; a single-replica fleet is the
    // pre-router serving shape.
    let (fleet, router) = road::coordinator::Fleet::start(
        tiny_econf("road"),
        dir,
        1,
        road::coordinator::PlaceKind::Affinity,
        move |eng| {
            eng.register_adapter("srv", &adapter)?;
            Ok(())
        },
    )
    .unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = road::coordinator::net::serve(listener, router);
    });

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.write_all(
        b"{\"op\":\"generate\",\"prompt\":[11,12,13],\"max_new_tokens\":5,\
          \"adapter\":\"srv\",\"tag\":\"t1\"}\n",
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut kinds = Vec::new();
    let mut tokens = Vec::new();
    let finished = loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed early");
        let ev = Json::parse(line.trim()).unwrap();
        assert_eq!(ev.get("tag").unwrap().as_str().unwrap(), "t1", "tag echo on {line}");
        let kind = ev.get("event").unwrap().as_str().unwrap().to_string();
        if kind == "token" {
            tokens.push(ev.get("token").unwrap().as_f64().unwrap() as i32);
        }
        kinds.push(kind.clone());
        if kind == "finished" {
            break ev;
        }
        assert_ne!(kind, "error", "unexpected wire error: {line}");
    };
    assert_eq!(kinds.first().map(String::as_str), Some("admitted"));
    assert_eq!(tokens.len(), 5);
    let wire_tokens: Vec<i32> = finished
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(tokens, wire_tokens, "streamed lines concatenate to the finished payload");
    assert_eq!(finished.get("finish").unwrap().as_str().unwrap(), "max_tokens");
    assert_eq!(finished.get("adapter").unwrap().as_str().unwrap(), "srv");

    // The stats op answers on the same connection.
    conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    let ev = Json::parse(line.trim()).unwrap();
    assert_eq!(ev.get("event").unwrap().as_str().unwrap(), "stats");
    assert_eq!(
        ev.get("stats").unwrap().get("requests_completed").unwrap().as_usize().unwrap(),
        1
    );
    // Fleet-mode stats fields ride alongside the legacy shape.
    assert_eq!(ev.get("replicas").unwrap().as_arr().unwrap().len(), 1);
    assert!(ev.get("active_connections").unwrap().as_usize().unwrap() >= 1);
    fleet.shutdown().unwrap();
}
