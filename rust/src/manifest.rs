//! Typed view of artifacts/manifest.json — the contract between the AOT
//! compile path (python/compile/aot.py) and this runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelConfigInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub n_adapters: usize,
    pub lora_rank: usize,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub group: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub config: String,
    /// "mode" for serving entries, "method" for train/eval entries.
    pub mode: Option<String>,
    pub method: Option<String>,
    pub batch: Option<usize>,
    pub prompt_len: Option<usize>,
    pub seq_len: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl EntryInfo {
    /// Positional index of the first input in `group`.
    pub fn group_range(&self, group: &str) -> (usize, usize) {
        let mut start = usize::MAX;
        let mut end = 0;
        for (i, s) in self.inputs.iter().enumerate() {
            if s.group == group {
                start = start.min(i);
                end = i + 1;
            }
        }
        if start == usize::MAX {
            (0, 0)
        } else {
            (start, end)
        }
    }

    pub fn input_index(&self, group: &str, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.group == group && s.name == name)
            .ok_or_else(|| anyhow!("entry {} has no input {group}/{name}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct GoldenInfo {
    pub entry: String,
    pub in_file: String,
    pub out_file: String,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ModelConfigInfo>,
    pub entries: BTreeMap<String, EntryInfo>,
    pub params_files: BTreeMap<String, String>,
    pub trainable_files: BTreeMap<String, String>,
    pub golden: BTreeMap<String, GoldenInfo>,
    pub serve_decode_batches: Vec<usize>,
    pub serve_prefill_buckets: Vec<(usize, usize)>,
    /// True for the in-memory manifest the reference backend synthesizes
    /// ([`crate::runtime::reference::synthetic_manifest`]): no files back
    /// it, and parameters are generated deterministically instead of
    /// loaded from `params_<cfg>.bin`.
    pub synthetic: bool,
}

fn parse_iospec(j: &Json, default_group: &str) -> Result<IoSpec> {
    Ok(IoSpec {
        group: j.opt("group").map(|g| g.as_str().unwrap_or(default_group).to_string())
            .unwrap_or_else(|| default_group.to_string()),
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.as_arr()?.iter().map(|x| x.as_usize().unwrap_or(0)).collect(),
        dtype: DType::parse(j.get("dtype")?.as_str()?)?,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut configs = BTreeMap::new();
        for (name, c) in j.get("configs")?.as_obj()? {
            configs.insert(
                name.clone(),
                ModelConfigInfo {
                    name: name.clone(),
                    vocab: c.get("vocab")?.as_usize()?,
                    d_model: c.get("d_model")?.as_usize()?,
                    n_layers: c.get("n_layers")?.as_usize()?,
                    n_heads: c.get("n_heads")?.as_usize()?,
                    d_ff: c.get("d_ff")?.as_usize()?,
                    max_seq: c.get("max_seq")?.as_usize()?,
                    head_dim: c.get("head_dim")?.as_usize()?,
                    n_adapters: c.get("n_adapters")?.as_usize()?,
                    lora_rank: c.get("lora_rank")?.as_usize()?,
                },
            );
        }

        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|x| parse_iospec(x, "data"))
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|x| parse_iospec(x, "out"))
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntryInfo {
                    name: name.clone(),
                    file: e.get("file")?.as_str()?.to_string(),
                    kind: e.get("kind")?.as_str()?.to_string(),
                    config: e.get("config")?.as_str()?.to_string(),
                    mode: e.opt("mode").and_then(|x| x.as_str().ok().map(String::from)),
                    method: e.opt("method").and_then(|x| x.as_str().ok().map(String::from)),
                    batch: e.opt("batch").and_then(|x| x.as_usize().ok()),
                    prompt_len: e.opt("prompt_len").and_then(|x| x.as_usize().ok()),
                    seq_len: e.opt("seq_len").and_then(|x| x.as_usize().ok()),
                    inputs,
                    outputs,
                },
            );
        }

        let mut params_files = BTreeMap::new();
        for (k, v) in j.get("params_files")?.as_obj()? {
            params_files.insert(k.clone(), v.as_str()?.to_string());
        }
        let mut trainable_files = BTreeMap::new();
        for (k, v) in j.get("trainable_files")?.as_obj()? {
            trainable_files.insert(k.clone(), v.as_str()?.to_string());
        }

        let mut golden = BTreeMap::new();
        for (k, g) in j.get("golden")?.as_obj()? {
            golden.insert(
                k.clone(),
                GoldenInfo {
                    entry: k.clone(),
                    in_file: g.get("in")?.as_str()?.to_string(),
                    out_file: g.get("out")?.as_str()?.to_string(),
                    outputs: g
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|x| parse_iospec(x, "out"))
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        let buckets = j.get("buckets")?;
        let serve_decode_batches = buckets
            .get("serve_decode_batches")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let serve_prefill_buckets = buckets
            .get("serve_prefill")?
            .as_arr()?
            .iter()
            .map(|x| {
                let a = x.as_arr().unwrap();
                (a[0].as_usize().unwrap(), a[1].as_usize().unwrap())
            })
            .collect();

        Ok(Manifest {
            dir,
            configs,
            entries,
            params_files,
            trainable_files,
            golden,
            serve_decode_batches,
            serve_prefill_buckets,
            synthetic: false,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryInfo> {
        self.entries.get(name).ok_or_else(|| anyhow!("no entry {name:?} in manifest"))
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfigInfo> {
        self.configs.get(name).ok_or_else(|| anyhow!("no config {name:?} in manifest"))
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// True when the default artifacts directory holds a manifest.
    /// Artifact-dependent integration tests and benches use this to skip
    /// cleanly (instead of erroring) when `make artifacts` has not run.
    pub fn available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    /// [`Manifest::available`], printing the canonical skip notice when
    /// artifacts are absent — the one message every gated test/bench shows.
    pub fn available_or_note() -> bool {
        let ok = Manifest::available();
        if !ok {
            eprintln!("skipped: AOT artifacts not found (run `make artifacts` first)");
        }
        ok
    }

    /// Default artifacts directory: $ROAD_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("ROAD_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // Walk up from cwd to find an `artifacts/manifest.json`.
            let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = d.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !d.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
    }
}

/// Skip the enclosing `#[test]` (early-return) when the AOT artifacts have
/// not been built, printing the canonical notice via
/// [`Manifest::available_or_note`].  Shared by every artifact-gated
/// integration test.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        if !$crate::Manifest::available_or_note() {
            return;
        }
    };
}
