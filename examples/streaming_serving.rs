//! Streaming serving: the `Generation` client API end to end — incremental
//! tokens (observed TTFT), explicit cancellation reclaiming a decode lane,
//! and a per-request deadline producing a typed error.
//!
//! ```bash
//! make artifacts && cargo run --release --example streaming_serving
//! ```
//!
//! For the wire-protocol flavor of the same thing, start
//! `road serve --listen 127.0.0.1:7433` and pipe NDJSON through `nc`
//! (README §Streaming quickstart).

use std::time::Duration;

use anyhow::Result;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::EngineConfig;
use road::coordinator::request::{Request, StreamEvent};
use road::coordinator::server::EngineServer;
use road::util::rng::Rng;

fn main() -> Result<()> {
    let econf = EngineConfig { decode_slots: 4, ..Default::default() };
    let (server, client) = EngineServer::start(econf, road::Manifest::default_dir(), |eng| {
        let mut rng = Rng::seed_from(11);
        for name in ["alice", "bob"] {
            let a = Adapter::Road(RoadAdapter::random(&eng.cfg, &mut rng, 0.2));
            eng.register_adapter(name, &a)?;
        }
        Ok(())
    })?;

    // 1. Stream a generation token by token: TTFT is something this caller
    //    *observes* (first Token event), not just a metric the engine logs.
    let req = Request::new(road::tokenizer::encode("hello"), 16).with_adapter("alice");
    let mut generation = client.submit(req)?;
    println!("request {} submitted; streaming:", generation.id());
    while let Some(ev) = generation.recv() {
        match ev {
            StreamEvent::Admitted { id } => println!("  admitted (id {id})"),
            StreamEvent::Token { token, pos, ttft_hint, .. } => match ttft_hint {
                Some(t) => println!("  token[{pos}] = {token}  (observed ttft {:.1}ms)", t * 1e3),
                None => println!("  token[{pos}] = {token}"),
            },
            StreamEvent::Finished(out) => {
                println!("  finished ({}): {:?}", out.finish.as_str(), out.tokens);
            }
            StreamEvent::Error { error, .. } => println!("  error: {error}"),
        }
    }

    // 2. Cancel mid-generation: the stream terminates with a Cancelled
    //    output carrying the tokens produced so far, and the decode lane is
    //    immediately reusable.
    let req = Request::new(road::tokenizer::encode("hello"), 64).with_adapter("bob");
    let mut generation = client.submit(req)?;
    let mut seen = 0;
    while let Some(ev) = generation.recv() {
        match ev {
            StreamEvent::Token { .. } => {
                seen += 1;
                if seen == 4 {
                    println!("cancelling request {} after {seen} tokens...", generation.id());
                    generation.cancel();
                }
            }
            StreamEvent::Finished(out) => {
                println!(
                    "cancelled request finished as {:?} with {} tokens",
                    out.finish.as_str(),
                    out.tokens.len()
                );
            }
            _ => {}
        }
    }

    // 3. Deadlines: a 1ms budget cannot cover a 64-token generation, so the
    //    request dies with a typed DeadlineExceeded instead of hogging a
    //    lane to completion.
    let req = Request::new(road::tokenizer::encode("hello"), 64)
        .with_deadline(Duration::from_millis(1));
    match client.submit(req)?.wait() {
        Ok(out) => println!("unexpectedly finished: {:?}", out.finish),
        Err(e) => println!("deadline request died with typed error: {e} (kind {})", e.kind()),
    }

    println!("\n{}", client.stats()?.report_table());
    server.shutdown()?;
    Ok(())
}
