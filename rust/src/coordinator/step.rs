//! Step assembly: the pure planning/packing half of the engine's mixed
//! scheduler iteration.
//!
//! A *mixed step* ([`super::engine::EngineConfig::prefill_chunk_tokens`])
//! spends one token budget across two kinds of work: every occupied decode
//! lane advances one token, and the remaining budget goes to
//! admitted-but-unfinished prefills in chunks.  This module owns the
//! shape-fixed input assembly for both halves ([`assemble_decode`],
//! [`assemble_chunk`]) and the chunk planner ([`plan_chunks`]) that decides
//! *whose* prompt tokens consume the leftover budget — ranked by the same
//! scheduling policy that ordered admission, so an EDF engine also
//! prioritizes the tightest-deadline prefill and fair-share counts
//! partially-prefilled lanes.
//!
//! Everything here is engine hot-path code: total (no panics), allocation
//! only for the returned vectors, and independent of clocks and I/O so the
//! planner is unit-testable in isolation.

use std::time::Instant;

use super::request::ActiveRequest;
use super::sched::PolicyKind;

/// Fixed-shape inputs for one decode step across all `b` slots: empty
/// lanes are masked by token/pos/id 0.
#[derive(Debug)]
pub struct DecodeInputs {
    pub token: Vec<i32>,
    pub pos: Vec<i32>,
    pub ids: Vec<i32>,
    /// Whether any lane is occupied (an all-empty step is skipped).
    pub any: bool,
}

/// Pack the decode-entry inputs from the current lane table.  A
/// prompt-feeding lane (`pos < prompt.len()`) feeds its own next prompt
/// token; a generating lane feeds its last sampled token.
pub fn assemble_decode(slots: &[Option<ActiveRequest>], b: usize) -> DecodeInputs {
    let mut token = vec![0i32; b];
    let mut pos = vec![0i32; b];
    let mut ids = vec![0i32; b];
    let mut any = false;
    for (s, slot) in slots.iter().enumerate().take(b) {
        let Some(ar) = slot.as_ref() else { continue };
        any = true;
        token[s] = if ar.pos < ar.req.prompt.len() {
            // Prompt-feeding lane (shared-prefix hit or chunked
            // admission): the unprefilled tail of its own prompt streams
            // through decode.
            ar.req.prompt.get(ar.pos).copied().unwrap_or_default()
        } else {
            // Prefill (or the feeding phase) pushes the first token
            // before normal decode, so `generated` is never empty here; a
            // zero fallback on a lost invariant decodes one garbage token
            // instead of killing the serving thread.
            ar.generated.last().copied().unwrap_or_default()
        };
        pos[s] = ar.pos as i32;
        ids[s] = ar.slot_adapter as i32;
    }
    DecodeInputs { token, pos, ids, any }
}

/// One partially-prefilled lane competing for the step's leftover token
/// budget — the policy-relevant facts only, so the planner stays decoupled
/// from the lane table.
#[derive(Clone, Debug)]
pub struct ChunkLane {
    pub slot: usize,
    /// Prompt tokens not yet in this lane's cache (`prompt.len() - pos`).
    pub remaining: usize,
    /// Absolute deadline, if any (the EDF key).
    pub deadline_at: Option<Instant>,
    /// Admission tier (the priority-policy key).
    pub priority: u8,
    /// Occupied lanes wearing the same adapter — the fair-share load
    /// signal; partially-prefilled lanes count like any other.
    pub in_flight_same_adapter: usize,
    /// Engine-issued request id (the FCFS key and the deterministic
    /// tie-break everywhere: ids are issued in submit order).
    pub id: u64,
}

/// Budget tokens granted to one lane this step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkAssign {
    pub slot: usize,
    pub n: usize,
}

/// Split `budget` prompt tokens across the feeding lanes, greedily in
/// policy-rank order: the best-ranked lane takes as much of its remaining
/// prompt as the budget covers, then the next, until the budget is spent.
/// Greedy (rather than round-robin) allocation finishes the most urgent
/// prefill soonest — exactly the policy's intent — while the decode-fed
/// token every feeding lane gets per step guarantees the others still
/// progress.
pub fn plan_chunks(lanes: &[ChunkLane], budget: usize, policy: PolicyKind) -> Vec<ChunkAssign> {
    let mut ranked: Vec<&ChunkLane> = lanes.iter().collect();
    match policy {
        PolicyKind::Fcfs => ranked.sort_by_key(|l| l.id),
        // Deadline-less lanes rank last, ids break ties deterministically.
        PolicyKind::Edf => ranked.sort_by_key(|l| (l.deadline_at.is_none(), l.deadline_at, l.id)),
        PolicyKind::Priority => ranked.sort_by_key(|l| (std::cmp::Reverse(l.priority), l.id)),
        PolicyKind::FairShare => ranked.sort_by_key(|l| (l.in_flight_same_adapter, l.id)),
    }
    let mut left = budget;
    let mut out = Vec::new();
    for lane in ranked {
        if left == 0 {
            break;
        }
        let n = lane.remaining.min(left);
        if n == 0 {
            continue;
        }
        left -= n;
        out.push(ChunkAssign { slot: lane.slot, n });
    }
    out
}

/// Fixed-shape inputs for one chunked-prefill call across all `b` slots:
/// `tokens` is `[b, max_seq]` with each granted lane's chunk written at
/// its absolute prompt positions, `start`/`len` delimit the chunk per
/// lane (`len == 0` masks a lane out entirely).
#[derive(Debug)]
pub struct ChunkInputs {
    pub ids: Vec<i32>,
    pub tokens: Vec<i32>,
    pub start: Vec<i32>,
    pub len: Vec<i32>,
}

/// Pack the chunk-entry inputs for the granted assignments.  Assignments
/// whose slot emptied since planning (impossible within one step, but the
/// packer stays total) are masked out with `len == 0`.
pub fn assemble_chunk(
    slots: &[Option<ActiveRequest>],
    b: usize,
    max_seq: usize,
    assigns: &[ChunkAssign],
) -> ChunkInputs {
    let mut ids = vec![0i32; b];
    let mut tokens = vec![0i32; b * max_seq];
    let mut start = vec![0i32; b];
    let mut len = vec![0i32; b];
    for a in assigns {
        let Some(ar) = slots.get(a.slot).and_then(|s| s.as_ref()) else { continue };
        if a.slot >= b {
            continue;
        }
        let s0 = ar.pos;
        let n = a.n.min(ar.req.prompt.len().saturating_sub(s0)).min(max_seq.saturating_sub(s0));
        if n == 0 {
            continue;
        }
        ids[a.slot] = ar.slot_adapter as i32;
        start[a.slot] = s0 as i32;
        len[a.slot] = n as i32;
        for i in 0..n {
            tokens[a.slot * max_seq + s0 + i] = ar.req.prompt.get(s0 + i).copied().unwrap_or_default();
        }
    }
    ChunkInputs { ids, tokens, start, len }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn lane(slot: usize, remaining: usize, id: u64) -> ChunkLane {
        ChunkLane {
            slot,
            remaining,
            deadline_at: None,
            priority: 0,
            in_flight_same_adapter: 0,
            id,
        }
    }

    #[test]
    fn plan_is_greedy_in_rank_order_and_respects_budget() {
        let lanes = vec![lane(0, 10, 2), lane(1, 4, 1), lane(2, 3, 3)];
        let plan = plan_chunks(&lanes, 8, PolicyKind::Fcfs);
        // FCFS ranks by id: lane 1 (id 1) drains fully, lane 0 (id 2)
        // takes the remaining 4, lane 2 gets nothing.
        assert_eq!(plan, vec![ChunkAssign { slot: 1, n: 4 }, ChunkAssign { slot: 0, n: 4 }]);
        let total: usize = plan.iter().map(|a| a.n).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn plan_edf_prefers_tightest_deadline_and_ranks_deadline_less_last() {
        let t0 = Instant::now();
        let mut a = lane(0, 5, 1);
        let mut b = lane(1, 5, 2);
        let c = lane(2, 5, 3); // no deadline
        a.deadline_at = Some(t0 + Duration::from_millis(50));
        b.deadline_at = Some(t0 + Duration::from_millis(10));
        let plan = plan_chunks(&[a, b, c], 12, PolicyKind::Edf);
        assert_eq!(
            plan,
            vec![
                ChunkAssign { slot: 1, n: 5 },
                ChunkAssign { slot: 0, n: 5 },
                ChunkAssign { slot: 2, n: 2 },
            ]
        );
    }

    #[test]
    fn plan_priority_and_fair_share_keys() {
        let mut hi = lane(0, 4, 9);
        hi.priority = 3;
        let lo = lane(1, 4, 1);
        let plan = plan_chunks(&[hi, lo], 4, PolicyKind::Priority);
        assert_eq!(plan, vec![ChunkAssign { slot: 0, n: 4 }]);

        let mut crowded = lane(0, 4, 1);
        crowded.in_flight_same_adapter = 3;
        let alone = lane(1, 4, 2);
        let plan = plan_chunks(&[crowded, alone], 4, PolicyKind::FairShare);
        assert_eq!(plan, vec![ChunkAssign { slot: 1, n: 4 }], "least-loaded adapter first");
    }

    #[test]
    fn plan_zero_budget_or_no_lanes_is_empty() {
        assert!(plan_chunks(&[lane(0, 5, 1)], 0, PolicyKind::Fcfs).is_empty());
        assert!(plan_chunks(&[], 7, PolicyKind::Fcfs).is_empty());
    }
}
