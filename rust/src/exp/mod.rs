//! Experiment drivers: one function per paper table/figure, producing the
//! markdown rows the paper reports (EXPERIMENTS.md records the runs).
//!
//! * Table 2  — NLU suite, one model per task per method, 3 seeds.
//! * Table 3  — commonsense suite, one unified model per method.
//! * Table 4  — arithmetic suite, Math10K-analogue training mix.
//! * Table 5  — instruction following, LL-judge win rate.
//! * Table 6  — multimodal suite.
//! * Table D.2 — commonsense on the second backbone (train2).
//! * Figure 1 — quality-vs-#params summary assembled from the above.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::engine::{Engine, EngineConfig};
use crate::runtime::Runtime;
use crate::tasks::{self, Metric, SuiteSampler, Task, TaskSampler};
use crate::trainer::{self, Recipe, Trainer};
use crate::util::stats;
use crate::util::table::{fmt_f, Table};

/// Common experiment options.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub steps: usize,
    pub seeds: Vec<u64>,
    pub n_eval: usize,
    pub verbose: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { steps: 200, seeds: vec![0, 1, 2], n_eval: 256, verbose: false }
    }
}

/// One method's row: per-task mean scores (+ std over seeds) and average.
#[derive(Clone, Debug)]
pub struct MethodRow {
    pub method: String,
    pub n_trainable: usize,
    pub pct_params: f64,
    pub scores: Vec<f64>,
    pub stds: Vec<f64>,
    pub avg: f64,
}

fn pct(n_trainable: usize, rt: &Rc<Runtime>, config: &str) -> f64 {
    let total = crate::model::ParamStore::load(&rt.manifest, config)
        .map(|p| p.n_params())
        .unwrap_or(1);
    100.0 * n_trainable as f64 / total as f64
}

fn render_rows(title: &str, task_names: &[String], rows: &[MethodRow]) -> String {
    let mut headers: Vec<&str> = vec!["method", "#params", "%params"];
    let names: Vec<&str> = task_names.iter().map(|s| s.as_str()).collect();
    headers.extend(names);
    headers.push("avg");
    let mut t = Table::new(&headers);
    for r in rows {
        let mut cells = vec![
            r.method.clone(),
            r.n_trainable.to_string(),
            format!("{:.3}%", r.pct_params),
        ];
        for (s, sd) in r.scores.iter().zip(&r.stds) {
            if r.stds.iter().any(|&x| x > 0.0) {
                cells.push(format!("{:.1}±{:.1}", 100.0 * s, 100.0 * sd));
            } else {
                cells.push(format!("{:.1}", 100.0 * s));
            }
        }
        cells.push(format!("{:.1}", 100.0 * r.avg));
        t.row(cells);
    }
    format!("## {title}\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Table 2: NLU (one model per task)
// ---------------------------------------------------------------------------

pub const NLU_METHODS: &[&str] =
    &["full", "lora", "bitfit", "ia3", "oft2", "road1", "road1_fc1"];

/// Train + evaluate one (method, task, seed) cell of Table 2.
pub fn nlu_cell(
    rt: &Rc<Runtime>,
    config: &str,
    method: &str,
    task: &dyn Task,
    steps: usize,
    n_eval: usize,
    seed: u64,
) -> Result<f64> {
    let mut tr = Trainer::new(rt.clone(), config, method)?;
    let recipe =
        Recipe::default().with_lr(Recipe::default_lr(method)).with_steps(steps).with_seed(seed);
    let mut src = TaskSampler { task, batch: tr.batch, seq_len: tr.seq_len };
    trainer::train(&mut tr, &recipe, &mut src, None)?;
    let eval = tasks::eval_classification(&tr, task, n_eval, seed ^ 0x7e57)?;
    Ok(eval.score)
}

pub fn run_nlu(
    rt: &Rc<Runtime>,
    config: &str,
    methods: &[&str],
    opts: &ExpOptions,
) -> Result<(Vec<String>, Vec<MethodRow>)> {
    let suite = tasks::nlu_suite();
    let task_names: Vec<String> = suite.iter().map(|t| t.name().to_string()).collect();
    let mut rows = Vec::new();
    for &method in methods {
        let mut scores = Vec::new();
        let mut stds = Vec::new();
        let mut n_trainable = 0usize;
        for task in &suite {
            let mut per_seed = Vec::new();
            for &seed in &opts.seeds {
                let mut tr = Trainer::new(rt.clone(), config, method)?;
                n_trainable = tr.n_trainable;
                let recipe = Recipe::default()
                    .with_lr(Recipe::default_lr(method))
                    .with_steps(opts.steps)
                    .with_seed(seed);
                let mut src =
                    TaskSampler { task: task.as_ref(), batch: tr.batch, seq_len: tr.seq_len };
                trainer::train(&mut tr, &recipe, &mut src, None)?;
                let ev = tasks::eval_classification(&tr, task.as_ref(), opts.n_eval, seed ^ 0x7e57)?;
                per_seed.push(ev.score);
            }
            scores.push(stats::mean(&per_seed));
            stds.push(stats::std(&per_seed));
            if opts.verbose {
                println!(
                    "  [nlu] {method:<10} {:<10} {:.3}",
                    task.name(),
                    scores.last().unwrap()
                );
            }
        }
        let avg = stats::mean(&scores);
        rows.push(MethodRow {
            method: method.to_string(),
            n_trainable,
            pct_params: pct(n_trainable, rt, config),
            scores,
            stds,
            avg,
        });
    }
    Ok((task_names, rows))
}

// ---------------------------------------------------------------------------
// Table 3 / D.2: commonsense (one unified model per method)
// ---------------------------------------------------------------------------

pub const COMMONSENSE_METHODS: &[&str] = &["lora", "ia3", "oft2", "road1", "road2", "road4"];
pub const TRAIN2_METHODS: &[&str] = &["lora", "road1", "road2", "road4"];

pub fn run_commonsense(
    rt: &Rc<Runtime>,
    config: &str,
    methods: &[&str],
    opts: &ExpOptions,
) -> Result<(Vec<String>, Vec<MethodRow>)> {
    let suite = tasks::commonsense_suite();
    let task_names: Vec<String> = suite.iter().map(|t| t.name().to_string()).collect();
    let mut rows = Vec::new();
    for &method in methods {
        let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); suite.len()];
        let mut n_trainable = 0usize;
        for &seed in &opts.seeds {
            let mut tr = Trainer::new(rt.clone(), config, method)?;
            n_trainable = tr.n_trainable;
            let recipe = Recipe::default()
                .with_lr(Recipe::default_lr(method))
                .with_steps(opts.steps)
                .with_seed(seed);
            let mut src = SuiteSampler::new(&suite, tr.batch, tr.seq_len);
            trainer::train(&mut tr, &recipe, &mut src, None)?;
            for (i, task) in suite.iter().enumerate() {
                let ev =
                    tasks::eval_choice_accuracy(&tr, task.as_ref(), opts.n_eval, seed ^ 0x7e57)?;
                per_task[i].push(ev.score);
                if opts.verbose {
                    println!("  [cs] {method:<8} {:<14} {:.3}", task.name(), ev.score);
                }
            }
        }
        let scores: Vec<f64> = per_task.iter().map(|v| stats::mean(v)).collect();
        let stds: Vec<f64> = per_task.iter().map(|v| stats::std(v)).collect();
        let avg = stats::mean(&scores);
        rows.push(MethodRow {
            method: method.to_string(),
            n_trainable,
            pct_params: pct(n_trainable, rt, config),
            scores,
            stds,
            avg,
        });
    }
    Ok((task_names, rows))
}

// ---------------------------------------------------------------------------
// Table 4: arithmetic (generative exact match through the engine)
// ---------------------------------------------------------------------------

pub const ARITHMETIC_METHODS: &[&str] = &["lora", "ia3", "road1", "road2", "road4"];

/// Serving mode for a trained method's generative eval.
fn gen_mode(method: &str) -> Result<&'static str> {
    Ok(match method {
        m if m.starts_with("road") => "road",
        "lora" => "lora",
        "ia3" => "ia3",
        "full" | "bitfit" => "base",
        m => bail!("no generative serving path for method {m}"),
    })
}

/// Build a generation engine for a trained model: adapter-bank modes carry
/// the exported adapter; merged methods serve through `base`.
pub fn gen_engine(rt: &Rc<Runtime>, config: &str, tr: &Trainer) -> Result<(Engine, Option<String>)> {
    let mode = gen_mode(&tr.method)?;
    let econf = EngineConfig {
        model: config.into(),
        mode: mode.into(),
        decode_slots: 8,
        queue_capacity: 4096,
        ..Default::default()
    };
    if mode == "base" {
        let params = tr.merged_params()?;
        let engine = Engine::with_params(rt.clone(), econf, params)?;
        Ok((engine, None))
    } else {
        let mut engine = Engine::new(rt.clone(), econf)?;
        let adapter = tr.export_adapter()?;
        engine.register_adapter("trained", &adapter)?;
        Ok((engine, Some("trained".to_string())))
    }
}

pub fn run_arithmetic(
    rt: &Rc<Runtime>,
    config: &str,
    methods: &[&str],
    opts: &ExpOptions,
) -> Result<(Vec<String>, Vec<MethodRow>)> {
    let train_suite = tasks::arithmetic_train_suite();
    let eval_suite = tasks::arithmetic_eval_suite();
    let task_names: Vec<String> = eval_suite.iter().map(|t| t.name().to_string()).collect();
    let mut rows = Vec::new();
    for &method in methods {
        let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); eval_suite.len()];
        let mut n_trainable = 0usize;
        for &seed in &opts.seeds {
            let mut tr = Trainer::new(rt.clone(), config, method)?;
            n_trainable = tr.n_trainable;
            let recipe = Recipe::default()
                .with_lr(Recipe::default_lr(method))
                .with_steps(opts.steps)
                .with_seed(seed);
            let mut src = SuiteSampler::new(&train_suite, tr.batch, tr.seq_len);
            trainer::train(&mut tr, &recipe, &mut src, None)?;

            let (mut engine, adapter) = gen_engine(rt, config, &tr)?;
            for (i, task) in eval_suite.iter().enumerate() {
                let score = match task.metric() {
                    Metric::ExactMatch => {
                        tasks::eval_exact_match(
                            &mut engine,
                            adapter.as_deref(),
                            task.as_ref(),
                            opts.n_eval.min(64),
                            seed ^ 0x7e57,
                        )?
                        .score
                    }
                    // AQuA analogue: choice accuracy by NLL scoring.
                    _ => {
                        tasks::eval_choice_accuracy(
                            &tr,
                            task.as_ref(),
                            opts.n_eval,
                            seed ^ 0x7e57,
                        )?
                        .score
                    }
                };
                per_task[i].push(score);
                if opts.verbose {
                    println!("  [arith] {method:<8} {:<10} {:.3}", task.name(), score);
                }
            }
        }
        let scores: Vec<f64> = per_task.iter().map(|v| stats::mean(v)).collect();
        let stds: Vec<f64> = per_task.iter().map(|v| stats::std(v)).collect();
        let avg = stats::mean(&scores);
        rows.push(MethodRow {
            method: method.to_string(),
            n_trainable,
            pct_params: pct(n_trainable, rt, config),
            scores,
            stds,
            avg,
        });
    }
    Ok((task_names, rows))
}

// ---------------------------------------------------------------------------
// Table 5: instruction following (win rate vs base model)
// ---------------------------------------------------------------------------

pub const INSTRUCT_METHODS: &[&str] = &["lora", "road1"];

pub fn run_instruct(
    rt: &Rc<Runtime>,
    config: &str,
    methods: &[&str],
    opts: &ExpOptions,
) -> Result<String> {
    let suites: Vec<(&str, Vec<Box<dyn Task>>)> = vec![
        ("alpaca-x", tasks::instruct_suite()),
        ("ultra-x", vec![Box::new(tasks::instruct::UltraX) as Box<dyn Task>]),
    ];
    let mut t = Table::new(&["method", "#params", "%params", "data", "win rate (%)"]);
    for (data_name, suite) in &suites {
        for &method in methods {
            let mut wins = Vec::new();
            let mut n_trainable = 0usize;
            for &seed in &opts.seeds {
                let mut tr = Trainer::new(rt.clone(), config, method)?;
                n_trainable = tr.n_trainable;
                let reference = Trainer::new(rt.clone(), config, method)?; // identity init
                let recipe = Recipe::default()
                    .with_lr(Recipe::default_lr(method))
                    .with_steps(opts.steps)
                    .with_seed(seed);
                let mut src = SuiteSampler::new(suite, tr.batch, tr.seq_len);
                trainer::train(&mut tr, &recipe, &mut src, None)?;
                // Win rate on the suite's first task distribution (held-out
                // seed), mirroring single-benchmark scoring.
                let ev = tasks::eval_win_rate(
                    &tr,
                    &reference,
                    suite[0].as_ref(),
                    opts.n_eval,
                    seed ^ 0x7e57,
                )?;
                wins.push(ev.score);
            }
            t.row(vec![
                method.to_string(),
                n_trainable.to_string(),
                format!("{:.3}%", pct(n_trainable, rt, config)),
                data_name.to_string(),
                format!("{:.2}", 100.0 * stats::mean(&wins)),
            ]);
        }
    }
    Ok(format!("## Table 5 analogue: instruction following (LL-judge)\n{}", t.render()))
}

// ---------------------------------------------------------------------------
// Table 6: multimodal
// ---------------------------------------------------------------------------

pub const MULTIMODAL_METHODS: &[&str] = &["lora", "road4", "road1"];

pub fn run_multimodal(
    rt: &Rc<Runtime>,
    config: &str,
    methods: &[&str],
    opts: &ExpOptions,
) -> Result<(Vec<String>, Vec<MethodRow>)> {
    let suite = tasks::multimodal_suite();
    let task_names: Vec<String> = suite.iter().map(|t| t.name().to_string()).collect();
    let mut rows = Vec::new();
    for &method in methods {
        let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); suite.len()];
        let mut n_trainable = 0usize;
        for &seed in &opts.seeds {
            let mut tr = Trainer::new(rt.clone(), config, method)?;
            n_trainable = tr.n_trainable;
            let recipe = Recipe::default()
                .with_lr(Recipe::default_lr(method))
                .with_steps(opts.steps)
                .with_seed(seed);
            let mut src = SuiteSampler::new(&suite, tr.batch, tr.seq_len);
            trainer::train(&mut tr, &recipe, &mut src, None)?;
            for (i, task) in suite.iter().enumerate() {
                let ev =
                    tasks::eval_classification(&tr, task.as_ref(), opts.n_eval, seed ^ 0x7e57)?;
                per_task[i].push(ev.score);
            }
        }
        let scores: Vec<f64> = per_task.iter().map(|v| stats::mean(v)).collect();
        let stds: Vec<f64> = per_task.iter().map(|v| stats::std(v)).collect();
        let avg = stats::mean(&scores);
        rows.push(MethodRow {
            method: method.to_string(),
            n_trainable,
            pct_params: pct(n_trainable, rt, config),
            scores,
            stds,
            avg,
        });
    }
    Ok((task_names, rows))
}

// ---------------------------------------------------------------------------
// Figure 1: quality vs #params summary
// ---------------------------------------------------------------------------

pub fn fig1_summary(
    nlu: &[MethodRow],
    commonsense: &[MethodRow],
    arithmetic: &[MethodRow],
) -> String {
    let mut t = Table::new(&["suite", "method", "%params", "avg score"]);
    for (suite, rows) in
        [("nlu", nlu), ("commonsense", commonsense), ("arithmetic", arithmetic)]
    {
        for r in rows {
            t.row(vec![
                suite.to_string(),
                r.method.clone(),
                format!("{:.3}%", r.pct_params),
                fmt_f(100.0 * r.avg, 1),
            ]);
        }
    }
    format!("## Figure 1 analogue: quality vs trainable parameters\n{}", t.render())
}

/// Render a (task_names, rows) pair as the paper-style markdown table.
pub fn render_table(title: &str, task_names: &[String], rows: &[MethodRow]) -> String {
    render_rows(title, task_names, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_includes_methods_and_avg() {
        let rows = vec![MethodRow {
            method: "road1".into(),
            n_trainable: 4224,
            pct_params: 0.59,
            scores: vec![0.9, 0.8],
            stds: vec![0.0, 0.0],
            avg: 0.85,
        }];
        let s = render_table("Table X", &["a".into(), "b".into()], &rows);
        assert!(s.contains("road1"));
        assert!(s.contains("85.0"));
    }

    #[test]
    fn gen_mode_covers_methods() {
        assert_eq!(gen_mode("road2").unwrap(), "road");
        assert_eq!(gen_mode("full").unwrap(), "base");
        assert!(gen_mode("oft2").is_err());
    }
}
