//! End-to-end numerics: HLO artifacts produced by python/compile/aot.py,
//! loaded and executed through the rust PJRT runtime, compared against the
//! golden records computed by jax at artifact-build time.
//!
//! The golden-record tests are artifact-gated (PJRT numerics are their
//! point; without `make artifacts` they skip cleanly).  The reference
//! backend's runtime-level contract — same entry names, same `Arg`
//! conventions, run/run_device agreement, bitwise determinism — runs
//! unconditionally below them.

use road::runtime::{allclose, buffer_to_host, Arg, BackendKind, Runtime};
use road::require_artifacts;

fn runtime() -> Runtime {
    Runtime::from_default_artifacts().expect("run `make artifacts` first")
}

#[test]
fn golden_decode_road() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_road_tiny_b2").unwrap();
    let exe = rt.load("decode_road_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    assert_eq!(outs.len(), expected.len());
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

/// `run_device` must agree with `run`: same entry, same inputs, device
/// outputs downloaded afterwards equal the host outputs (and the golden
/// record).  This is the runtime-level contract the device-resident decode
/// loop depends on.
#[test]
fn golden_decode_device_outputs_match_host_outputs() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_road_tiny_b2").unwrap();
    let exe = rt.load("decode_road_tiny_b2").unwrap();

    // Mixed-residency call: upload the K/V cache inputs once and pass them
    // as persistent buffers, exactly like the engine's decode loop.
    let is_cache = |name: &str| name == "k_cache" || name == "v_cache";
    let mut bufs = Vec::new();
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            bufs.push(rt.upload(t).unwrap());
        }
    }
    let mut args: Vec<Arg> = Vec::new();
    let mut bi = 0;
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            args.push(Arg::Buffer(&bufs[bi]));
            bi += 1;
        } else {
            args.push(Arg::Host(t));
        }
    }

    let dev_outs = exe.run_device(&args).unwrap();
    assert_eq!(dev_outs.len(), expected.len());
    for ((buf, spec), e) in dev_outs.iter().zip(&exe.info.outputs).zip(&expected) {
        let host = buffer_to_host(buf, spec.dtype).unwrap();
        allclose(&host, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_decode_base() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_base_tiny_b2").unwrap();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_prefill_road() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("prefill_road_tiny_b2_l16").unwrap();
    let exe = rt.load("prefill_road_tiny_b2_l16").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_train_step_road1() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("train_road1_tiny").unwrap();
    let exe = rt.load("train_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    // train outputs include the loss scalar as the last element
    let loss = outs.last().unwrap().as_f32()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 2e-3, 1e-4).unwrap();
    }
}

#[test]
fn golden_eval_loss_road1() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("eval_loss_road1_tiny").unwrap();
    let exe = rt.load("eval_loss_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-3, 1e-5).unwrap();
    }
}

#[test]
fn executable_rejects_wrong_arity_and_shape() {
    require_artifacts!();
    let rt = runtime();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    assert!(exe.run_host(&[]).is_err());
    let (mut ins, _) = rt.load_golden("decode_base_tiny_b2").unwrap();
    // corrupt a shape
    let bad = road::HostTensor::f32(vec![1], vec![0.0]);
    ins[0] = bad;
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    assert!(exe.run_host(&refs).is_err());
}

// ---------------------------------------------------------------------------
// Reference backend: runtime-level contract, no artifacts required
// ---------------------------------------------------------------------------

/// Build the full positional input list for a reference serving entry:
/// real params from the (synthetic) store, identity adapter banks, plus
/// the caller's data tensors.
fn reference_inputs(
    rt: &Runtime,
    entry: &str,
    data: &std::collections::BTreeMap<&str, road::HostTensor>,
) -> Vec<road::HostTensor> {
    let info = rt.manifest.entry(entry).unwrap();
    let store = road::model::ParamStore::load_pretrained(&rt.manifest, &info.config).unwrap();
    info.inputs
        .iter()
        .map(|s| match s.group.as_str() {
            "params" => store.get(&s.name).unwrap().clone(),
            "adapters" => road::runtime::reference::identity_bank_tensor(s),
            _ => data[s.name.as_str()].clone(),
        })
        .collect()
}

fn tiny_decode_data(
    rt: &Runtime,
) -> std::collections::BTreeMap<&'static str, road::HostTensor> {
    let cfg = rt.manifest.config("tiny").unwrap();
    let cache = vec![cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim];
    let n: usize = cache.iter().product();
    let mut rng = road::util::rng::Rng::seed_from(41);
    std::collections::BTreeMap::from([
        ("ids", road::HostTensor::i32(vec![2], vec![0, 1])),
        ("token", road::HostTensor::i32(vec![2], vec![11, 222])),
        ("pos", road::HostTensor::i32(vec![2], vec![4, 7])),
        ("k_cache", road::HostTensor::f32(cache.clone(), rng.normal_vec(n, 0.02))),
        ("v_cache", road::HostTensor::f32(cache, rng.normal_vec(n, 0.02))),
    ])
}

#[test]
fn reference_runtime_loads_serving_entries_without_artifacts() {
    let rt = Runtime::reference();
    assert_eq!(rt.backend, BackendKind::Reference);
    assert!(rt.manifest.synthetic);
    for cfg in ["tiny", "serve", "train", "train2"] {
        assert!(rt.manifest.configs.contains_key(cfg));
    }
    // Same naming scheme as the artifact manifest.
    for b in &rt.manifest.serve_decode_batches {
        for mode in ["base", "road", "lora"] {
            let name = format!("decode_{mode}_serve_b{b}");
            assert!(rt.manifest.entries.contains_key(&name), "{name}");
            rt.load(&name).unwrap();
        }
    }
    // Non-serving kinds fail loudly instead of silently mis-executing.
    assert!(rt.manifest.entries.values().all(|e| e.kind == "prefill" || e.kind == "decode"));
}

/// `run` and `run_device` agree on the reference backend, with the same
/// mixed host/buffer calling convention the engine's decode loop uses —
/// and two identical calls are bitwise identical.
#[test]
fn reference_run_device_matches_run_and_is_deterministic() {
    let rt = Runtime::reference();
    let exe = rt.load("decode_road_tiny_b2").unwrap();
    let data = tiny_decode_data(&rt);
    let ins = reference_inputs(&rt, "decode_road_tiny_b2", &data);

    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let host_outs = exe.run_host(&refs).unwrap();
    assert_eq!(host_outs.len(), 3);

    // Mixed-residency call: caches as persistent buffers, rest as host
    // args (the engine's device-resident decode convention).
    let is_cache = |name: &str| name == "k_cache" || name == "v_cache";
    let mut bufs = Vec::new();
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            bufs.push(rt.upload(t).unwrap());
        }
    }
    let mut args: Vec<Arg> = Vec::new();
    let mut bi = 0;
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            args.push(Arg::Buffer(&bufs[bi]));
            bi += 1;
        } else {
            args.push(Arg::Host(t));
        }
    }
    let dev_outs = exe.run_device(&args).unwrap();
    assert_eq!(dev_outs.len(), host_outs.len());
    for ((buf, spec), host) in dev_outs.iter().zip(&exe.info.outputs).zip(&host_outs) {
        let back = buffer_to_host(buf, spec.dtype).unwrap();
        assert_eq!(back.shape, host.shape);
        assert_eq!(back.bytes(), host.bytes(), "run_device diverged from run");
    }
    let again = exe.run_host(&refs).unwrap();
    for (a, b) in again.iter().zip(&host_outs) {
        assert_eq!(a.bytes(), b.bytes(), "reference execution must be bitwise deterministic");
    }
}

/// Identity adapter banks are numeric no-ops at the runtime level: road
/// and ia3 decode logits equal the base entry's bit for bit (lora's zero
/// bank adds an exact zero delta).
#[test]
fn reference_identity_banks_match_base_entry() {
    let rt = Runtime::reference();
    let data = tiny_decode_data(&rt);
    let base_ins = reference_inputs(&rt, "decode_base_tiny_b2", &data);
    let base_refs: Vec<&road::HostTensor> = base_ins.iter().collect();
    let base = rt.load("decode_base_tiny_b2").unwrap().run_host(&base_refs).unwrap();
    for mode in ["road", "ia3", "lora"] {
        let name = format!("decode_{mode}_tiny_b2");
        let ins = reference_inputs(&rt, &name, &data);
        let refs: Vec<&road::HostTensor> = ins.iter().collect();
        let outs = rt.load(&name).unwrap().run_host(&refs).unwrap();
        allclose(&outs[0], &base[0], 0.0, 1e-6)
            .unwrap_or_else(|e| panic!("identity {mode} logits diverged from base: {e}"));
    }
}

/// Shape/arity validation applies on the reference backend exactly like
/// the PJRT path.
#[test]
fn reference_executable_rejects_wrong_arity_and_shape() {
    let rt = Runtime::reference();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    assert!(exe.run_host(&[]).is_err());
    let data = tiny_decode_data(&rt);
    let mut ins = reference_inputs(&rt, "decode_base_tiny_b2", &data);
    ins[0] = road::HostTensor::f32(vec![1], vec![0.0]);
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    assert!(exe.run_host(&refs).is_err());
}

#[test]
fn manifest_loads_and_entries_consistent() {
    require_artifacts!();
    let rt = runtime();
    assert!(rt.manifest.entries.len() >= 90, "{}", rt.manifest.entries.len());
    for cfg in ["tiny", "serve", "train", "train2"] {
        assert!(rt.manifest.configs.contains_key(cfg));
    }
    // decode buckets advertised by the manifest exist as entries
    for b in &rt.manifest.serve_decode_batches {
        for mode in ["base", "road", "lora"] {
            let name = format!("decode_{mode}_serve_b{b}");
            assert!(rt.manifest.entries.contains_key(&name), "{name}");
        }
    }
}
