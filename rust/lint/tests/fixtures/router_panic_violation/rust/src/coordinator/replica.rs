pub fn advance(state: u8) {
    if state > 3 {
        panic!("invalid lifecycle transition");
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        Some(1usize).unwrap();
    }
}
