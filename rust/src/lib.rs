//! # road — 2D Rotary Adaptation, reproduced as a serving + finetuning stack
//!
//! Reproduction of *"3-in-1: 2D Rotary Adaptation for Efficient Finetuning,
//! Efficient Batching and Composability"* (Liao & Monz, NeurIPS 2024) as a
//! three-layer system:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the Eq.-4
//!   element-wise RoAd apply and the batched-LoRA bmm baseline.
//! * **Layer 2** — JAX model + training graphs (`python/compile/`), AOT
//!   lowered to HLO text artifacts.
//! * **Layer 3** — this crate: a rust coordinator that loads the artifacts
//!   through PJRT and runs multi-adapter serving (continuous batching over
//!   decode slots, per-request adapters), PEFT training loops, the paper's
//!   pilot studies, and the composability experiment.  Python never runs on
//!   the request path.
//!
//! Entry points: [`runtime::Runtime`] (PJRT), [`coordinator::Engine`]
//! (serving), [`trainer::Trainer`] (finetuning), [`tasks`] (synthetic
//! benchmark suites), [`bench`] (Figure-4 workloads).

pub mod adapters;
pub mod bench;
pub mod compose;
pub mod coordinator;
pub mod exp;
pub mod manifest;
pub mod model;
pub mod pilot;
pub mod runtime;
pub mod tasks;
pub mod tensor;
pub mod tokenizer;
pub mod trainer;
pub mod util;

pub use manifest::Manifest;
pub use runtime::Runtime;
pub use tensor::{DType, HostTensor};
