//! The serving engine: continuous batching over fixed decode slots with
//! per-request adapters — the paper's heterogeneous-batching scenario
//! (§2.2/§4.2) as a running system.
//!
//! One engine owns one PJRT runtime (single-threaded by construction — the
//! xla client is `Rc`-based); the [`super::server::EngineServer`] wraps it
//! in a dedicated thread behind mpsc channels.
//!
//! Iteration structure (vLLM-style, iteration-level scheduling):
//!   1. admit waiting requests into free slots via a bucketed prefill
//!      (fixed-shape executables; prompts padded to the bucket),
//!   2. run ONE decode step across all slots (active lanes advance, empty
//!      lanes are masked by pos/id 0),
//!   3. sample, detect finished requests, free their slots.
//!
//! The decode loop is device-resident: the K/V cache lives in PJRT buffers
//! and each step's cache outputs are fed back as the next step's inputs
//! ([`crate::runtime::Executable::run_device`]); only the logits are
//! downloaded per step.  `EngineConfig::kv_host_roundtrip` re-enables the
//! old full-cache host round-trip as a measurable baseline.
//!
//! Adapters are virtualized: registration lands in an unbounded host
//! [`crate::adapters::AdapterStore`], and admission pages a request's
//! adapter into the device bank (an LRU slot cache) before the request
//! enters a prefill batch.  Slots referenced by in-flight lanes are pinned
//! so eviction can never corrupt an active request; when every pageable
//! slot is pinned, the request simply stays queued.  Bank uploads move
//! only dirty slot rows (`EngineConfig::paged_bank_uploads` flips the
//! whole-bank re-upload baseline back on for comparison).
//!
//! KV memory is block-granular ([`super::kv::PagedKv`],
//! `EngineConfig::paged_kv`): admission reserves only a request's
//! generation footprint from a shared block pool, prompts that share a
//! cached prefix skip that much prefill work (their lanes start in
//! prompt-feeding state and stream the uncached tail through decode
//! steps), and cold prefills publish their prompt blocks for later
//! requests.  Shared blocks are refcounted and copy-on-write by
//! construction; unreferenced cached blocks are evicted LRU-first under
//! pressure.  `--paged-kv=false` restores the flat baseline where every
//! lane charges a full `max_seq` footprint.
//!
//! Admission order is policy-driven ([`super::sched`]): every scheduler
//! iteration ranks the queue through `EngineConfig::policy` (FCFS / EDF /
//! priority tiers / fair-share) before popping, and every timestamp the
//! engine takes goes through `EngineConfig::clock`, so the whole temporal
//! surface — deadline sheds, TTFT, queue waits — runs deterministically
//! on a manual clock (docs/DESIGN.md §Scheduling).

use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::adapters::{Adapter, AdapterBank, AdapterRegistry, PageOutcome};
use crate::manifest::{EntryInfo, ModelConfigInfo};
use crate::model::ParamStore;
use crate::runtime::{buffer_to_host, Arg, BackendKind, Executable, Runtime};
use crate::tensor::{DType, HostTensor};
use crate::util::clock::Clock;

use super::kv::{KvReservation, KvState, PagedKv, SlotAllocator};
use super::metrics::Metrics;
use super::queue::{AdmissionQueue, EngineError};
use super::request::{ActiveRequest, FinishReason, Request, RequestOutput, StreamEvent};
use super::sampler;
use super::sched::{self, PolicyKind, SchedContext, SchedPolicy};
use super::step;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model config name from the manifest ("serve", "train", "tiny").
    pub model: String,
    /// Adapter execution mode: "base" (merged / no adapters), "road"
    /// (element-wise Eq. 4 path), "lora" (bmm baseline), "ia3".
    pub mode: String,
    /// Decode slot count; must have a matching `decode_<mode>_<model>_b<N>`
    /// artifact.
    pub decode_slots: usize,
    pub queue_capacity: usize,
    /// Baseline escape hatch: round-trip the full K/V cache host↔device on
    /// every decode step (the pre-device-resident behavior).  Used by the
    /// fig4 bench to measure what staying on device saves; leave `false`
    /// for serving.
    pub kv_host_roundtrip: bool,
    /// Usable device bank slots, including the reserved identity slot 0
    /// (`None` = every slot the compiled artifact carries).  The
    /// adapter-churn bench pins this below the registered-adapter count to
    /// exercise paging.
    pub bank_slots: Option<usize>,
    /// `true` (default): dirty-slot rows are paged up individually.
    /// `false`: any change re-uploads the whole bank — the measurable
    /// baseline for `road bench-serving --study bank`.
    pub paged_bank_uploads: bool,
    /// Admission scheduling policy — which queued request gets the next
    /// free decode slot and the chance to page its adapter in: FCFS
    /// (default, the pre-policy FIFO), deadline-aware EDF, priority
    /// tiers, or fair-share across adapters.  `road serve --policy`.
    pub policy: PolicyKind,
    /// Time source for every engine timestamp: submit stamps, TTFT and
    /// queue-wait metrics, deadline enforcement, step timing.
    /// [`Clock::wall`] in production; [`Clock::manual`] makes the whole
    /// temporal surface deterministic for tests and the sched study.
    pub clock: Clock,
    /// Which runtime backend serves this engine (`road serve --backend`):
    /// compiled PJRT artifacts, or the artifact-free pure-Rust reference
    /// model ([`crate::runtime::reference`]).  Consulted by whoever
    /// constructs the [`Runtime`] ([`super::server::EngineServer`],
    /// `main.rs`); the engine itself is backend-agnostic.
    pub backend: BackendKind,
    /// `true` (default): block-granular KV accounting with shared-prefix
    /// reuse ([`super::kv::PagedKv`]) — admission reserves only the
    /// request's generation footprint, and prompts whose leading blocks are
    /// cached skip that much prefill.  `false`: the measurable flat
    /// baseline — every lane charges a full `max_seq` worth of blocks and
    /// nothing is shared (`road serve --paged-kv=false`).
    pub paged_kv: bool,
    /// Tokens per KV block (prefix sharing granularity and the admission
    /// accounting unit).  `road serve --kv-block`.
    pub kv_block_size: usize,
    /// Total blocks in the shared pool — the serving memory budget.
    /// `None` = `decode_slots * ceil(max_seq / kv_block_size)`: enough for
    /// every lane to reach `max_seq`, so the block gate never binds unless
    /// explicitly squeezed (`road serve --kv-pool-blocks`, the kvpage
    /// bench's pressure knob).
    pub kv_pool_blocks: Option<usize>,
    /// First engine-issued request id (default 1; 0 is reserved — empty
    /// decode lanes are masked by id 0).  A multi-replica
    /// [`crate::coordinator::Fleet`] gives replica `r` the base `r + 1` so
    /// wire ids stay globally unique and encode their home replica.
    pub request_id_base: u64,
    /// Increment between consecutive engine-issued ids (default 1).  A
    /// fleet of `n` replicas uses stride `n`: replica `r` issues
    /// `r+1, r+1+n, r+1+2n, ...`, so `(id - 1) % n` recovers the replica
    /// for O(1) cancel routing with no shared id state.
    pub request_id_stride: u64,
    /// Per-iteration token budget for chunked prefill (`road serve
    /// --prefill-chunk`).  `0` (default) keeps the atomic bucketed-prefill
    /// baseline: admission pads a whole batch of prompts to one bucket and
    /// runs the prefill executable in a single call, freezing every active
    /// decode lane for its duration.  `> 0` switches the engine to *mixed
    /// steps*: each iteration, every occupied lane advances one token
    /// through decode, and up to `prefill_chunk_tokens - occupied_lanes`
    /// further prompt tokens stream through the `chunk_prefill` entry —
    /// admission starts prompt-feeding lanes immediately (no bucket, no
    /// padding) and long prompts prefill incrementally over several
    /// iterations instead of stalling the batch (docs/DESIGN.md §Engine
    /// step).
    pub prefill_chunk_tokens: usize,
    /// `true` (default): the reference backend drives adapter epilogues
    /// through the chunked fused kernels in [`crate::runtime::epilogue`].
    /// `false`: the element-at-a-time scalar oracle (`road serve
    /// --fused-epilogue=false`).  The two are bitwise-identical for
    /// road/ia3 and within 1 ulp for lora; the flag exists so that claim
    /// can be checked end-to-end, not because the outputs should differ.
    pub fused_epilogue: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "serve".into(),
            mode: "road".into(),
            decode_slots: 8,
            queue_capacity: 1024,
            kv_host_roundtrip: false,
            bank_slots: None,
            paged_bank_uploads: true,
            policy: PolicyKind::Fcfs,
            clock: Clock::Wall,
            backend: BackendKind::Pjrt,
            paged_kv: true,
            kv_block_size: 16,
            kv_pool_blocks: None,
            request_id_base: 1,
            request_id_stride: 1,
            prefill_chunk_tokens: 0,
            fused_epilogue: true,
        }
    }
}

struct PrefillBucket {
    batch: usize,
    prompt_len: usize,
    exe: Rc<Executable>,
}

pub struct Engine {
    pub rt: Rc<Runtime>,
    pub cfg: ModelConfigInfo,
    pub econf: EngineConfig,
    pub registry: AdapterRegistry,
    params: ParamStore,
    param_bufs: BTreeMap<String, xla::PjRtBuffer>,
    bank_bufs: BTreeMap<String, xla::PjRtBuffer>,
    decode_exe: Rc<Executable>,
    /// Chunked-prefill entry (`chunk_prefill_<mode>_<model>_b<slots>`),
    /// loaded only when [`EngineConfig::prefill_chunk_tokens`] > 0.
    chunk_exe: Option<Rc<Executable>>,
    prefill_buckets: Vec<PrefillBucket>,
    slots: Vec<Option<ActiveRequest>>,
    alloc: SlotAllocator,
    kv: KvState,
    /// Block-granular KV accounting + shared-prefix content cache layered
    /// over `kv` ([`EngineConfig::paged_kv`]; flat baseline when false).
    paged: PagedKv,
    pub queue: AdmissionQueue,
    pub metrics: Metrics,
    /// Admission scheduler ([`EngineConfig::policy`]): ranks the queue
    /// each iteration before `pop_scheduled`.
    policy: Box<dyn SchedPolicy>,
    /// Time source for every timestamp this engine takes
    /// ([`EngineConfig::clock`]).
    clock: Clock,
    /// Lifetime admissions per adapter name ("" = base model) — the
    /// fair-share policy's service ledger.
    admitted_per_adapter: BTreeMap<String, usize>,
    next_id: u64,
    /// Events produced inside the current scheduler iteration, drained by
    /// [`Engine::step`].
    events: Vec<StreamEvent>,
    /// Requests currently stalled at the KV-block admission gate — stall
    /// metrics count *transitions* into this set, not per-iteration
    /// retries (one stuck request is one stall, however many scheduler
    /// ticks it waits).
    kv_stalled: BTreeSet<u64>,
    /// Same transition tracking for the adapter-bank `Stalled` gate.
    bank_stalled: BTreeSet<u64>,
    /// When the previous decode step completed — the decode-stall
    /// recorder's anchor; cleared when the engine has no active lanes.
    last_decode_at: Option<Instant>,
    /// Test-only fault injection ([`Engine::inject_reservation_loss`]):
    /// the next admission of this id discards its KV reservation, seeding
    /// the missing-reservation invariant breach the typed
    /// [`EngineError::Internal`] path surfaces.
    lose_reservation: Option<u64>,
}

impl Engine {
    pub fn new(rt: Rc<Runtime>, econf: EngineConfig) -> Result<Engine> {
        let params = ParamStore::load_pretrained(&rt.manifest, &econf.model)?;
        Engine::with_params(rt, econf, params)
    }

    /// The parameter store this engine serves (merged weights included).
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Build an engine over explicit parameters (e.g. merged weights).
    pub fn with_params(rt: Rc<Runtime>, econf: EngineConfig, params: ParamStore) -> Result<Engine> {
        rt.set_fused_epilogue(econf.fused_epilogue);
        let cfg = rt.manifest.config(&econf.model)?.clone();
        let decode_name = format!("decode_{}_{}_b{}", econf.mode, econf.model, econf.decode_slots);
        let decode_exe = rt
            .load(&decode_name)
            .with_context(|| format!("loading decode entry {decode_name}"))?;

        // Discover prefill buckets for this (model, mode).
        let mut prefill_buckets = Vec::new();
        let names: Vec<String> = rt
            .manifest
            .entries
            .values()
            .filter(|e| {
                e.kind == "prefill"
                    && e.config == econf.model
                    && e.mode.as_deref() == Some(econf.mode.as_str())
            })
            .map(|e| e.name.clone())
            .collect();
        for name in names {
            let exe = rt.load(&name)?;
            let (batch, prompt_len) =
                (exe.info.batch.unwrap_or(1), exe.info.prompt_len.unwrap_or(0));
            prefill_buckets.push(PrefillBucket { batch, prompt_len, exe });
        }
        if prefill_buckets.is_empty() {
            bail!("no prefill entries for model={} mode={}", econf.model, econf.mode);
        }
        prefill_buckets.sort_by_key(|b| (b.prompt_len, b.batch));

        // Chunked prefill needs its own fixed-shape entry (same batch as
        // decode); artifact sets without one can't serve --prefill-chunk>0
        // and fail loudly at construction, not mid-request.
        let chunk_exe = if econf.prefill_chunk_tokens > 0 {
            let name = format!(
                "chunk_prefill_{}_{}_b{}",
                econf.mode, econf.model, econf.decode_slots
            );
            Some(rt.load(&name).with_context(|| {
                format!(
                    "chunked prefill (--prefill-chunk > 0) requires the {name} entry; \
                     this artifact set has no chunk_prefill entries"
                )
            })?)
        } else {
            None
        };

        // Upload parameters once; they stay device-resident for every call.
        let mut param_bufs = BTreeMap::new();
        for (name, t) in params.names.iter().zip(&params.tensors) {
            param_bufs.insert(name.clone(), rt.upload(t)?);
        }

        let n_bank = cfg.n_adapters;
        let bank = AdapterBank::new(&cfg, &econf.mode, n_bank)?;
        let usable = econf.bank_slots.unwrap_or(n_bank).min(n_bank);
        if econf.mode != "base" && usable < 2 {
            bail!(
                "bank_slots = {usable} leaves no pageable slot (slot 0 is the reserved \
                 identity page); need at least 2"
            );
        }
        let registry = AdapterRegistry::with_usable_slots(bank, usable);

        let kv = KvState::new(&cfg, econf.decode_slots);
        let block_size = econf.kv_block_size.max(1);
        // Default budget: every lane can reach max_seq, so the block gate
        // only binds when explicitly squeezed below it.
        let lane_blocks = (cfg.max_seq + block_size - 1) / block_size;
        let pool_blocks =
            econf.kv_pool_blocks.unwrap_or(econf.decode_slots.saturating_mul(lane_blocks));
        let paged =
            PagedKv::new(econf.decode_slots, cfg.max_seq, block_size, pool_blocks, econf.paged_kv);
        let slots = (0..econf.decode_slots).map(|_| None).collect();
        let mut engine = Engine {
            rt,
            cfg,
            registry,
            params,
            param_bufs,
            bank_bufs: BTreeMap::new(),
            decode_exe,
            chunk_exe,
            prefill_buckets,
            alloc: SlotAllocator::new(econf.decode_slots),
            slots,
            kv,
            paged,
            queue: AdmissionQueue::new(econf.queue_capacity),
            metrics: Metrics::with_clock(econf.clock.clone()),
            policy: sched::make_policy(econf.policy),
            clock: econf.clock.clone(),
            admitted_per_adapter: BTreeMap::new(),
            // Id 0 is reserved for masked decode lanes, so the base
            // saturates up to 1 even if a caller passes 0.
            next_id: econf.request_id_base.max(1),
            events: Vec::new(),
            kv_stalled: BTreeSet::new(),
            bank_stalled: BTreeSet::new(),
            last_decode_at: None,
            lose_reservation: None,
            econf,
        };
        // The free-block low-water mark starts at the full pool.
        engine.metrics.kv_blocks_free_min = engine.paged.pool().n_free();
        Ok(engine)
    }

    /// The paged-KV layer (pool stats, block tables) — read-only; the
    /// engine owns all mutations.
    pub fn paged_kv(&self) -> &PagedKv {
        &self.paged
    }

    /// The engine's time source (a clone of [`EngineConfig::clock`]):
    /// tests holding the same manual clock advance it to drive deadline
    /// sheds and latency stamps deterministically.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Register (or replace) a named adapter in the host store.  Never
    /// fails for capacity — device residency is paged in at admission.
    pub fn register_adapter(&mut self, name: &str, adapter: &Adapter) -> Result<()> {
        if self.econf.mode == "base" {
            bail!("engine in merged/base mode serves no per-request adapters");
        }
        self.registry.register(name, adapter)
    }

    /// Remove a named adapter from the store.  Rejected while any of its
    /// requests are in flight (the bank slot stays pinned) or still
    /// waiting in the admission queue.
    pub fn unregister_adapter(&mut self, name: &str) -> Result<()> {
        if self.queue.contains_adapter(name) {
            bail!("adapter {name:?} has queued requests; unregister after they drain");
        }
        self.registry.unregister(name)
    }

    /// Drop a named adapter's device slot but keep it registered; a later
    /// request pages it back in.  Returns whether a slot was freed.
    pub fn evict_adapter(&mut self, name: &str) -> Result<bool> {
        self.registry.evict(name)
    }

    pub fn max_prompt_len(&self) -> usize {
        self.prefill_buckets.iter().map(|b| b.prompt_len).max().unwrap_or(0)
    }

    /// Enqueue a request and return its engine-issued id.  Every failure
    /// mode is a typed [`EngineError`]: validation problems are
    /// [`EngineError::Invalid`], unknown adapters are
    /// [`EngineError::AdapterNotFound`], and a queue at capacity is
    /// [`EngineError::QueueFull`] backpressure.  Stamps the submission time
    /// so TTFT/e2e metrics (and deadline budgets) start at the front door.
    pub fn submit(&mut self, mut req: Request) -> std::result::Result<u64, EngineError> {
        let invalid = |reason: String| EngineError::Invalid { reason };
        if req.prompt.is_empty() {
            return Err(invalid("empty prompt".into()));
        }
        if req.prompt.len() > self.max_prompt_len() {
            return Err(invalid(format!(
                "prompt of {} tokens exceeds the largest prefill bucket ({})",
                req.prompt.len(),
                self.max_prompt_len()
            )));
        }
        // checked_add: wire clients can send arbitrary max_new_tokens, and
        // a wrapping sum in release mode would slip past this guard (and
        // then decode forever — done() could never reach MaxTokens).
        let total = req.prompt.len().checked_add(req.max_new_tokens);
        if total.map_or(true, |t| t > self.cfg.max_seq) {
            return Err(invalid(format!(
                "prompt {} + max_new {} exceeds max_seq {}",
                req.prompt.len(),
                req.max_new_tokens,
                self.cfg.max_seq
            )));
        }
        if let Some(a) = &req.adapter {
            if !self.registry.store.contains(a) {
                return Err(EngineError::AdapterNotFound { name: a.clone() });
            }
        }
        // Ids are engine-issued, unconditionally: a caller-stamped id is
        // overwritten, so correlation goes through the returned id.
        req.id = self.next_id;
        self.next_id = self.next_id.wrapping_add(self.econf.request_id_stride.max(1));
        let id = req.id;
        if req.submitted_at.is_none() {
            req.submitted_at = Some(self.clock.now());
        }
        self.queue.push(req)?;
        Ok(id)
    }

    /// Cancel a request wherever it currently lives.
    ///
    /// * Still queued: removed before it ever occupies a slot.
    /// * In a decode lane: the slot is freed and the adapter bank pin is
    ///   released immediately — the next scheduler step can admit waiting
    ///   work into the reclaimed lane.
    ///
    /// Returns the terminal [`RequestOutput`] (finish =
    /// [`FinishReason::Cancelled`], tokens generated so far) or `None` when
    /// the id is unknown or already finished — cancellation races resolve
    /// as no-ops.
    pub fn cancel(&mut self, id: u64) -> Option<RequestOutput> {
        let now = self.clock.now();
        if let Some(req) = self.queue.cancel(id) {
            // It can never stall at an admission gate again.
            self.kv_stalled.remove(&id);
            self.bank_stalled.remove(&id);
            self.metrics.requests_cancelled += 1;
            let e2e = req.submitted_at.map(|s| (now - s).as_secs_f64()).unwrap_or_default();
            return Some(RequestOutput {
                id,
                adapter: req.adapter,
                tokens: Vec::new(),
                finish: FinishReason::Cancelled,
                ttft: 0.0,
                e2e,
            });
        }
        let s = self
            .slots
            .iter()
            .position(|slot| slot.as_ref().is_some_and(|ar| ar.req.id == id))?;
        let ar = self.slots[s].take()?;
        // The allocator cannot refuse: `s` was found occupied above.  A
        // disagreeing allocator is a lost invariant, not a reason to kill
        // the engine thread mid-cancel — loud in debug, tolerated live.
        let released = self.alloc.release(s);
        debug_assert!(released.is_ok(), "cancelled slot was allocated");
        // A cancelled hit lane drops its shared-prefix refs; the cached
        // originals survive for the other lanes holding them.
        let kv_released = self.paged.release_lane(s);
        debug_assert!(kv_released.is_ok(), "cancelled lane held KV blocks");
        self.registry.unpin(ar.slot_adapter);
        self.metrics.requests_cancelled += 1;
        let ttft = ar.first_token_at.map(|t| (t - ar.submitted).as_secs_f64()).unwrap_or_default();
        Some(RequestOutput {
            id,
            adapter: ar.req.adapter,
            tokens: ar.generated,
            finish: FinishReason::Cancelled,
            ttft,
            e2e: (now - ar.submitted).as_secs_f64(),
        })
    }

    /// Test-only: make the next admission of `id` discard its KV
    /// reservation after popping, reproducing the lost-reservation
    /// invariant breach that the typed [`EngineError::Internal`] path
    /// surfaces (and that conservation tests assert is never silent).
    #[doc(hidden)]
    pub fn inject_reservation_loss(&mut self, id: u64) {
        self.lose_reservation = Some(id);
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.n_active() > 0 || !self.queue.is_empty()
    }

    /// Refresh the device bank from dirty slots ([`AdapterBank::upload_dirty`]
    /// does the transfer accounting: per-slot rows on the paged path, the
    /// whole bank on the baseline).
    fn upload_bank_if_dirty(&mut self) -> Result<()> {
        let paged = self.econf.paged_bank_uploads;
        if let Some(up) =
            self.registry.bank.upload_dirty(&self.rt.client, &mut self.bank_bufs, paged)?
        {
            self.metrics.bank_upload_bytes += up.bytes;
            self.metrics.bank_staged_rows += up.staged_rows;
            if up.full {
                self.metrics.bank_full_uploads += 1;
            }
        }
        Ok(())
    }

    /// Assemble the positional argument list for an entry: device-resident
    /// params/banks, per-call host `data` tensors, and loop-carried device
    /// buffers (`dev`, checked before `data` — the decode K/V caches).
    fn build_args<'a>(
        &'a self,
        info: &EntryInfo,
        data: &BTreeMap<&'static str, &'a HostTensor>,
        dev: &BTreeMap<&'static str, &'a xla::PjRtBuffer>,
    ) -> Result<Vec<Arg<'a>>> {
        let mut args = Vec::with_capacity(info.inputs.len());
        for spec in &info.inputs {
            match spec.group.as_str() {
                "params" => args.push(Arg::Buffer(
                    self.param_bufs
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("missing param {}", spec.name))?,
                )),
                "adapters" => args.push(Arg::Buffer(
                    self.bank_bufs
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("missing bank tensor {}", spec.name))?,
                )),
                "data" => {
                    if let Some(b) = dev.get(spec.name.as_str()) {
                        args.push(Arg::Buffer(b));
                    } else {
                        args.push(Arg::Host(
                            data.get(spec.name.as_str())
                                .copied()
                                .ok_or_else(|| anyhow!("missing data input {}", spec.name))?,
                        ));
                    }
                }
                g => bail!("unexpected input group {g} in {}", info.name),
            }
        }
        Ok(args)
    }

    /// Admit queued requests into free slots via bucketed prefill.
    ///
    /// Which waiting requests are *considered* first is the scheduling
    /// policy's call ([`EngineConfig::policy`]): the queue is ranked by
    /// [`SchedPolicy::order`] and popped in that order, so EDF admits the
    /// tightest deadline first, priority admits the highest tier first,
    /// and fair-share admits the least-served adapter first.  FCFS ranks
    /// by queue position, reproducing the pre-policy FIFO byte for byte.
    ///
    /// Admission stays gated on adapter residency: a request is only
    /// popped when its adapter is (or can be paged) device-resident; the
    /// paged-in slot is pinned immediately so nothing admitted later in
    /// the same batch can evict it.  Requests whose adapter cannot be
    /// paged (every pageable slot pinned) keep their queue position.
    fn maybe_prefill(&mut self) -> Result<()> {
        let chunked = self.chunk_exe.is_some();
        loop {
            let n_free = self.alloc.n_free();
            if n_free == 0 || self.queue.is_empty() {
                return Ok(());
            }
            // Rank the queue FIRST: the policy sees current lane occupancy
            // (partially-prefilled feeding lanes included) and the
            // lifetime admission ledger (the fair-share inputs).  The
            // bucket is then selected against the top-ranked request —
            // electing it from `min_prompt_len()` before ranking let
            // short, late prompts keep choosing a small bucket whose
            // `prompt_len` filter skipped a top-ranked long prompt every
            // wave (the policy-order inversion bug).
            let mut in_flight: BTreeMap<String, usize> = BTreeMap::new();
            for lane in self.slots.iter().flatten() {
                *in_flight.entry(lane.req.adapter.clone().unwrap_or_default()).or_insert(0) += 1;
            }
            let ctx = SchedContext {
                now: self.clock.now(),
                in_flight: &in_flight,
                admitted: &self.admitted_per_adapter,
            };
            let order = self.policy.order(&self.queue, &ctx);
            // Chunked mode admits without a bucket: every admission starts
            // a prompt-feeding lane and streams its prefill through
            // decode+chunk steps, so no padded shape constrains who fits.
            let (bucket, cap, max_len) = if chunked {
                (None, n_free, self.max_prompt_len())
            } else {
                // The prompt length the bucket must cover: the top-ranked
                // waiting request's (falling back to the shortest prompt
                // if the ranking is stale/empty).
                let target_len = order
                    .iter()
                    .filter_map(|&i| self.queue.iter().nth(i))
                    .map(|r| r.prompt.len())
                    .next()
                    .unwrap_or_else(|| self.queue.min_prompt_len());
                // Smallest bucket that fits the target; among those, the
                // largest batch that we can actually fill.
                let want = n_free.min(self.queue.len());
                let mut best: Option<usize> = None;
                for (i, b) in self.prefill_buckets.iter().enumerate() {
                    if b.prompt_len < target_len {
                        continue;
                    }
                    let cap = b.batch.min(want);
                    let better = match best {
                        None => true,
                        Some(j) => {
                            let bj = &self.prefill_buckets[j];
                            let (cap_j, len_j) = (bj.batch.min(want), bj.prompt_len);
                            // prefer more admitted, then shorter padded length
                            cap > cap_j || (cap == cap_j && b.prompt_len < len_j)
                        }
                    };
                    if better {
                        best = Some(i);
                    }
                }
                let Some(bi) = best else { return Ok(()) };
                let b = &self.prefill_buckets[bi];
                (Some(bi), n_free.min(b.batch), b.prompt_len)
            };
            let mut paged_ids: BTreeSet<u64> = BTreeSet::new();
            let mut reservations: BTreeMap<u64, KvReservation> = BTreeMap::new();
            let registry = &mut self.registry;
            let metrics = &mut self.metrics;
            let paged = &mut self.paged;
            let kv_stalled = &mut self.kv_stalled;
            let bank_stalled = &mut self.bank_stalled;
            let take = self.queue.pop_scheduled(&order, cap, max_len, |req| {
                // Gate 1: KV blocks.  All-or-nothing reservation of the
                // request's footprint (shared-prefix refs + private blocks);
                // a pool that can't cover it leaves the request queued and
                // holding nothing.
                let Some(res) =
                    paged.try_reserve(req.adapter.as_deref(), &req.prompt, req.max_new_tokens)
                else {
                    // Count stall *transitions*, not retries: one stuck
                    // request is one stall however many scheduler
                    // iterations it waits (the counter-inflation bug).
                    if kv_stalled.insert(req.id) {
                        metrics.kv_admission_stalls += 1;
                    }
                    return false;
                };
                kv_stalled.remove(&req.id);
                // Gate 2: adapter residency (pinned immediately so nothing
                // admitted later in this batch can evict it).
                let adapter_ok = match req.adapter.as_deref() {
                    None => true,
                    Some(name) => match registry.ensure_resident(name) {
                        Ok(PageOutcome::Hit(slot)) => {
                            metrics.bank_hits += 1;
                            registry.pin(slot);
                            true
                        }
                        Ok(PageOutcome::Paged { slot, evicted }) => {
                            metrics.bank_misses += 1;
                            if evicted.is_some() {
                                metrics.bank_evictions += 1;
                            }
                            paged_ids.insert(req.id);
                            registry.pin(slot);
                            true
                        }
                        // All pageable slots pinned by in-flight lanes: leave
                        // the request queued; a finishing lane unblocks it.
                        // Transition-counted like the KV gate above.
                        Ok(PageOutcome::Stalled) => {
                            if bank_stalled.insert(req.id) {
                                metrics.bank_admission_stalls += 1;
                            }
                            false
                        }
                        // Unregistered mid-queue (unregister raced a waiting
                        // request): leave it queued rather than corrupting the
                        // batch; submit() validates, so this is exceptional.
                        Err(_) => false,
                    },
                };
                if !adapter_ok {
                    // Roll the block reservation back; the request keeps its
                    // queue position with no blocks held.
                    let rolled_back = paged.cancel_reservation(res);
                    debug_assert!(rolled_back.is_ok(), "reservation rollback must succeed");
                    return false;
                }
                bank_stalled.remove(&req.id);
                metrics.kv_block_hits += res.hit_blocks;
                metrics.kv_block_misses += res.n_blocks() - res.hit_blocks;
                metrics.kv_block_evictions += res.evictions;
                if res.hit_blocks > 0 {
                    metrics.kv_prefix_hits += 1;
                }
                reservations.insert(req.id, res);
                true
            });
            if take.is_empty() {
                return Ok(());
            }
            // Memory-pressure gauges right after the reservation wave — the
            // low-water mark of free blocks happens here, not at release.
            self.metrics.kv_blocks_free_min =
                self.metrics.kv_blocks_free_min.min(self.paged.pool().n_free());
            self.metrics.kv_shared_refs_peak =
                self.metrics.kv_shared_refs_peak.max(self.paged.pool().total_refs());
            // Pair every popped request with its reservation up front.  A
            // request whose reservation went missing used to be silently
            // dropped right here (`else { continue }` — no event, no slot,
            // a caller waiting forever).  A lost reservation is a broken
            // engine invariant, so it now ends the request's stream with a
            // typed terminal [`EngineError::Internal`] instead.
            let mut paired: Vec<(Request, KvReservation)> = Vec::with_capacity(take.len());
            for req in take {
                let mut res = reservations.remove(&req.id);
                if self.lose_reservation == Some(req.id) {
                    // Test-only fault injection: discard the reservation
                    // (returning its blocks, so nothing leaks) to seed the
                    // invariant breach this path is meant to surface.
                    self.lose_reservation = None;
                    if let Some(res) = res.take() {
                        let rolled_back = self.paged.cancel_reservation(res);
                        debug_assert!(rolled_back.is_ok(), "injected rollback must succeed");
                    }
                }
                let Some(res) = res else {
                    // The gate pinned the adapter before the reservation was
                    // lost; unpin so the slot is not leaked forever.
                    if let Some(slot) =
                        req.adapter.as_deref().and_then(|name| self.registry.slot_of(name))
                    {
                        self.registry.unpin(slot);
                    }
                    self.events.push(StreamEvent::Error {
                        id: req.id,
                        error: EngineError::Internal {
                            reason: format!(
                                "request {} lost its KV reservation at admission",
                                req.id
                            ),
                        },
                    });
                    continue;
                };
                paired.push((req, res));
            }
            // Prefix-hit lanes skip prefill compute entirely; chunked mode
            // starts EVERY admission as a feeding lane (cold ones stream
            // their whole prompt through decode + chunk-prefill steps).
            let mut cold: Vec<(Request, KvReservation)> = Vec::new();
            for (req, res) in paired {
                if chunked || res.hit_blocks > 0 {
                    self.admit_feeding_lane(req, res, &paged_ids)?;
                } else {
                    cold.push((req, res));
                }
            }
            if let Some(bi) = bucket {
                if !cold.is_empty() {
                    self.prefill_batch(bi, cold, &paged_ids)?;
                }
            }
            debug_assert!(
                reservations.is_empty(),
                "every admitted request consumed its KV reservation"
            );
        }
    }

    /// Admit a request straight into a prompt-feeding decode lane: bind
    /// its block reservation, adopt whatever shared-prefix blocks the
    /// reservation hit (none for a cold chunked admission), and start the
    /// lane feeding at the first uncached prompt position — the rest of
    /// the prompt streams through decode steps (and, in chunked mode,
    /// through chunk-prefill grants), and the first new token is sampled
    /// when the last prompt position's logits appear.  No bucketed
    /// prefill executable runs for this request.
    fn admit_feeding_lane(
        &mut self,
        req: Request,
        res: KvReservation,
        paged_ids: &BTreeSet<u64>,
    ) -> Result<()> {
        let now = self.clock.now();
        *self
            .admitted_per_adapter
            .entry(req.adapter.clone().unwrap_or_default())
            .or_insert(0) += 1;
        let slot_adapter = match &req.adapter {
            Some(name) => {
                self.registry.slot_of(name).ok_or_else(|| anyhow!("adapter {name:?} vanished"))?
            }
            None => 0,
        };
        if let Some(s) = req.submitted_at {
            self.metrics.queue_wait.record(now.duration_since(s));
            if paged_ids.contains(&req.id) {
                self.metrics.paged_wait.record(now.duration_since(s));
            }
        }
        self.events.push(StreamEvent::Admitted { id: req.id });
        let slot = self
            .alloc
            .alloc()
            .ok_or_else(|| anyhow!("scheduler invariant violated: no free slot"))?;
        let cold = res.hit_blocks == 0;
        self.paged.bind_lane(slot, res)?;
        let hit_tokens = if cold {
            0
        } else {
            // Adoption is a host-side scatter, same as prefill-lane
            // adoption.
            if self.kv.materialize_host()? {
                self.metrics.kv_host_syncs += 1;
            }
            self.paged.adopt_shared_prefix(&mut self.kv, slot)?
        };
        self.metrics.prompt_tokens += req.prompt.len();
        self.metrics.kv_prefill_tokens_saved += hit_tokens;
        let mut ar = ActiveRequest::new(req, slot_adapter, now);
        // Resume where the cached prefix ends (position 0 for a cold
        // chunked admission): decode feeds prompt[pos] until the whole
        // prompt is in cache, then samples the first token.
        ar.pos = hit_tokens;
        // Cold chunked lanes publish their prompt prefix once fully fed
        // (hit lanes adopted an already-published prefix, nothing to add).
        ar.publish_on_fed = cold;
        debug_assert!(self.slots[slot].is_none());
        self.slots[slot] = Some(ar);
        Ok(())
    }

    fn prefill_batch(
        &mut self,
        bucket_idx: usize,
        reqs: Vec<(Request, KvReservation)>,
        paged_ids: &BTreeSet<u64>,
    ) -> Result<()> {
        self.upload_bank_if_dirty()?;
        let (b, l) = (
            self.prefill_buckets[bucket_idx].batch,
            self.prefill_buckets[bucket_idx].prompt_len,
        );
        let mut tokens = vec![0i32; b * l];
        let mut lengths = vec![1i32; b];
        let mut ids = vec![0i32; b];
        let mut actives: Vec<(ActiveRequest, KvReservation)> = Vec::with_capacity(reqs.len());
        let now = self.clock.now();
        for (lane, (req, res)) in reqs.into_iter().enumerate() {
            *self
                .admitted_per_adapter
                .entry(req.adapter.clone().unwrap_or_default())
                .or_insert(0) += 1;
            let slot_adapter = match &req.adapter {
                Some(name) => self
                    .registry
                    .slot_of(name)
                    .ok_or_else(|| anyhow!("adapter {name:?} vanished"))?,
                None => 0,
            };
            tokens[lane * l..lane * l + req.prompt.len()]
                .copy_from_slice(&req.prompt);
            lengths[lane] = req.prompt.len() as i32;
            ids[lane] = slot_adapter as i32;
            // Queue wait = submit → admission into a prefill batch; bank
            // misses also land in the paged-adapter histogram so the
            // queueing cost of paging is separately visible.
            if let Some(s) = req.submitted_at {
                self.metrics.queue_wait.record(now.duration_since(s));
                if paged_ids.contains(&req.id) {
                    self.metrics.paged_wait.record(now.duration_since(s));
                }
            }
            self.events.push(StreamEvent::Admitted { id: req.id });
            actives.push((ActiveRequest::new(req, slot_adapter, now), res));
        }

        let ids_t = HostTensor::i32(vec![b], ids);
        let tokens_t = HostTensor::i32(vec![b, l], tokens);
        let lengths_t = HostTensor::i32(vec![b], lengths);
        let mut data: BTreeMap<&'static str, &HostTensor> = BTreeMap::new();
        data.insert("ids", &ids_t);
        data.insert("tokens", &tokens_t);
        data.insert("lengths", &lengths_t);
        let exe = self.prefill_buckets[bucket_idx].exe.clone();
        let args = self.build_args(&exe.info, &data, &BTreeMap::new())?;
        let t0 = self.clock.now();
        let outs = exe.run(&args)?;
        drop(args);
        self.metrics.prefill_time += self.clock.now().saturating_duration_since(t0);
        self.metrics.prefill_batches += 1;

        let logits = &outs[0]; // [b, vocab]
        let (pk, pv) = (&outs[1], &outs[2]);
        // Lane adoption is a host-side scatter; when the decode loop left
        // the cache on device this downloads it once per admitted batch
        // (NOT per decode step — see KvState's residency model).
        if self.kv.materialize_host()? {
            self.metrics.kv_host_syncs += 1;
        }
        let vocab = self.cfg.vocab;
        for (lane, (mut ar, res)) in actives.into_iter().enumerate() {
            // Sample the first generated token from the prefill logits.
            let row = logits.read_f32_range(lane * vocab, vocab);
            let tok = sampler::sample(
                &row,
                ar.req.sampling.temperature,
                ar.req.sampling.top_k,
                &mut ar.rng_state,
            );
            ar.generated.push(tok);
            let first_token_at = self.clock.now();
            ar.first_token_at = Some(first_token_at);
            ar.last_token_at = Some(first_token_at);
            self.metrics.tokens_generated += 1;
            self.metrics.prompt_tokens += ar.req.prompt.len();
            self.metrics.prefill_lane_tokens += ar.req.prompt.len();
            // Stream the first token with its TTFT; a stop token is
            // terminal and never emitted (it is also stripped from the
            // finished output, keeping the stream concatenation exact).
            if !matches!(ar.done(), Some(FinishReason::StopToken)) {
                let ttft = (first_token_at - ar.submitted).as_secs_f64();
                self.events.push(StreamEvent::Token {
                    id: ar.req.id,
                    token: tok,
                    pos: 0,
                    ttft_hint: Some(ttft),
                });
            }

            let slot = self
                .alloc
                .alloc()
                .ok_or_else(|| anyhow!("scheduler invariant violated: no free slot"))?;
            self.paged.bind_lane(slot, res)?;
            self.kv.adopt_prefill_lane(pk, pv, lane, slot, ar.req.prompt.len())?;
            // Promote this prompt's full blocks into the shared-prefix
            // cache so later identical prompts can skip their prefill.
            let published = self.paged.publish_prefix(&mut self.kv, slot, ar.req.prompt.len())?;
            self.metrics.kv_blocks_published += published;
            debug_assert!(self.slots[slot].is_none());
            self.slots[slot] = Some(ar);
        }
        Ok(())
    }

    /// Run a serving entry with the standard K/V cache plumbing — the one
    /// step-execution path shared by decode and chunked prefill.  `data`
    /// carries the entry's per-call host inputs; the cache pair is
    /// appended here.  On the device-resident hot path the caches stay in
    /// PJRT buffers and each call's outputs are handed straight back as
    /// the next call's inputs; [`EngineConfig::kv_host_roundtrip`] keeps
    /// the full host round-trip measurable as a baseline.  Returns the
    /// logits and the measured run time (the caller attributes it to
    /// decode or prefill).
    fn run_with_cache(
        &mut self,
        exe: Rc<Executable>,
        data: &BTreeMap<&'static str, &HostTensor>,
    ) -> Result<(HostTensor, Duration)> {
        if self.econf.kv_host_roundtrip {
            // Baseline: the full [n_layers, B, n_heads, max_seq, head_dim]
            // K/V pair is uploaded and downloaded every call — kept only as
            // the measurable comparison point for the device-resident path.
            if self.kv.materialize_host()? {
                self.metrics.kv_host_syncs += 1;
            }
            let (outs, elapsed) = {
                let mut all: BTreeMap<&'static str, &HostTensor> = data.clone();
                all.insert("k_cache", self.kv.host_k()?);
                all.insert("v_cache", self.kv.host_v()?);
                let args = self.build_args(&exe.info, &all, &BTreeMap::new())?;
                let t0 = self.clock.now();
                let outs = exe.run(&args)?;
                (outs, self.clock.now().saturating_duration_since(t0))
            };
            // This call moved the full cache up (Arg::Host inputs) and back
            // down (outputs) — count it so the report reflects the baseline's
            // actual transfer behavior.
            self.metrics.kv_uploads += 1;
            self.metrics.kv_host_syncs += 1;
            let [logits, k_new, v_new]: [HostTensor; 3] = outs.try_into().map_err(|v: Vec<_>| {
                anyhow!("entry {} returned {} outputs, expected 3", exe.info.name, v.len())
            })?;
            self.kv.replace(k_new, v_new)?;
            Ok((logits, elapsed))
        } else {
            // Device-resident hot path: the only per-call transfer is the
            // [B, vocab] logits download.
            if self.kv.ensure_device(&self.rt.client)? {
                self.metrics.kv_uploads += 1;
            }
            let t0 = self.clock.now();
            let outs = {
                let (kb, vb) = self.kv.device_pair()?;
                let mut dev: BTreeMap<&'static str, &xla::PjRtBuffer> = BTreeMap::new();
                dev.insert("k_cache", kb);
                dev.insert("v_cache", vb);
                let args = self.build_args(&exe.info, data, &dev)?;
                exe.run_device(&args)?
            };
            // Same positional contract as the host path: [logits, k, v].
            let [l_buf, k_buf, v_buf]: [xla::PjRtBuffer; 3] =
                outs.try_into().map_err(|v: Vec<_>| {
                    anyhow!("entry {} returned {} outputs, expected 3", exe.info.name, v.len())
                })?;
            let logits_dtype = exe.info.outputs.first().map_or(DType::F32, |s| s.dtype);
            let logits = buffer_to_host(&l_buf, logits_dtype)?;
            let elapsed = self.clock.now().saturating_duration_since(t0);
            self.kv.install_device(k_buf, v_buf)?;
            Ok((logits, elapsed))
        }
    }

    /// One decode step across all slots.
    fn decode_once(&mut self) -> Result<()> {
        self.upload_bank_if_dirty()?;
        let b = self.econf.decode_slots;
        let d = step::assemble_decode(&self.slots, b);
        if !d.any {
            return Ok(());
        }

        let ids_t = HostTensor::i32(vec![b], d.ids);
        let token_t = HostTensor::i32(vec![b], d.token);
        let pos_t = HostTensor::i32(vec![b], d.pos);
        let mut data: BTreeMap<&'static str, &HostTensor> = BTreeMap::new();
        data.insert("ids", &ids_t);
        data.insert("token", &token_t);
        data.insert("pos", &pos_t);
        let exe = self.decode_exe.clone();
        let (logits, elapsed) = self.run_with_cache(exe, &data)?;
        self.metrics.decode_time += elapsed;
        self.metrics.decode_steps += 1;
        // Decode-stall recorder: the gap between consecutive decode steps
        // as active lanes see it — a long atomic prefill wedged between
        // steps is exactly what shows up here.
        let decoded_at = self.clock.now();
        if let Some(prev) = self.last_decode_at {
            self.metrics.decode_stall.record(decoded_at.saturating_duration_since(prev));
        }
        self.last_decode_at = Some(decoded_at);

        let vocab = self.cfg.vocab;
        for s in 0..b {
            // Advance the lane.  A prompt-feeding step (shared-prefix hit
            // or chunked admission still streaming its prompt in) produced
            // logits for a token we already know — nothing is sampled or
            // streamed for it.
            let (feeding, first) = {
                let Some(ar) = self.slots[s].as_mut() else { continue };
                ar.pos += 1;
                (ar.pos < ar.req.prompt.len(), ar.first_token_at.is_none())
            };
            if feeding {
                continue;
            }
            let now = self.clock.now();
            let (id, tok, pos, reason, ttft_hint, hit_lane) = {
                let Some(ar) = self.slots[s].as_mut() else { continue };
                let row = logits.read_f32_range(s * vocab, vocab);
                let tok = sampler::sample(
                    &row,
                    ar.req.sampling.temperature,
                    ar.req.sampling.top_k,
                    &mut ar.rng_state,
                );
                ar.generated.push(tok);
                // Inter-token latency as this lane's consumer sees it.
                if let Some(prev) = ar.last_token_at {
                    self.metrics.itl.record(now.saturating_duration_since(prev));
                }
                ar.last_token_at = Some(now);
                // A feeding lane's first token lands here (bucketed cold
                // lanes stamp theirs in the prefill batch).
                let hint = if first {
                    ar.first_token_at = Some(now);
                    Some((now - ar.submitted).as_secs_f64())
                } else {
                    None
                };
                (ar.req.id, tok, ar.generated.len() - 1, ar.done(), hint, !ar.publish_on_fed)
            };
            if let Some(ttft) = ttft_hint {
                // Cold chunked lanes also take their first token mid-decode,
                // but only genuine prefix hits feed the prefix-hit TTFT
                // panel (`publish_on_fed` marks the cold ones).
                if hit_lane {
                    self.metrics.prefix_hit_ttft.record_us(ttft * 1e6);
                }
            }
            self.metrics.tokens_generated += 1;
            // Stop tokens are terminal and stripped from the output, so
            // they are never streamed either.
            if !matches!(reason, Some(FinishReason::StopToken)) {
                self.events.push(StreamEvent::Token { id, token: tok, pos, ttft_hint });
            }
            if let Some(reason) = reason {
                let Some(ar) = self.slots[s].take() else { continue };
                self.alloc.release(s)?;
                self.release_kv_lane(s)?;
                self.finish(ar, reason);
            }
        }
        Ok(())
    }

    /// Spend the step's leftover token budget on partially-prefilled
    /// lanes' prompts through the chunk-prefill entry.  The budget is
    /// [`EngineConfig::prefill_chunk_tokens`] minus the occupied lanes
    /// (each already advanced one token through decode this iteration);
    /// [`step::plan_chunks`] ranks whose chunks run under the same
    /// scheduling policy that ordered admission.  A chunk that covers the
    /// rest of a lane's prompt samples that request's first token from
    /// the chunk logits.
    fn chunk_prefill_once(&mut self) -> Result<()> {
        let Some(exe) = self.chunk_exe.clone() else { return Ok(()) };
        let budget = self.econf.prefill_chunk_tokens.saturating_sub(self.n_active());
        if budget == 0 {
            return Ok(());
        }
        // Fair-share signal: occupied lanes per adapter name, feeding
        // lanes included.
        let mut in_flight: BTreeMap<String, usize> = BTreeMap::new();
        for lane in self.slots.iter().flatten() {
            *in_flight.entry(lane.req.adapter.clone().unwrap_or_default()).or_insert(0) += 1;
        }
        let lanes: Vec<step::ChunkLane> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(s, slot)| {
                let ar = slot.as_ref()?;
                let remaining = ar.req.prompt.len().checked_sub(ar.pos).filter(|&r| r > 0)?;
                Some(step::ChunkLane {
                    slot: s,
                    remaining,
                    deadline_at: ar.req.deadline_at(),
                    priority: ar.req.priority,
                    in_flight_same_adapter: in_flight
                        .get(ar.req.adapter.as_deref().unwrap_or(""))
                        .copied()
                        .unwrap_or(0),
                    id: ar.req.id,
                })
            })
            .collect();
        let assigns = step::plan_chunks(&lanes, budget, self.econf.policy);
        if assigns.is_empty() {
            return Ok(());
        }
        self.upload_bank_if_dirty()?;
        let b = self.econf.decode_slots;
        let ci = step::assemble_chunk(&self.slots, b, self.cfg.max_seq, &assigns);
        let ids_t = HostTensor::i32(vec![b], ci.ids);
        let tokens_t = HostTensor::i32(vec![b, self.cfg.max_seq], ci.tokens);
        let start_t = HostTensor::i32(vec![b], ci.start);
        let len_t = HostTensor::i32(vec![b], ci.len);
        let mut data: BTreeMap<&'static str, &HostTensor> = BTreeMap::new();
        data.insert("ids", &ids_t);
        data.insert("tokens", &tokens_t);
        data.insert("start", &start_t);
        data.insert("len", &len_t);
        let (logits, elapsed) = self.run_with_cache(exe, &data)?;
        self.metrics.prefill_time += elapsed;

        let vocab = self.cfg.vocab;
        for a in &assigns {
            let s = a.slot;
            // Advance the lane past its granted chunk; a lane whose whole
            // prompt is now in cache samples its first token from the
            // chunk's last-position logits row.
            let (fed, first) = {
                let Some(ar) = self.slots[s].as_mut() else { continue };
                ar.pos += a.n;
                self.metrics.chunk_prefill_tokens += a.n;
                (ar.pos < ar.req.prompt.len(), ar.first_token_at.is_none())
            };
            if fed {
                continue;
            }
            let now = self.clock.now();
            let (id, tok, pos, reason, ttft_hint, hit_lane) = {
                let Some(ar) = self.slots[s].as_mut() else { continue };
                let row = logits.read_f32_range(s * vocab, vocab);
                let tok = sampler::sample(
                    &row,
                    ar.req.sampling.temperature,
                    ar.req.sampling.top_k,
                    &mut ar.rng_state,
                );
                ar.generated.push(tok);
                if let Some(prev) = ar.last_token_at {
                    self.metrics.itl.record(now.saturating_duration_since(prev));
                }
                ar.last_token_at = Some(now);
                let hint = if first {
                    ar.first_token_at = Some(now);
                    Some((now - ar.submitted).as_secs_f64())
                } else {
                    None
                };
                (ar.req.id, tok, ar.generated.len() - 1, ar.done(), hint, !ar.publish_on_fed)
            };
            if let Some(ttft) = ttft_hint {
                if hit_lane {
                    self.metrics.prefix_hit_ttft.record_us(ttft * 1e6);
                }
            }
            self.metrics.tokens_generated += 1;
            if !matches!(reason, Some(FinishReason::StopToken)) {
                self.events.push(StreamEvent::Token { id, token: tok, pos, ttft_hint });
            }
            if let Some(reason) = reason {
                let Some(ar) = self.slots[s].take() else { continue };
                self.alloc.release(s)?;
                self.release_kv_lane(s)?;
                self.finish(ar, reason);
            }
        }
        Ok(())
    }

    /// Publish fully-fed cold chunked lanes' prompt prefixes into the
    /// shared-prefix cache — the chunked-path counterpart of the publish
    /// step inside `prefill_batch`, so later identical prompts hit.  A
    /// lane that finished in the same step it was fed has already
    /// released its blocks and simply never publishes.
    fn publish_fed_lanes(&mut self) -> Result<()> {
        if !self.econf.paged_kv {
            // Flat KV shares nothing; just retire the flags.
            for ar in self.slots.iter_mut().flatten() {
                ar.publish_on_fed = false;
            }
            return Ok(());
        }
        for s in 0..self.slots.len() {
            let prompt_len = match self.slots[s].as_ref() {
                Some(ar) if ar.publish_on_fed && ar.pos >= ar.req.prompt.len() => {
                    ar.req.prompt.len()
                }
                _ => continue,
            };
            // Publication reads lane blocks host-side, same as adoption.
            if self.kv.materialize_host()? {
                self.metrics.kv_host_syncs += 1;
            }
            let published = self.paged.publish_prefix(&mut self.kv, s, prompt_len)?;
            self.metrics.kv_blocks_published += published;
            if let Some(ar) = self.slots[s].as_mut() {
                ar.publish_on_fed = false;
            }
        }
        Ok(())
    }

    /// Return a reaped lane's KV blocks exactly once: private blocks to
    /// the free list, shared-prefix refs dropped (never the cached
    /// originals — other lanes may hold them).
    fn release_kv_lane(&mut self, slot: usize) -> Result<()> {
        self.paged.release_lane(slot).with_context(|| format!("releasing KV lane {slot}"))?;
        Ok(())
    }

    /// Complete a request: release its bank pin, record latency metrics,
    /// and emit the terminal [`StreamEvent::Finished`].
    fn finish(&mut self, ar: ActiveRequest, reason: FinishReason) {
        // The lane no longer references its adapter slot; release the pin
        // so the pager may evict it (identity slot 0 is a no-op).
        self.registry.unpin(ar.slot_adapter);
        let now = self.clock.now();
        let ttft = ar
            .first_token_at
            .map(|t| (t - ar.submitted).as_secs_f64())
            .unwrap_or_default();
        let mut tokens = ar.generated;
        if reason == FinishReason::StopToken {
            tokens.pop();
        }
        self.metrics.requests_completed += 1;
        self.metrics.ttft.record_us(ttft * 1e6);
        let e2e = (now - ar.submitted).as_secs_f64();
        self.metrics.e2e.record_us(e2e * 1e6);
        self.events.push(StreamEvent::Finished(RequestOutput {
            id: ar.req.id,
            adapter: ar.req.adapter,
            tokens,
            finish: reason,
            ttft,
            e2e,
        }));
    }

    /// Reap requests whose deadline passed: shed expired queued work before
    /// it is admitted, and free decode lanes holding expired requests
    /// before spending another decode step on them.  Each reaped request
    /// ends its stream with [`EngineError::DeadlineExceeded`].
    fn enforce_deadlines(&mut self) -> Result<()> {
        let now = self.clock.now();
        for req in self.queue.shed_expired(now) {
            // A shed request leaves the admission gates too.
            self.kv_stalled.remove(&req.id);
            self.bank_stalled.remove(&req.id);
            self.metrics.deadline_shed += 1;
            self.events
                .push(StreamEvent::Error { id: req.id, error: EngineError::DeadlineExceeded });
        }
        for s in 0..self.slots.len() {
            if self.slots[s].as_ref().is_some_and(|ar| ar.req.expired(now)) {
                let Some(ar) = self.slots[s].take() else { continue };
                self.alloc.release(s)?;
                self.release_kv_lane(s)?;
                self.registry.unpin(ar.slot_adapter);
                self.metrics.deadline_shed += 1;
                self.events
                    .push(StreamEvent::Error { id: ar.req.id, error: EngineError::DeadlineExceeded });
            }
        }
        Ok(())
    }

    /// One scheduler iteration: enforce deadlines, admit, decode.  Returns
    /// every [`StreamEvent`] produced while lanes advanced this iteration —
    /// `Admitted`/`Token` progress plus terminal `Finished`/`Error` events.
    pub fn step(&mut self) -> Result<Vec<StreamEvent>> {
        self.metrics.start();
        self.metrics.queue_depth.record_value(self.queue.len() as f64);
        self.enforce_deadlines()?;
        self.maybe_prefill()?;
        // A request can finish at prefill time (max_new_tokens == 1, or a
        // stop token sampled from the prefill logits).
        let finished_at_prefill: Vec<(usize, FinishReason)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(s, slot)| {
                slot.as_ref().and_then(|ar| ar.done().map(|reason| (s, reason)))
            })
            .collect();
        for (s, reason) in finished_at_prefill {
            let Some(ar) = self.slots[s].take() else { continue };
            self.alloc.release(s)?;
            self.release_kv_lane(s)?;
            self.finish(ar, reason);
        }
        self.decode_once()?;
        self.chunk_prefill_once()?;
        self.publish_fed_lanes()?;
        if self.n_active() == 0 {
            // Nobody is observing decode gaps across the idle period; the
            // next admitted batch starts its stall accounting fresh.
            self.last_decode_at = None;
        }
        Ok(std::mem::take(&mut self.events))
    }

    /// Submit a workload and run to completion (bench/example driver).
    /// Returns terminal outputs only; streaming consumers use
    /// [`Engine::step`] (or the threaded [`super::server::EngineClient`])
    /// to observe per-token events.
    ///
    /// Typed [`EngineError::QueueFull`] backpressure parks the remaining
    /// requests and drains a scheduler step; any other submit error aborts.
    /// A request that dies mid-run (e.g. a deadline shed) aborts too —
    /// callers of this API zip outputs against inputs by sorted id and
    /// must never silently lose a request from the returned set.
    pub fn run_all(&mut self, reqs: Vec<Request>) -> Result<Vec<RequestOutput>> {
        let mut pending: std::collections::VecDeque<Request> = reqs.into();
        let mut outputs = Vec::new();
        while !pending.is_empty() || self.has_work() {
            while let Some(mut r) = pending.pop_front() {
                // Stamp before the first attempt: a backpressured request
                // keeps its original clock across re-submits, so its
                // reported latency includes the time it spent parked here.
                if r.submitted_at.is_none() {
                    r.submitted_at = Some(self.clock.now());
                }
                match self.submit(r.clone()) {
                    Ok(_) => {}
                    Err(EngineError::QueueFull { .. }) => {
                        pending.push_front(r);
                        break;
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            for ev in self.step()? {
                match ev {
                    StreamEvent::Finished(out) => outputs.push(out),
                    StreamEvent::Error { id, error } => {
                        return Err(error)
                            .with_context(|| format!("request {id} died during run_all"));
                    }
                    StreamEvent::Admitted { .. } | StreamEvent::Token { .. } => {}
                }
            }
        }
        self.metrics.stop();
        Ok(outputs)
    }
}
