//! Microbench of the host-side adapter operations: RoAd's element-wise
//! rotate (Eq. 4) vs LoRA's rank-r matmul delta vs weight merging, across
//! ranks — the rank axis of Figure 4 (Left) at the op level, plus the
//! merge cost that makes "merged serving" free at request time.
//!
//! ```bash
//! cargo bench --bench adapter_ops
//! ```

use std::time::Instant;

use road::adapters::RoadVectors;
use road::model::{lora_merge_weight, road_merge_weight, road_rotate_vec};
use road::tensor::HostTensor;
use road::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<40} {:>10.2} ns/op", per * 1e9);
    per
}

fn main() {
    let mut rng = Rng::seed_from(1);
    let d_in = 256usize;
    let d_out = 256usize;
    let iters = 2000;

    let h: Vec<f32> = rng.normal_vec(d_out, 1.0);
    let theta: Vec<f32> = rng.normal_vec(d_out / 2, 0.3);
    let alpha = vec![1.0f32; d_out / 2];
    let v = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();

    println!("# adapter epilogue cost per token (d={d_out})");
    let road_t = bench("road rotate (element-wise, Eq. 4)", iters, || {
        std::hint::black_box(road_rotate_vec(
            std::hint::black_box(&h),
            &v.r1,
            &v.r2,
        ));
    });

    let x: Vec<f32> = rng.normal_vec(d_in, 1.0);
    for rank in [4usize, 8, 16, 32] {
        let lb: Vec<f32> = rng.normal_vec(d_in * rank, 0.05);
        let la: Vec<f32> = rng.normal_vec(rank * d_out, 0.05);
        let lora_t = bench(&format!("lora delta (bmm-equivalent, r={rank})"), iters, || {
            // z += (x @ lb) @ la
            let mut mid = vec![0f32; rank];
            for r in 0..rank {
                let mut acc = 0f32;
                for i in 0..d_in {
                    acc += x[i] * lb[i * rank + r];
                }
                mid[r] = acc;
            }
            let mut z = vec![0f32; d_out];
            for r in 0..rank {
                let m = mid[r];
                for j in 0..d_out {
                    z[j] += m * la[r * d_out + j];
                }
            }
            std::hint::black_box(z);
        });
        println!("    -> lora(r={rank}) / road = {:.1}x", lora_t / road_t);
    }

    println!("\n# one-time merge cost (amortized to zero at serving time)");
    let w = HostTensor::f32(vec![d_in, d_out], rng.normal_vec(d_in * d_out, 0.05));
    bench("road merge  W <- W R^T", 200, || {
        std::hint::black_box(road_merge_weight(&w, &v.r1, &v.r2));
    });
    let lb: Vec<f32> = rng.normal_vec(d_in * 8, 0.05);
    let la: Vec<f32> = rng.normal_vec(8 * d_out, 0.05);
    bench("lora merge  W <- W + BA (r=8)", 200, || {
        std::hint::black_box(lora_merge_weight(&w, &lb, &la, 8));
    });
}
