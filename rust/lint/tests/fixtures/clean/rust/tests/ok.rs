fn gated_a() {
    require_artifacts!();
}

fn gated_b() {
    require_artifacts!();
}
